"""Predicate-filter Pallas kernel (L1).

Computes an ``i32`` 0/1 selection mask for a range predicate
``lo <= x < hi`` over one column, combined with the incoming validity
mask. This is the device half of the Filter operator (§3.1): the mask is
returned to the coordinator, which performs the (memory-bound) gather
when materializing the output batch.

The kernel is gridded over ``BLOCK_ROWS`` tiles so each tile fits a VMEM
block; scalars ride along as (1,)-shaped operands mapped to block (0,).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import BATCH_ROWS, BLOCK_ROWS


def _range_mask_kernel(col_ref, lo_ref, hi_ref, mask_ref, out_ref):
    x = col_ref[...]
    lo = lo_ref[0]
    hi = hi_ref[0]
    keep = (x >= lo) & (x < hi)
    out_ref[...] = jnp.where(keep, 1, 0).astype(jnp.int32) * mask_ref[...]


def range_mask(col, lo, hi, mask, *, n=BATCH_ROWS, block=BLOCK_ROWS):
    """0/1 i32 mask for ``lo <= col < hi`` AND ``mask != 0``.

    Args:
      col:  f32[n] or i64[n] column values (padding rows are don't-care).
      lo:   same-dtype (1,) lower bound (inclusive).
      hi:   same-dtype (1,) upper bound (exclusive).
      mask: i32[n] incoming validity mask (0 for padding rows).
    Returns:
      i32[n] selection mask.
    """
    grid = (n // block,)
    return pl.pallas_call(
        _range_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(col, lo, hi, mask)


def _eq_mask_kernel(col_ref, val_ref, mask_ref, out_ref):
    keep = col_ref[...] == val_ref[0]
    out_ref[...] = jnp.where(keep, 1, 0).astype(jnp.int32) * mask_ref[...]


def eq_mask(col, val, mask, *, n=BATCH_ROWS, block=BLOCK_ROWS):
    """0/1 i32 mask for ``col == val`` AND ``mask != 0`` (dictionary-coded
    string equality predicates are pushed down as integer codes)."""
    grid = (n // block,)
    return pl.pallas_call(
        _eq_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(col, val, mask)
