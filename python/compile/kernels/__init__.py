"""Layer-1 Pallas kernels for the Theseus compute hot spots.

Every kernel is written with ``interpret=True`` so that the lowered HLO
contains plain XLA ops executable by the PJRT CPU client in the Rust
runtime (real-TPU Mosaic lowering is compile-only in this environment;
see DESIGN.md §Hardware-Adaptation).

Fixed shapes: HLO is static-shape, so the Rust coordinator pads every
batch to ``BATCH_ROWS`` and passes the true row count out-of-band (the
mask column). This mirrors the paper's batch sizing discipline (§3.1):
"large enough to amortize GPU kernel launch overhead and small enough to
allow multiple GPU streams to run simultaneously".
"""

BATCH_ROWS = 8192      # rows per device batch (padded)
BLOCK_ROWS = 1024      # Pallas block size (VMEM tile)
NUM_PARTS = 16         # exchange hash-partition fanout
NUM_BUCKETS = 1024     # pre-aggregation hash buckets
BLOOM_BITS = 16384     # LIP bloom filter width (unpacked u32 cells)

from . import filter as filter_kernel   # noqa: E402,F401
from . import hashing                    # noqa: E402,F401
from . import agg                        # noqa: E402,F401
from . import bloom                      # noqa: E402,F401
from . import ref                        # noqa: E402,F401
