"""Bucketed pre-aggregation Pallas kernel (L1).

Grid-accumulation reduction: the grid walks ``BLOCK_ROWS`` tiles of the
input while every grid step maps to the *same* output block, so the
kernel accumulates per-bucket partial sums/counts across tiles — the
Pallas idiom for a reduction kernel (the TPU analogue of a CUDA
atomic-add histogram kernel; see DESIGN.md §Hardware-Adaptation).

The coordinator merges per-batch partials and resolves bucket collisions
with the true group keys (exec/operators/aggregate.rs), exactly like a
two-phase GPU hash aggregation with a device pre-aggregate pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import BATCH_ROWS, BLOCK_ROWS, NUM_BUCKETS


def _preagg_kernel(bucket_ref, val_ref, mask_ref, sum_ref, cnt_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    b = bucket_ref[...]
    m = mask_ref[...]
    v = val_ref[...] * m.astype(val_ref.dtype)
    g = sum_ref.shape[0]
    sum_ref[...] += jnp.zeros((g,), val_ref.dtype).at[b].add(v)
    cnt_ref[...] += jnp.zeros((g,), jnp.int32).at[b].add(m)


def preagg_sum_count(buckets, vals, mask, *, g=NUM_BUCKETS, n=BATCH_ROWS,
                     block=BLOCK_ROWS):
    """Per-bucket (sum f32[g], count i32[g]) of masked values.

    Padding rows must carry ``mask == 0``; they then contribute nothing
    to either output (bucket 0 receives +0.0 / +0).
    """
    grid = (n // block,)
    return pl.pallas_call(
        _preagg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g,), jnp.float32),
            jax.ShapeDtypeStruct((g,), jnp.int32),
        ],
        interpret=True,
    )(buckets, vals, mask)


def _minmax_kernel(bucket_ref, val_ref, mask_ref, min_ref, max_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        max_ref[...] = jnp.full_like(max_ref, -jnp.inf)

    b = bucket_ref[...]
    m = mask_ref[...] != 0
    v = val_ref[...]
    g = min_ref.shape[0]
    vmin = jnp.where(m, v, jnp.inf)
    vmax = jnp.where(m, v, -jnp.inf)
    min_ref[...] = jnp.minimum(min_ref[...],
                               jnp.full((g,), jnp.inf, v.dtype).at[b].min(vmin))
    max_ref[...] = jnp.maximum(max_ref[...],
                               jnp.full((g,), -jnp.inf, v.dtype).at[b].max(vmax))


def preagg_min_max(buckets, vals, mask, *, g=NUM_BUCKETS, n=BATCH_ROWS,
                   block=BLOCK_ROWS):
    """Per-bucket (min f32[g], max f32[g]); empty buckets hold ±inf."""
    grid = (n // block,)
    return pl.pallas_call(
        _minmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g,), jnp.float32),
            jax.ShapeDtypeStruct((g,), jnp.float32),
        ],
        interpret=True,
    )(buckets, vals, mask)
