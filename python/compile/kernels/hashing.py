"""Hash / partition Pallas kernel (L1).

SplitMix64-finalizer hash of i64 join/exchange keys. The same finalizer
is implemented bit-for-bit in Rust (``rust/src/util/hash.rs``) so that
the CPU baseline engine, the bucket-overflow finalize step, and the
device kernels agree on every partition decision.

Used by the Adaptive Exchange operator (§3.2) to hash-partition batches
across workers, and by the pre-aggregation / join stages to derive
bucket ids.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import BATCH_ROWS, BLOCK_ROWS

_SPLITMIX_C0 = 0x9E3779B97F4A7C15
_SPLITMIX_C1 = 0xBF58476D1CE4E5B9
_SPLITMIX_C2 = 0x94D049BB133111EB


def splitmix64(x):
    """SplitMix64 finalizer over uint64 lanes (vectorized)."""
    z = (x + jnp.uint64(_SPLITMIX_C0)).astype(jnp.uint64)
    z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(_SPLITMIX_C1)
    z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(_SPLITMIX_C2)
    return z ^ (z >> jnp.uint64(31))


def _hash_kernel(keys_ref, out_ref):
    k = keys_ref[...].astype(jnp.uint64)
    out_ref[...] = splitmix64(k)


def hash_keys(keys, *, n=BATCH_ROWS, block=BLOCK_ROWS):
    """u64[n] SplitMix64 hash of i64[n] keys."""
    grid = (n // block,)
    return pl.pallas_call(
        _hash_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint64),
        interpret=True,
    )(keys)


def _partition_kernel(keys_ref, mask_ref, part_ref, *, parts):
    h = splitmix64(keys_ref[...].astype(jnp.uint64))
    p = (h & jnp.uint64(parts - 1)).astype(jnp.int32)
    # Padding rows are routed to partition 0 but carry mask 0; the
    # coordinator drops them during compaction.
    part_ref[...] = jnp.where(mask_ref[...] != 0, p, 0)


def partition_ids(keys, mask, *, parts, n=BATCH_ROWS, block=BLOCK_ROWS):
    """i32[n] partition id in [0, parts) for each key; parts must be 2^k."""
    assert parts & (parts - 1) == 0, "parts must be a power of two"
    grid = (n // block,)
    import functools
    return pl.pallas_call(
        functools.partial(_partition_kernel, parts=parts),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(keys, mask)


def _bucket_kernel(keys_ref, mask_ref, out_ref, *, buckets):
    h = splitmix64(keys_ref[...].astype(jnp.uint64))
    # Use the *high* bits for bucketing so bucket ids stay independent of
    # the low-bit partition ids (avoids correlated skew after exchange).
    b = ((h >> jnp.uint64(32)) & jnp.uint64(buckets - 1)).astype(jnp.int32)
    out_ref[...] = jnp.where(mask_ref[...] != 0, b, 0)


def bucket_ids(keys, mask, *, buckets, n=BATCH_ROWS, block=BLOCK_ROWS):
    """i32[n] aggregation/join bucket id in [0, buckets) per key."""
    assert buckets & (buckets - 1) == 0
    grid = (n // block,)
    import functools
    return pl.pallas_call(
        functools.partial(_bucket_kernel, buckets=buckets),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(keys, mask)
