"""Bloom-filter build/probe Pallas kernels (L1) — Lookahead Information
Passing (paper §5, citing Zhu et al., VLDB'17).

The build side of a join builds a bloom filter over its (filtered) key
set; the filter is broadcast to all workers and *pushed down* under the
probe-side scan, discarding probe rows that cannot join before they pay
exchange + join cost. The paper reports ~50% runtime reduction on
join-extensive queries; bench E5 reproduces the ablation.

Cells are unpacked u32 0/1 flags (scatter-max builds them portably under
interpret mode); packing to real bit words is a recorded perf-pass
candidate (EXPERIMENTS.md §Perf).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import BATCH_ROWS, BLOCK_ROWS, BLOOM_BITS
from .hashing import splitmix64

_SECOND_HASH_SEED = 0xA24BAED4963EE407


def _hash2(k):
    """Two independent hash lanes per key (double hashing)."""
    h1 = splitmix64(k)
    h2 = splitmix64(k ^ jnp.uint64(_SECOND_HASH_SEED))
    return h1, h2


def _build_kernel(keys_ref, mask_ref, bits_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        bits_ref[...] = jnp.zeros_like(bits_ref)

    b = bits_ref.shape[0]
    k = keys_ref[...].astype(jnp.uint64)
    m = mask_ref[...].astype(jnp.uint32)
    h1, h2 = _hash2(k)
    i1 = (h1 % jnp.uint64(b)).astype(jnp.int32)
    i2 = (h2 % jnp.uint64(b)).astype(jnp.int32)
    cells = jnp.zeros((b,), jnp.uint32).at[i1].max(m).at[i2].max(m)
    bits_ref[...] = jnp.maximum(bits_ref[...], cells)


def bloom_build(keys, mask, *, bits=BLOOM_BITS, n=BATCH_ROWS,
                block=BLOCK_ROWS):
    """u32[bits] bloom cells (0/1) over the masked keys of one batch.

    Per-batch filters are OR-merged by the coordinator (cheap u32 max)
    before broadcast — the same merge the paper does across workers.
    """
    grid = (n // block,)
    return pl.pallas_call(
        _build_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bits,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((bits,), jnp.uint32),
        interpret=True,
    )(keys, mask)


def _probe_kernel(keys_ref, mask_ref, bits_ref, out_ref):
    b = bits_ref.shape[0]
    k = keys_ref[...].astype(jnp.uint64)
    h1, h2 = _hash2(k)
    i1 = (h1 % jnp.uint64(b)).astype(jnp.int32)
    i2 = (h2 % jnp.uint64(b)).astype(jnp.int32)
    hit = (bits_ref[i1] != 0) & (bits_ref[i2] != 0)
    out_ref[...] = jnp.where(hit, 1, 0).astype(jnp.int32) * mask_ref[...]


def bloom_probe(keys, mask, bits_arr, *, bits=BLOOM_BITS, n=BATCH_ROWS,
                block=BLOCK_ROWS):
    """i32[n] mask of probe keys that *may* be present (no false
    negatives; false-positive rate set by bits / build-side NDV)."""
    grid = (n // block,)
    return pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((bits,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(keys, mask, bits_arr)
