"""Pure-numpy oracle for every L1 kernel — the correctness ground truth.

pytest (python/tests/) asserts kernel == ref across hypothesis-generated
shapes, dtypes, and values; the Rust side re-asserts the same SplitMix64
constants via artifacts executed through PJRT (rust/tests/).
"""

import numpy as np

_SPLITMIX_C0 = np.uint64(0x9E3779B97F4A7C15)
_SPLITMIX_C1 = np.uint64(0xBF58476D1CE4E5B9)
_SPLITMIX_C2 = np.uint64(0x94D049BB133111EB)
_SECOND_HASH_SEED = np.uint64(0xA24BAED4963EE407)


def splitmix64(x: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):
        z = (x.astype(np.uint64) + _SPLITMIX_C0)
        z = (z ^ (z >> np.uint64(30))) * _SPLITMIX_C1
        z = (z ^ (z >> np.uint64(27))) * _SPLITMIX_C2
        return z ^ (z >> np.uint64(31))


def range_mask(col, lo, hi, mask):
    keep = (col >= lo) & (col < hi)
    return (keep.astype(np.int32) * mask.astype(np.int32)).astype(np.int32)


def eq_mask(col, val, mask):
    return ((col == val).astype(np.int32) * mask.astype(np.int32)).astype(
        np.int32)


def partition_ids(keys, mask, parts):
    h = splitmix64(keys.astype(np.uint64))
    p = (h & np.uint64(parts - 1)).astype(np.int32)
    return np.where(mask != 0, p, 0).astype(np.int32)


def bucket_ids(keys, mask, buckets):
    h = splitmix64(keys.astype(np.uint64))
    b = ((h >> np.uint64(32)) & np.uint64(buckets - 1)).astype(np.int32)
    return np.where(mask != 0, b, 0).astype(np.int32)


def preagg_sum_count(buckets, vals, mask, g):
    sums = np.zeros(g, np.float32)
    cnts = np.zeros(g, np.int32)
    np.add.at(sums, buckets, vals.astype(np.float32) * mask)
    np.add.at(cnts, buckets, mask.astype(np.int32))
    return sums, cnts


def preagg_min_max(buckets, vals, mask, g):
    mins = np.full(g, np.inf, np.float32)
    maxs = np.full(g, -np.inf, np.float32)
    sel = mask != 0
    np.minimum.at(mins, buckets[sel], vals[sel].astype(np.float32))
    np.maximum.at(maxs, buckets[sel], vals[sel].astype(np.float32))
    return mins, maxs


def _hash2(keys):
    k = keys.astype(np.uint64)
    return splitmix64(k), splitmix64(k ^ _SECOND_HASH_SEED)


def bloom_build(keys, mask, bits):
    h1, h2 = _hash2(keys)
    cells = np.zeros(bits, np.uint32)
    sel = mask != 0
    cells[(h1[sel] % np.uint64(bits)).astype(np.int64)] = 1
    cells[(h2[sel] % np.uint64(bits)).astype(np.int64)] = 1
    return cells


def bloom_probe(keys, mask, cells):
    bits = np.uint64(cells.shape[0])
    h1, h2 = _hash2(keys)
    hit = (cells[(h1 % bits).astype(np.int64)] != 0) & (
        cells[(h2 % bits).astype(np.int64)] != 0)
    return (hit.astype(np.int32) * mask.astype(np.int32)).astype(np.int32)
