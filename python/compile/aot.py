"""AOT pipeline: lower every L2 stage to HLO *text* + emit a manifest.

HLO text — not ``lowered.compile().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids
which xla_extension 0.5.1 (what the Rust ``xla`` crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Outputs:
  artifacts/<stage>.hlo.txt          one per model.STAGES entry
  artifacts/manifest.tsv             stage name + I/O specs, parsed by
                                     rust/src/runtime/manifest.rs
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import (BATCH_ROWS, BLOCK_ROWS, BLOOM_BITS, NUM_BUCKETS,  # noqa: E402
                      NUM_PARTS)


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_str(s) -> str:
    """'f32[8192]' / 'i64[1]' — the grammar runtime/manifest.rs parses."""
    name = {"float32": "f32", "int64": "i64", "int32": "i32",
            "uint32": "u32", "uint64": "u64"}[str(s.dtype)]
    dims = ",".join(str(d) for d in s.shape)
    return f"{name}[{dims}]"


def lower_stage(name: str, fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    out_shapes = jax.eval_shape(fn, *example_args)
    return text, [_spec_str(s) for s in example_args], \
        [_spec_str(s) for s in out_shapes]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated stage subset (for iteration)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    rows = []
    for name, (fn, ex) in model.STAGES.items():
        if only and name not in only:
            continue
        text, ins, outs = lower_stage(name, fn, ex)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        rows.append((name, ins, outs))
        print(f"  {name}: {len(text)} chars, in={ins} out={outs}")

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    header = (f"# theseus AOT manifest\tbatch_rows={BATCH_ROWS}"
              f"\tblock_rows={BLOCK_ROWS}\tnum_parts={NUM_PARTS}"
              f"\tnum_buckets={NUM_BUCKETS}\tbloom_bits={BLOOM_BITS}\n")
    with open(manifest, "w") as f:
        f.write(header)
        for name, ins, outs in rows:
            f.write(f"{name}\t{';'.join(ins)}\t{';'.join(outs)}\n")
    print(f"wrote {manifest} ({len(rows)} stages)")


if __name__ == "__main__":
    main()
