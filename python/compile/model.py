"""Layer-2 stage graphs: the JAX compute functions the Rust Compute
Executor dispatches to, each composed from the L1 Pallas kernels.

One *stage* == one AOT HLO artifact == one PJRT executable in the Rust
``runtime::KernelRegistry``. Shapes are static (see kernels/__init__);
``aot.py`` lowers every entry of ``STAGES`` and emits a manifest the
Rust side parses.

Stage catalogue (operator → stage):
  Filter                → filter_range_f32 / filter_range_i64 / filter_eq_i64
  Adaptive Exchange     → hash_partition (ids + histogram)
  Hash Aggregate        → bucket_preagg (ids + masked sum/count/min/max)
  Adaptive Join (LIP)   → bloom_build / bloom_probe
  fused scan filter     → filter_hash_fused (perf-pass: one launch
                          instead of two for filter→exchange pipelines)
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import (BATCH_ROWS, BLOOM_BITS, NUM_BUCKETS, NUM_PARTS, agg,
                      bloom, filter as filt, hashing)

N = BATCH_ROWS


# --------------------------------------------------------------------------
# stage functions (all return tuples — lowered with return_tuple=True)
# --------------------------------------------------------------------------

def filter_range_f32(col, lo, hi, mask):
    return (filt.range_mask(col, lo, hi, mask),)


def filter_range_i64(col, lo, hi, mask):
    return (filt.range_mask(col, lo, hi, mask),)


def filter_eq_i64(col, val, mask):
    return (filt.eq_mask(col, val, mask),)


def hash_partition(keys, mask):
    """Partition ids + per-partition histogram for the Adaptive Exchange.

    The histogram feeds the exchange's *size estimation* phase (§3.2):
    workers broadcast estimated per-partition bytes derived from these
    counts before deciding hash-partition vs broadcast.
    """
    part = hashing.partition_ids(keys, mask, parts=NUM_PARTS)
    hist = jnp.zeros((NUM_PARTS,), jnp.int32).at[part].add(mask)
    return part, hist


def bucket_preagg(keys, vals, mask):
    """Bucket ids + per-bucket sum/count/min/max — the device
    pre-aggregation pass of the two-phase hash aggregate."""
    b = hashing.bucket_ids(keys, mask, buckets=NUM_BUCKETS)
    sums, cnts = agg.preagg_sum_count(b, vals, mask)
    mins, maxs = agg.preagg_min_max(b, vals, mask)
    return b, sums, cnts, mins, maxs


def bloom_build(keys, mask):
    return (bloom.bloom_build(keys, mask),)


def bloom_probe(keys, mask, cells):
    return (bloom.bloom_probe(keys, mask, cells),)


def filter_hash_fused(col, lo, hi, keys, mask):
    """Fused Filter → Exchange-hash stage: the filter mask feeds the
    partitioner in one launch, saving one device round-trip per batch on
    the scan→filter→exchange spine of most TPC-H plans (perf pass)."""
    m = filt.range_mask(col, lo, hi, mask)
    part = hashing.partition_ids(keys, m, parts=NUM_PARTS)
    hist = jnp.zeros((NUM_PARTS,), jnp.int32).at[part].add(m)
    return m, part, hist


# --------------------------------------------------------------------------
# lowering specs
# --------------------------------------------------------------------------

def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int64)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


#: name -> (fn, example_args). Every entry becomes artifacts/<name>.hlo.txt.
STAGES = {
    "filter_range_f32": (filter_range_f32, (_f32(N), _f32(1), _f32(1), _i32(N))),
    "filter_range_i64": (filter_range_i64, (_i64(N), _i64(1), _i64(1), _i32(N))),
    "filter_eq_i64": (filter_eq_i64, (_i64(N), _i64(1), _i32(N))),
    "hash_partition": (hash_partition, (_i64(N), _i32(N))),
    "bucket_preagg": (bucket_preagg, (_i64(N), _f32(N), _i32(N))),
    "bloom_build": (bloom_build, (_i64(N), _i32(N))),
    "bloom_probe": (bloom_probe, (_i64(N), _i32(N), _u32(BLOOM_BITS))),
    "filter_hash_fused": (filter_hash_fused,
                          (_f32(N), _f32(1), _f32(1), _i64(N), _i32(N))),
}
