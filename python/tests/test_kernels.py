"""L1 correctness: every Pallas kernel vs the pure-numpy oracle.

Hypothesis sweeps shapes (n, block), dtypes, and adversarial values
(bounds at extremes, empty masks, all-duplicate keys); fixed-seed numpy
cases pin the regression corpus.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (BLOOM_BITS, NUM_BUCKETS, NUM_PARTS, agg, bloom,
                             hashing, ref)
from compile.kernels import filter as filt

RNG = np.random.default_rng(7)


def _shapes():
    # (n, block) with block | n; small so interpret-mode stays fast.
    return st.sampled_from([(64, 16), (128, 32), (256, 64), (1024, 256)])


def _mask(n, rng=RNG):
    m = rng.integers(0, 2, n).astype(np.int32)
    return m


# ---------------------------------------------------------------- filter --

@settings(deadline=None, max_examples=20)
@given(_shapes(), st.floats(-100, 100), st.floats(-100, 100),
       st.integers(0, 2**32 - 1))
def test_range_mask_f32(shape, a, b, seed):
    n, block = shape
    rng = np.random.default_rng(seed)
    col = rng.normal(0, 50, n).astype(np.float32)
    mask = rng.integers(0, 2, n).astype(np.int32)
    lo, hi = np.float32(min(a, b)), np.float32(max(a, b))
    got = np.asarray(filt.range_mask(col, np.array([lo]), np.array([hi]),
                                     mask, n=n, block=block))
    np.testing.assert_array_equal(got, ref.range_mask(col, lo, hi, mask))


@settings(deadline=None, max_examples=20)
@given(_shapes(), st.integers(-1000, 1000), st.integers(-1000, 1000),
       st.integers(0, 2**32 - 1))
def test_range_mask_i64(shape, a, b, seed):
    n, block = shape
    rng = np.random.default_rng(seed)
    col = rng.integers(-1000, 1000, n).astype(np.int64)
    mask = rng.integers(0, 2, n).astype(np.int32)
    lo, hi = np.int64(min(a, b)), np.int64(max(a, b))
    got = np.asarray(filt.range_mask(col, np.array([lo]), np.array([hi]),
                                     mask, n=n, block=block))
    np.testing.assert_array_equal(got, ref.range_mask(col, lo, hi, mask))


@settings(deadline=None, max_examples=15)
@given(_shapes(), st.integers(0, 24), st.integers(0, 2**32 - 1))
def test_eq_mask(shape, val, seed):
    n, block = shape
    rng = np.random.default_rng(seed)
    col = rng.integers(0, 25, n).astype(np.int64)  # dictionary codes
    mask = rng.integers(0, 2, n).astype(np.int32)
    got = np.asarray(filt.eq_mask(col, np.array([np.int64(val)]), mask,
                                  n=n, block=block))
    np.testing.assert_array_equal(got, ref.eq_mask(col, np.int64(val), mask))


def test_range_mask_empty_and_full():
    n, block = 64, 16
    col = np.arange(n, dtype=np.float32)
    ones = np.ones(n, np.int32)
    got = np.asarray(filt.range_mask(col, np.array([np.float32(1e9)]),
                                     np.array([np.float32(2e9)]), ones,
                                     n=n, block=block))
    assert got.sum() == 0
    got = np.asarray(filt.range_mask(col, np.array([np.float32(-1e9)]),
                                     np.array([np.float32(1e9)]), ones,
                                     n=n, block=block))
    assert got.sum() == n


# ----------------------------------------------------------------- hash --

@settings(deadline=None, max_examples=20)
@given(_shapes(), st.integers(0, 2**32 - 1))
def test_hash_keys_matches_ref(shape, seed):
    n, block = shape
    rng = np.random.default_rng(seed)
    keys = rng.integers(-2**62, 2**62, n).astype(np.int64)
    got = np.asarray(hashing.hash_keys(keys, n=n, block=block))
    np.testing.assert_array_equal(got, ref.splitmix64(keys.astype(np.uint64)))


@settings(deadline=None, max_examples=20)
@given(_shapes(), st.sampled_from([2, 4, 8, 16, 32]),
       st.integers(0, 2**32 - 1))
def test_partition_ids(shape, parts, seed):
    n, block = shape
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 10**9, n).astype(np.int64)
    mask = rng.integers(0, 2, n).astype(np.int32)
    got = np.asarray(hashing.partition_ids(keys, mask, parts=parts,
                                           n=n, block=block))
    np.testing.assert_array_equal(got, ref.partition_ids(keys, mask, parts))
    assert got.min() >= 0 and got.max() < parts


@settings(deadline=None, max_examples=15)
@given(_shapes(), st.sampled_from([64, 256, 1024]), st.integers(0, 2**32 - 1))
def test_bucket_ids(shape, buckets, seed):
    n, block = shape
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 10**9, n).astype(np.int64)
    mask = rng.integers(0, 2, n).astype(np.int32)
    got = np.asarray(hashing.bucket_ids(keys, mask, buckets=buckets,
                                        n=n, block=block))
    np.testing.assert_array_equal(got, ref.bucket_ids(keys, mask, buckets))


def test_partition_balance():
    """SplitMix64 should spread sequential keys near-uniformly (the
    exchange depends on this to avoid skewed workers)."""
    n, parts = 8192, 16
    keys = np.arange(n, dtype=np.int64)
    mask = np.ones(n, np.int32)
    p = ref.partition_ids(keys, mask, parts)
    counts = np.bincount(p, minlength=parts)
    assert counts.min() > (n // parts) * 0.8
    assert counts.max() < (n // parts) * 1.2


# ------------------------------------------------------------------ agg --

@settings(deadline=None, max_examples=15)
@given(_shapes(), st.sampled_from([16, 64, 256]), st.integers(0, 2**32 - 1))
def test_preagg_sum_count(shape, g, seed):
    n, block = shape
    rng = np.random.default_rng(seed)
    buckets = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(0, 10, n).astype(np.float32)
    mask = rng.integers(0, 2, n).astype(np.int32)
    s, c = agg.preagg_sum_count(buckets, vals, mask, g=g, n=n, block=block)
    rs, rc = ref.preagg_sum_count(buckets, vals, mask, g)
    np.testing.assert_allclose(np.asarray(s), rs, rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(c), rc)


@settings(deadline=None, max_examples=15)
@given(_shapes(), st.sampled_from([16, 256]), st.integers(0, 2**32 - 1))
def test_preagg_min_max(shape, g, seed):
    n, block = shape
    rng = np.random.default_rng(seed)
    buckets = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(0, 10, n).astype(np.float32)
    mask = rng.integers(0, 2, n).astype(np.int32)
    mn, mx = agg.preagg_min_max(buckets, vals, mask, g=g, n=n, block=block)
    rmn, rmx = ref.preagg_min_max(buckets, vals, mask, g)
    np.testing.assert_allclose(np.asarray(mn), rmn, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(mx), rmx, rtol=1e-6)


def test_preagg_all_masked_out():
    n, block, g = 64, 16, 16
    buckets = RNG.integers(0, g, n).astype(np.int32)
    vals = RNG.normal(size=n).astype(np.float32)
    zeros = np.zeros(n, np.int32)
    s, c = agg.preagg_sum_count(buckets, vals, zeros, g=g, n=n, block=block)
    assert np.asarray(s).sum() == 0.0 and np.asarray(c).sum() == 0


def test_preagg_single_bucket_accumulates_across_blocks():
    n, block, g = 256, 32, 16
    buckets = np.full(n, 3, np.int32)
    vals = np.ones(n, np.float32)
    mask = np.ones(n, np.int32)
    s, c = agg.preagg_sum_count(buckets, vals, mask, g=g, n=n, block=block)
    assert np.asarray(s)[3] == n and np.asarray(c)[3] == n


# ---------------------------------------------------------------- bloom --

@settings(deadline=None, max_examples=10)
@given(_shapes(), st.sampled_from([1024, 4096]), st.integers(0, 2**32 - 1))
def test_bloom_build_probe_roundtrip(shape, bits, seed):
    n, block = shape
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 10**6, n).astype(np.int64)
    mask = rng.integers(0, 2, n).astype(np.int32)
    cells = np.asarray(bloom.bloom_build(keys, mask, bits=bits, n=n,
                                         block=block))
    np.testing.assert_array_equal(cells, ref.bloom_build(keys, mask, bits))
    got = np.asarray(bloom.bloom_probe(keys, mask, cells, bits=bits, n=n,
                                       block=block))
    np.testing.assert_array_equal(got, ref.bloom_probe(keys, mask, cells))
    # No false negatives: every masked build key must probe positive.
    np.testing.assert_array_equal(got & mask, mask & got)
    assert np.all(got[mask != 0] == 1)


def test_bloom_rejects_disjoint_keys_mostly():
    n, block, bits = 1024, 256, BLOOM_BITS
    build_keys = np.arange(n, dtype=np.int64)
    probe_keys = np.arange(10**9, 10**9 + n, dtype=np.int64)
    ones = np.ones(n, np.int32)
    cells = np.asarray(bloom.bloom_build(build_keys, ones, bits=bits, n=n,
                                         block=block))
    got = np.asarray(bloom.bloom_probe(probe_keys, ones, cells, bits=bits,
                                       n=n, block=block))
    # ~ (n/bits)^2 double-hash FP rate — should reject the vast majority.
    assert got.mean() < 0.05
