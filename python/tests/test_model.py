"""L2 stage-graph correctness: full-shape stages vs oracle + lowering
round-trips (shape/dtype of every artifact input/output)."""

import numpy as np
import pytest

from compile import model
from compile.kernels import (BATCH_ROWS, BLOOM_BITS, NUM_BUCKETS, NUM_PARTS,
                             ref)

RNG = np.random.default_rng(11)
N = BATCH_ROWS


def _batch():
    col = RNG.normal(0, 100, N).astype(np.float32)
    keys = RNG.integers(0, 10**7, N).astype(np.int64)
    mask = np.ones(N, np.int32)
    mask[N - 100:] = 0  # padded tail
    return col, keys, mask


def test_filter_range_f32_full_shape():
    col, _, mask = _batch()
    (got,) = model.filter_range_f32(col, np.array([np.float32(-50)]),
                                    np.array([np.float32(50)]), mask)
    np.testing.assert_array_equal(
        np.asarray(got), ref.range_mask(col, np.float32(-50), np.float32(50),
                                        mask))


def test_hash_partition_histogram_consistent():
    _, keys, mask = _batch()
    part, hist = model.hash_partition(keys, mask)
    part, hist = np.asarray(part), np.asarray(hist)
    expect = ref.partition_ids(keys, mask, NUM_PARTS)
    np.testing.assert_array_equal(part, expect)
    # Histogram counts only masked rows, and matches the ids.
    assert hist.sum() == mask.sum()
    counts = np.bincount(part[mask != 0], minlength=NUM_PARTS)
    np.testing.assert_array_equal(hist, counts)


def test_bucket_preagg_full_shape():
    col, keys, mask = _batch()
    b, s, c, mn, mx = model.bucket_preagg(keys, col, mask)
    b = np.asarray(b)
    np.testing.assert_array_equal(b, ref.bucket_ids(keys, mask, NUM_BUCKETS))
    rs, rc = ref.preagg_sum_count(b, col, mask, NUM_BUCKETS)
    np.testing.assert_allclose(np.asarray(s), rs, rtol=1e-4, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(c), rc)
    assert np.asarray(c).sum() == mask.sum()


def test_bloom_stage_pushdown_semantics():
    _, keys, mask = _batch()
    (cells,) = model.bloom_build(keys, mask)
    (got,) = model.bloom_probe(keys, mask, np.asarray(cells))
    # every masked build key must survive its own filter
    assert np.all(np.asarray(got)[mask != 0] == 1)


def test_fused_equals_unfused():
    col, keys, mask = _batch()
    lo = np.array([np.float32(-10)])
    hi = np.array([np.float32(10)])
    m_f, part_f, hist_f = model.filter_hash_fused(col, lo, hi, keys, mask)
    (m_u,) = model.filter_range_f32(col, lo, hi, mask)
    part_u, hist_u = model.hash_partition(keys, np.asarray(m_u))
    np.testing.assert_array_equal(np.asarray(m_f), np.asarray(m_u))
    np.testing.assert_array_equal(np.asarray(part_f), np.asarray(part_u))
    np.testing.assert_array_equal(np.asarray(hist_f), np.asarray(hist_u))


@pytest.mark.parametrize("name", list(model.STAGES))
def test_stage_eval_shapes(name):
    """Every STAGES entry must evaluate shape-consistently (what the
    manifest promises the Rust runtime)."""
    import jax
    fn, ex = model.STAGES[name]
    outs = jax.eval_shape(fn, *ex)
    for o in outs:
        assert all(d > 0 for d in o.shape)
