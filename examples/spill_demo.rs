//! Spilling demonstration (§4.2: "we demonstrate spilling by processing
//! SF=100k (100TB) on two nodes"): run a dataset that is several times
//! larger than the configured device memory, watch the Data-Movement
//! Executor demote Batch-Holder contents across device → host → disk,
//! and verify the query still completes with exactly correct results.
//!
//! ```sh
//! cargo run --release --example spill_demo
//! ```

use std::sync::Arc;

use theseus::cluster::{Cluster, Gateway};
use theseus::config::WorkerConfig;
use theseus::exec::plan::{AggFn, AggSpec};
use theseus::planner::Logical;
use theseus::runtime::KernelRegistry;
use theseus::sim::SimContext;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::util::human_bytes;
use theseus::workload::{CpuEngine, TpchGen};

fn main() -> theseus::Result<()> {
    let sf = 0.01; // ~14 MiB of lineitem payload
    let device_capacity = 384 << 10; // 384 KiB "GPU": ~3 batches fit

    let cfg = WorkerConfig {
        num_workers: 2,
        device_capacity,
        spill_watermark: 0.5,
        spill_codec: theseus::storage::Codec::Zstd { level: 1 },
        ..WorkerConfig::default()
    };
    let sim = SimContext::new(cfg.profile.clone(), cfg.time_scale);
    let store: Arc<dyn ObjectStore> = SimObjectStore::in_memory(&sim);
    let gen = TpchGen::new(sf);
    let bytes = gen.write_all(&store)?;
    println!(
        "dataset: {} ({} lineitem rows); device memory: {} per worker",
        human_bytes(bytes as usize),
        gen.lineitem_rows(),
        human_bytes(device_capacity)
    );

    let cluster = Cluster::launch(cfg, store.clone(), KernelRegistry::shared().ok())?;
    let gw = Gateway::new(cluster);

    // a shuffle-heavy aggregation: all of lineitem crosses the exchange
    let q = Logical::scan("lineitem", &["l_orderkey", "l_quantity"])
        .aggregate("l_orderkey", vec![AggSpec::new(AggFn::Sum, "l_quantity")])
        .sort("sum_l_quantity", true)
        .limit(10);

    let r = gw.submit(&q)?;
    println!("\ncompleted in {:?}", r.elapsed);
    for s in &r.worker_stats {
        println!(
            "worker {}: {} spill demotions ({} freed), peak device {} / {}",
            s.worker_id,
            s.spills,
            human_bytes(s.spilled_bytes as usize),
            human_bytes(s.device_peak_bytes),
            human_bytes(device_capacity),
        );
    }
    let total_spills: u64 = r.worker_stats.iter().map(|s| s.spills).sum();
    assert!(total_spills > 0, "expected spilling with a {device_capacity}-byte device");

    // correctness under memory pressure: compare against the baseline
    let b = CpuEngine::new(store).run(&q)?;
    let top_t = r.batch.column("sum_l_quantity")?.data.as_f64()?;
    let top_b = b.batch.column("sum_l_quantity")?.data.as_f64()?;
    assert_eq!(r.batch.rows(), b.batch.rows());
    for (x, y) in top_t.iter().zip(top_b) {
        assert!((x - y).abs() < 1e-6, "spilled result diverged: {x} vs {y}");
    }
    println!("\ntop-10 sums identical to the in-memory CPU baseline: OK");
    println!("spilling demonstrated: {} demotions across the cluster", total_spills);
    Ok(())
}
