//! Configuration sweep: the Figure-4 ablations in miniature. Runs a
//! join-heavy query under the on-prem presets A→E and the cloud presets
//! F→I, printing runtime plus the mechanism-level counters that explain
//! each step (wire bytes, compression CPU time, store requests,
//! pre-load hits).
//!
//! ```sh
//! cargo run --release --example config_sweep [sf]
//! ```
//!
//! The shaped simulation (`time_scale`) is enabled so the modeled
//! fabric/storage speeds — not the host CPU — dominate, as in the
//! paper's testbeds. The full bench (`cargo bench --bench fig4_configs`)
//! runs the whole suite; this example is the quick visual.

use std::sync::Arc;

use theseus::cluster::{Cluster, Gateway};
use theseus::config::WorkerConfig;
use theseus::runtime::KernelRegistry;
use theseus::sim::SimContext;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::util::human_bytes;
use theseus::workload::{tpch_suite, TpchGen};

fn main() -> theseus::Result<()> {
    let sf: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.005);
    let registry = KernelRegistry::shared().ok();
    let q = tpch_suite().into_iter().find(|q| q.id == "q3").unwrap();

    println!("== Fig-4-style sweep: {} at sf={sf}, 4 workers ==\n", q.id);
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "preset", "time", "wire", "compress", "store-req", "preloads"
    );
    for preset in ['A', 'B', 'C', 'D', 'E', 'F', 'G', 'H', 'I'] {
        let mut cfg = WorkerConfig::preset(preset)?;
        cfg.num_workers = 4;
        cfg.time_scale = 0.02; // compress modeled hours into seconds
        let sim = SimContext::new(cfg.profile.clone(), cfg.time_scale);
        let store_impl = SimObjectStore::in_memory(&sim);
        let store: Arc<dyn ObjectStore> = store_impl.clone();
        TpchGen::new(sf).write_all(&store)?;

        let cluster = Cluster::launch(cfg, store, registry.clone())?;
        let gw = Gateway::new(cluster);
        let r = gw.submit(&q.logical())?;
        let compress: std::time::Duration =
            r.worker_stats.iter().map(|s| s.compress_time).sum();
        let preloads: u64 = r
            .worker_stats
            .iter()
            .map(|s| s.preload_byte_ranges + s.preload_promotions)
            .sum();
        println!(
            "{:<8} {:>12?} {:>12} {:>12?} {:>10} {:>10}",
            preset,
            r.elapsed,
            human_bytes(r.total_wire_bytes() as usize),
            compress,
            store_impl.request_count(),
            preloads,
        );
    }
    println!("\n(A–E are on-prem network ablations; F–I are cloud storage ablations.)");
    Ok(())
}
