//! End-to-end driver (the repo's required full-system validation):
//! run the complete TPC-H-derived suite on a real generated dataset
//! through all three layers — Rust coordinator (4 executors, adaptive
//! exchange, spilling), AOT JAX/Pallas kernels via PJRT, simulated
//! cloud fabric — then run the same queries on the Photon-like CPU
//! baseline, verify the results agree bit-for-bit, and report the
//! cost-normalized comparison (the paper's Fig-6 headline metric).
//!
//! ```sh
//! make artifacts && cargo run --release --example tpch_e2e [sf] [workers]
//! ```
//!
//! Results of a reference run are recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;
use std::time::Duration;

use theseus::cluster::{Cluster, Gateway};
use theseus::config::WorkerConfig;
use theseus::runtime::KernelRegistry;
use theseus::sim::cost::{CostModel, G6_4XLARGE, R7GD_12XLARGE};
use theseus::sim::SimContext;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::types::ColumnData;
use theseus::util::human_bytes;
use theseus::workload::{tpch_suite, CpuEngine, TpchGen};

fn main() -> theseus::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sf: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(0.01);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    // ---------------- data
    // Cloud profile with scaled modeled time: both engines pay the same
    // shaped object-store (S3-like latency/bandwidth); Theseus overlaps
    // it across executors and connections, the baseline cannot — the
    // contrast the paper's evaluation isolates.
    let cfg = WorkerConfig {
        num_workers: workers,
        compute_threads: 2,
        device_capacity: 96 << 20,
        profile: theseus::sim::HwProfile::cloud(),
        time_scale: 0.1,
        ..WorkerConfig::default()
    };
    let sim = SimContext::new(cfg.profile.clone(), cfg.time_scale);
    let store: Arc<dyn ObjectStore> = SimObjectStore::in_memory(&sim);
    let gen = TpchGen::new(sf);
    let bytes = gen.write_all(&store)?;
    println!(
        "== TPC-H e2e: sf={sf} ({} lineitem rows, {} on store), {workers} workers ==",
        gen.lineitem_rows(),
        human_bytes(bytes as usize)
    );

    // ---------------- engines
    let registry = KernelRegistry::shared().ok();
    println!(
        "AOT kernels: {}",
        if registry.is_some() { "loaded (PJRT CPU)" } else { "UNAVAILABLE (host fallback)" }
    );
    let cluster = Cluster::launch(cfg, store.clone(), registry)?;
    let gw = Gateway::new(cluster);
    let baseline = CpuEngine::new(store);

    // ---------------- run
    println!(
        "\n{:<6} {:>7} {:>14} {:>14} {:>7} {:>7} {:>10} {:>9}",
        "query", "rows", "theseus", "baseline", "match", "spills", "wire", "speedup"
    );
    let mut t_total = Duration::ZERO;
    let mut b_total = Duration::ZERO;
    let mut all_match = true;
    for q in tpch_suite() {
        let r = gw.submit(&q.logical())?;
        let b = baseline.run(&q.logical())?;
        let ok = batches_equal(&r.batch, &b.batch);
        all_match &= ok;
        t_total += r.elapsed;
        b_total += b.elapsed;
        println!(
            "{:<6} {:>7} {:>14?} {:>14?} {:>7} {:>7} {:>10} {:>8.2}x",
            q.id,
            r.batch.rows(),
            r.elapsed,
            b.elapsed,
            if ok { "yes" } else { "NO" },
            r.total_spills(),
            human_bytes(r.total_wire_bytes() as usize),
            b.elapsed.as_secs_f64() / r.elapsed.as_secs_f64().max(1e-9),
        );
    }

    // ---------------- headline
    println!("\nsuite totals: theseus {t_total:?} vs baseline {b_total:?}");
    let speedup = b_total.as_secs_f64() / t_total.as_secs_f64().max(1e-9);
    println!("wall-clock speedup: {speedup:.2}x");
    // cost parity per the paper's Table-1 cluster pairing (8 GPU nodes
    // vs 3 CPU nodes at near-equal $/h)
    let t_cost = CostModel::new(G6_4XLARGE, 8);
    let b_cost = CostModel::new(R7GD_12XLARGE, 3);
    let parity = t_cost.speedup_at_cost_parity(
        t_total.as_secs_f64(),
        &b_cost,
        b_total.as_secs_f64(),
    );
    println!(
        "speedup at cost parity ({} vs {}): {parity:.2}x",
        t_cost.usd_per_hour(),
        b_cost.usd_per_hour()
    );
    println!(
        "\nresult correctness vs baseline: {}",
        if all_match { "ALL MATCH" } else { "MISMATCH (bug!)" }
    );
    if !all_match {
        std::process::exit(1);
    }
    Ok(())
}

/// Compare engines' outputs.
///
/// Per-column *multiset* comparison: both engines sort rows by the same
/// key, but ties may be ordered differently across engines (the
/// distributed gather concatenates worker outputs in arbitrary order),
/// so each column is compared as a sorted value set. f64 tolerance
/// covers the device path's f32 partial sums (error ~ n·eps_f32
/// relative, well under 2e-3 at these batch sizes).
fn batches_equal(a: &theseus::types::RecordBatch, b: &theseus::types::RecordBatch) -> bool {
    if a.rows() != b.rows() || a.num_columns() != b.num_columns() {
        return false;
    }
    for (ca, cb) in a.columns.iter().zip(&b.columns) {
        if ca.name != cb.name {
            return false;
        }
        match (&ca.data, &cb.data) {
            (ColumnData::I64(x), ColumnData::I64(y)) => {
                let mut x = x.clone();
                let mut y = y.clone();
                x.sort_unstable();
                y.sort_unstable();
                if x != y {
                    return false;
                }
            }
            (ColumnData::F64(x), ColumnData::F64(y)) => {
                let mut x = x.clone();
                let mut y = y.clone();
                x.sort_by(|p, q| p.partial_cmp(q).unwrap());
                y.sort_by(|p, q| p.partial_cmp(q).unwrap());
                for (u, v) in x.iter().zip(&y) {
                    if (u - v).abs() > 2e-3 * v.abs().max(1.0) {
                        return false;
                    }
                }
            }
            (ColumnData::F32(x), ColumnData::F32(y)) => {
                let mut x = x.clone();
                let mut y = y.clone();
                x.sort_by(|p, q| p.partial_cmp(q).unwrap());
                y.sort_by(|p, q| p.partial_cmp(q).unwrap());
                for (u, v) in x.iter().zip(&y) {
                    if (u - v).abs() > 1e-2 * v.abs().max(1.0) {
                        return false;
                    }
                }
            }
            _ => return false,
        }
    }
    true
}
