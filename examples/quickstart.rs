//! Quickstart: generate a tiny TPC-H dataset, launch a 2-worker
//! cluster, and run one query through the full three-layer stack.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use theseus::cluster::client::connect;
use theseus::config::WorkerConfig;
use theseus::exec::plan::{AggFn, AggSpec, Pred};
use theseus::planner::Logical;
use theseus::runtime::KernelRegistry;
use theseus::sim::SimContext;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::workload::TpchGen;

fn main() -> theseus::Result<()> {
    // 1. a place for data: the in-memory object store, shaped like S3
    let cfg = WorkerConfig { num_workers: 2, ..WorkerConfig::default() };
    let sim = SimContext::new(cfg.profile.clone(), cfg.time_scale);
    let store: Arc<dyn ObjectStore> = SimObjectStore::in_memory(&sim);

    // 2. data: TPC-H at a small scale factor (6k lineitem rows)
    let bytes = TpchGen::new(0.001).write_all(&store)?;
    println!("generated TPC-H sf=0.001 ({bytes} bytes of THS files)");

    // 3. the engine: 2 workers, AOT kernels if artifacts are built
    let registry = KernelRegistry::shared().ok();
    if registry.is_none() {
        println!("note: no artifacts found, using host fallbacks (run `make artifacts`)");
    }
    let client = connect(cfg, store, registry)?;

    // 4. a query: revenue by return flag for early ship dates
    let q = Logical::scan("lineitem", &["l_returnflag", "l_extendedprice", "l_shipdate"])
        .filter(Pred::RangeI64 { col: "l_shipdate".into(), lo: 8036, hi: 9500 })
        .aggregate(
            "l_returnflag",
            vec![
                AggSpec::new(AggFn::Sum, "l_extendedprice"),
                AggSpec::new(AggFn::Count, "l_extendedprice"),
            ],
        )
        .sort("l_returnflag", false);

    let r = client.query(&q)?;
    println!("\nresult ({} rows in {:?}):", r.batch.rows(), r.elapsed);
    println!("flag\tsum(price)\tcount");
    for i in 0..r.batch.rows() {
        let flag = r.batch.column("l_returnflag")?.data.as_i64()?[i];
        let sum = r.batch.column("sum_l_extendedprice")?.data.as_f64()?[i];
        let cnt = r.batch.column("count_l_extendedprice")?.data.as_f64()?[i];
        println!("{flag}\t{sum:.2}\t{cnt}");
    }
    for s in &r.worker_stats {
        println!(
            "worker {}: {} tasks, {} spills, {} wire bytes",
            s.worker_id, s.tasks_executed, s.spills, s.net_bytes_wire
        );
    }
    Ok(())
}
