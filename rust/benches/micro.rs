//! Micro-benchmarks and ablations:
//!
//! 1. **LIP** (§5): Lookahead Information Passing on/off over the
//!    join-heavy queries — the paper reports ~50% runtime cuts on some
//!    queries; we report runtime delta + probe rows eliminated.
//! 2. **Negative result: UVM-style paging vs Batch-Holder spilling**
//!    (§5: "an attempt to rely on Unified Virtual Memory and driver
//!    paging ... was an order of magnitude slower"): modeled
//!    fault-per-page driver paging vs explicit batch demotion.
//! 3. **Negative result: dynamic pinned allocation vs the fixed pool**
//!    (§5/§3.4: dynamic page-locked allocation "was slow and led to
//!    memory fragmentation"): allocate+mlock per use vs pool reuse.
//! 4. **Network compression ratio/CPU trade** (§3.3.5 context for the
//!    Fig-4 B/E flip).
//! 5. **Spill-reload concurrency**: the Data-Movement plane's
//!    positional-I/O `SpillStore` vs the seed's single
//!    `Mutex<File>` + seek design, under concurrent demotions and
//!    promotions.
//! 6. **Zero-copy pinned bounce path** (§3.4): host-side memcpy'd
//!    bytes and throughput on the exchange-send and spill paths,
//!    slab-backed vs the seed's `Vec<u8>`-bounce baseline.
//! 7. **Shuffle coalescing** (§3.4/§4.1): the fragmented seed shuffle
//!    (per-batch per-destination take + encode + frame) vs the
//!    destination-coalesced single-pass-scatter path, at 4–64 workers:
//!    frames emitted, bytes on the wire, wall time.
//! 8. **Serving cache** (PR 7): the gateway's two-level result/fragment
//!    cache over the repeat-heavy serving mix — cold vs warm-exact vs
//!    fragment-hit latency and cluster tasks executed. Asserts a warm
//!    exact hit runs zero cluster tasks and a fragment-hit drilldown
//!    runs strictly fewer than its cold run.
//! 9. **Gateway concurrency** (PR 8): sustained gateway QPS at 1/4/16
//!    concurrent sessions, cold (every query executes, gated by
//!    admission control) vs warm (every query a result-cache hit,
//!    which bypasses admission). Asserts warm bytes are identical to
//!    cold and that only cold submissions consumed admissions.
//!
//! Run: `cargo bench --bench micro`.

mod common;

use std::time::{Duration, Instant};

use common::{gateway, secs, tpch_store};
use theseus::cluster::QueryResult;
use theseus::config::WorkerConfig;
use theseus::memory::{PinnedPool, PinnedSlab, SlabSlice, SpillStore};
use theseus::sim::{HwProfile, LinkSpec, SimContext, GIB};
use theseus::storage::compression::Codec;
use theseus::workload::{serving_mix, tpch_suite};

fn main() {
    // MICRO_BENCHES=5,6,7 runs a subset (CI's bench-runner step uses
    // this to run the movement benches at sim scale); unset runs all.
    let only: Option<Vec<usize>> = std::env::var("MICRO_BENCHES")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect());
    let run = |i: usize| only.as_ref().map_or(true, |v| v.contains(&i));
    if run(1) {
        lip_ablation();
    }
    if run(2) {
        uvm_vs_batch_holder();
    }
    if run(3) {
        dynamic_vs_pooled_pinned();
    }
    if run(4) {
        compression_trade();
    }
    if run(5) {
        spill_store_concurrency();
    }
    if run(6) {
        zero_copy_bounce();
    }
    if run(7) {
        shuffle_coalescing();
    }
    if run(8) {
        serving_cache();
    }
    if run(9) {
        gateway_concurrency();
    }
}

// ------------------------------------------------------------------ 1
fn lip_ablation() {
    println!("== LIP ablation (§5): join-heavy queries, bloom pushdown on/off ==");
    println!(
        "{:<6} {:>12} {:>12} {:>8} {:>14} {:>14} {:>9}",
        "query", "lip off", "lip on", "delta", "wire off", "wire on", "wire cut"
    );
    let sf = std::env::var("LIP_SF")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.03);
    for qid in ["q3", "q14", "q19"] {
        let mut results = Vec::new();
        for lip in [false, true] {
            let cfg = WorkerConfig {
                num_workers: 4,
                profile: HwProfile::on_prem(),
                time_scale: 0.1,
                ..WorkerConfig::default()
            };
            let store = tpch_store(&cfg, sf);
            let mut gw = gateway(cfg, store);
            gw.planner.lip_enabled = lip;
            let q = tpch_suite().into_iter().find(|q| q.id == qid).unwrap();
            let r = gw.submit(&q.logical()).unwrap();
            results.push(r);
        }
        let off = results[0].elapsed;
        let on = results[1].elapsed;
        let woff = results[0].total_wire_bytes();
        let won = results[1].total_wire_bytes();
        println!(
            "{:<6} {:>12} {:>12} {:>7.1}% {:>13}B {:>13}B {:>8.1}%",
            qid,
            secs(off),
            secs(on),
            100.0 * (off.as_secs_f64() - on.as_secs_f64()) / off.as_secs_f64(),
            woff,
            won,
            100.0 * (woff.saturating_sub(won)) as f64 / woff.max(1) as f64,
        );
    }
    println!(
        "(paper: ~50% improvement on some join-extensive queries. The headline here\n\
         is the movement cut — up to ~96% of probe bytes never cross the exchange.\n\
         Wall-clock can invert on this substrate: bloom probes cost real CPU cycles\n\
         on the 1-core PJRT device, whereas on an A100 they are ~free relative to\n\
         the wire; see DESIGN.md §Hardware-Adaptation. LIP applies in broadcast-\n\
         build joins; partition-mode LIP would need a bloom all-reduce — future work\n\
         as in the paper's full-length version.)\n"
    );
}

// ------------------------------------------------------------------ 2
fn uvm_vs_batch_holder() {
    println!("== negative result (§5): UVM-style driver paging vs Batch-Holder spilling ==");
    // Model: moving B bytes device<->host.
    //  * Batch Holder: one bulk pinned transfer per batch (PCIe at full
    //    bandwidth + one launch latency).
    //  * UVM driver paging: 4 KiB-page faults, each paying fault
    //    latency (~20us: fault + driver + map) at pageable throughput.
    // Both timed in modeled time on the same link spec.
    let ctx = SimContext::new(HwProfile::on_prem(), 0.0);
    let pcie = ctx.throttle(&ctx.profile.pcie);
    let fault = ctx.throttle(&LinkSpec::new(20, 8 * GIB)); // per-fault cost
    let batch_bytes = 8 << 20; // one 8 MiB working set
    let batches = 16;

    let bulk: Duration = (0..batches).map(|_| pcie.model_duration(batch_bytes)).sum();
    let pages = batch_bytes / 4096;
    let paged: Duration = (0..batches)
        .map(|_| {
            (0..pages)
                .map(|_| fault.model_duration(4096))
                .sum::<Duration>()
        })
        .sum();
    println!(
        "move {} x {} MiB: batch-holder bulk {:?} vs driver paging {:?} ({:.1}x slower)",
        batches,
        batch_bytes >> 20,
        bulk,
        paged,
        paged.as_secs_f64() / bulk.as_secs_f64()
    );
    println!("(paper: UVM was an order of magnitude slower)\n");
}

// ------------------------------------------------------------------ 3
fn dynamic_vs_pooled_pinned() {
    println!("== negative result (§5): dynamic pinned allocation vs fixed-size pool ==");
    let buf = 256 << 10;
    let iters = 200;
    let payload = vec![7u8; buf * 3 / 2]; // spans 2 buffers

    // pooled: allocate once, reuse
    let pool = PinnedPool::new(buf, 8).unwrap();
    let t0 = Instant::now();
    for _ in 0..iters {
        let slab = PinnedSlab::write(&pool, &payload).unwrap();
        std::hint::black_box(slab.read());
    }
    let pooled = t0.elapsed();

    // dynamic: fresh allocation + mlock + munlock per use
    let t0 = Instant::now();
    for _ in 0..iters {
        let fresh = PinnedPool::new(buf, 2).unwrap(); // alloc+mlock
        let slab = PinnedSlab::write(&fresh, &payload).unwrap();
        std::hint::black_box(slab.read());
        drop(slab);
        drop(fresh); // munlock+free
    }
    let dynamic = t0.elapsed();
    println!(
        "{iters} x {}-KiB transfers: pooled {:?} vs dynamic alloc {:?} ({:.1}x slower)",
        (payload.len()) >> 10,
        pooled,
        dynamic,
        dynamic.as_secs_f64() / pooled.as_secs_f64()
    );
    println!("(paper: dynamic page-locked allocation was slow and fragmented)\n");
}

// ------------------------------------------------------------------ 4
fn compression_trade() {
    println!("== network compression trade (§3.3.5) ==");
    // representative exchange payload: encoded TPC-H-ish batch
    let mut rng = theseus::util::rng::Rng::new(11);
    let batch = theseus::types::RecordBatch::new(vec![
        theseus::types::Column::i64("k", (0..8192).map(|_| rng.gen_i64(0, 1 << 20)).collect()),
        theseus::types::Column::f32("v", (0..8192).map(|_| rng.gen_f32(0.0, 1e5)).collect()),
        theseus::types::Column::dict("f", (0..8192).map(|_| rng.gen_i64(0, 2)).collect()),
    ])
    .unwrap();
    let encoded = batch.encode();
    println!(
        "{:<10} {:>10} {:>10} {:>12} {:>12}",
        "codec", "bytes", "ratio", "compress", "decompress"
    );
    for codec in [Codec::None, Codec::Lz4Like, Codec::Zstd { level: 1 }, Codec::Zstd { level: 6 }] {
        let t0 = Instant::now();
        let mut c = Vec::new();
        for _ in 0..50 {
            c = codec.compress(&encoded);
        }
        let ct = t0.elapsed() / 50;
        let t0 = Instant::now();
        for _ in 0..50 {
            std::hint::black_box(Codec::decompress(&c).unwrap());
        }
        let dt = t0.elapsed() / 50;
        println!(
            "{:<10} {:>10} {:>9.2}x {:>12?} {:>12?}",
            format!("{:?}", codec.name()),
            c.len(),
            encoded.len() as f64 / c.len() as f64,
            ct,
            dt
        );
    }
    println!("(compression buys wire bytes with CPU time: worth it on slow fabrics — Fig-4 B —\n and a net loss once RDMA raises wire bandwidth — Fig-4 E)\n");
}

// ------------------------------------------------------------------ 5

/// The seed's spill tier: one file behind a mutex, every access a
/// seek + read/write pair under the lock. Kept here as the baseline the
/// Data-Movement plane's `SpillStore` is measured against.
struct MutexFileStore {
    file: std::sync::Mutex<std::fs::File>,
    path: std::path::PathBuf,
    write_off: std::sync::atomic::AtomicU64,
}

impl MutexFileStore {
    fn new(tag: &str) -> Self {
        let path = std::env::temp_dir().join(format!(
            "theseus-bench-mutexspill-{tag}-{}",
            std::process::id()
        ));
        let file = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .truncate(true)
            .open(&path)
            .unwrap();
        MutexFileStore {
            file: std::sync::Mutex::new(file),
            path,
            write_off: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn write(&self, data: &[u8]) -> (u64, u64) {
        use std::io::{Seek, SeekFrom, Write};
        let mut f = self.file.lock().unwrap();
        let off = self
            .write_off
            .fetch_add(data.len() as u64, std::sync::atomic::Ordering::AcqRel);
        f.seek(SeekFrom::Start(off)).unwrap();
        f.write_all(data).unwrap();
        (off, data.len() as u64)
    }

    fn read(&self, off: u64, len: u64) -> Vec<u8> {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = self.file.lock().unwrap();
        f.seek(SeekFrom::Start(off)).unwrap();
        let mut buf = vec![0u8; len as usize];
        f.read_exact(&mut buf).unwrap();
        buf
    }
}

impl Drop for MutexFileStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

fn spill_store_concurrency() {
    println!("== spill-reload concurrency: positional segmented store vs Mutex<File> ==");
    const PAYLOAD: usize = 64 << 10;
    const OPS_PER_THREAD: usize = 200; // each op = 1 write + 1 read-back
    let payload = vec![0xabu8; PAYLOAD];

    let run_mutex = |threads: usize| -> Duration {
        let store = std::sync::Arc::new(MutexFileStore::new(&format!("t{threads}")));
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..threads {
                let store = store.clone();
                let payload = &payload;
                s.spawn(move || {
                    for _ in 0..OPS_PER_THREAD {
                        let (off, len) = store.write(payload);
                        std::hint::black_box(store.read(off, len));
                    }
                });
            }
        });
        t0.elapsed()
    };

    let run_positional = |threads: usize| -> Duration {
        let store = std::sync::Arc::new(
            SpillStore::temp_with(&format!("bench{threads}"), 64 << 20).unwrap(),
        );
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..threads {
                let store = store.clone();
                let payload = &payload;
                s.spawn(move || {
                    for _ in 0..OPS_PER_THREAD {
                        let slot = store.write(payload).unwrap();
                        std::hint::black_box(store.read(slot).unwrap());
                        store.free(slot);
                    }
                });
            }
        });
        t0.elapsed()
    };

    println!(
        "{:<12} {:>12} {:>12} {:>10}",
        "threads", "mutex-file", "positional", "speedup"
    );
    let mut scaling = (1.0f64, 1.0f64); // (mutex, positional) 1->8 thread slowdown
    for threads in [1usize, 4, 8] {
        let m = run_mutex(threads);
        let p = run_positional(threads);
        if threads == 1 {
            scaling = (m.as_secs_f64(), p.as_secs_f64());
        } else if threads == 8 {
            scaling = (
                m.as_secs_f64() / scaling.0.max(1e-9),
                p.as_secs_f64() / scaling.1.max(1e-9),
            );
        }
        println!(
            "{:<12} {:>12?} {:>12?} {:>9.2}x",
            threads,
            m,
            p,
            m.as_secs_f64() / p.as_secs_f64().max(1e-9)
        );
    }
    println!(
        "(8-thread/1-thread wall-clock growth: mutex-file {:.2}x vs positional {:.2}x —\n \
         concurrent demotions/promotions no longer serialize on one file cursor)",
        scaling.0, scaling.1
    );
    // every spill op above ran through fault::check gates; with no plan
    // installed the disabled fast path must stay invisible — zero
    // firings, zero extra I/O in the timed loops
    assert_eq!(
        theseus::fault::injected_total(),
        0,
        "disabled fault injector must not fire in benches"
    );
}

// ------------------------------------------------------------------ 6

fn zero_copy_bounce() {
    use std::io::Write;
    println!("== zero-copy pinned bounce (§3.4): slab path vs Vec-bounce baseline ==");
    const PAYLOAD: usize = 256 << 10;
    const ITERS: usize = 400;
    let payload = vec![0x5au8; PAYLOAD];
    let pool = PinnedPool::new(64 << 10, 32).unwrap();

    // ---- exchange-send leg.
    // Baseline (seed): encoded Vec -> Codec::None.compress (copy 1)
    // -> Frame::encode reassembly (copy 2) -> write.
    // Slab path: holder slab (already resident) -> 9-byte prelude +
    // vectored chunks -> write. Zero host memcpy on the send hop.
    let mut sink = std::io::sink();
    let t0 = Instant::now();
    let mut base_copied = 0u64;
    for _ in 0..ITERS {
        let framed = Codec::None.compress(&payload); // copy 1
        let mut wire = Vec::with_capacity(framed.len() + 21);
        wire.extend_from_slice(&[0u8; 21]); // header stand-in
        wire.extend_from_slice(&framed); // copy 2 (the old encode())
        base_copied += 2 * PAYLOAD as u64;
        sink.write_all(&wire).unwrap();
        std::hint::black_box(&wire);
    }
    let base_send = t0.elapsed();

    let slab = PinnedSlab::write(&pool, &payload).unwrap();
    let body = SlabSlice::whole(slab);
    let staged_before = pool.bounce_bytes();
    let t0 = Instant::now();
    for _ in 0..ITERS {
        let prelude = Codec::None.prelude(body.len());
        sink.write_all(&prelude).unwrap();
        for c in body.chunks() {
            sink.write_all(c).unwrap(); // vectored stand-in: no reassembly
        }
    }
    let slab_send = t0.elapsed();
    let slab_copied = pool.bounce_bytes() - staged_before; // 0
    println!(
        "exchange-send {ITERS} x {} KiB: baseline {:?} ({} MiB memcpy) vs slab {:?} ({} MiB memcpy) — {:.1}x",
        PAYLOAD >> 10,
        base_send,
        base_copied >> 20,
        slab_send,
        slab_copied >> 20,
        base_send.as_secs_f64() / slab_send.as_secs_f64().max(1e-9),
    );
    drop(body);

    // ---- spill leg.
    // Baseline: slab.read() (copy 1) -> compress None (copy 2) ->
    // spill.write; reload: spill.read -> decompress (copy 3) ->
    // PinnedSlab::write (copy 4).
    // Direct: write_vectored from the slab (0 copies) and reload
    // read_into_slab (1 staging copy, counted by the pool).
    let store = SpillStore::temp("bounce-base").unwrap();
    let slab = PinnedSlab::write(&pool, &payload).unwrap();
    let t0 = Instant::now();
    let mut base_copied = 0u64;
    for _ in 0..ITERS {
        let bytes = slab.read(); // copy 1 (the seed's demotion)
        let framed = Codec::None.compress(&bytes); // copy 2
        let slot = store.write(&framed).unwrap();
        let raw = store.read(slot).unwrap();
        let back = Codec::decompress(&raw).unwrap(); // copy 3
        let reloaded = PinnedSlab::write(&pool, &back).unwrap(); // copy 4
        base_copied += 4 * PAYLOAD as u64;
        std::hint::black_box(reloaded.len());
        store.free(slot);
    }
    let base_spill = t0.elapsed();

    let store = SpillStore::temp("bounce-direct").unwrap();
    let staged_before = pool.bounce_bytes();
    let t0 = Instant::now();
    for _ in 0..ITERS {
        let prelude = Codec::None.prelude(slab.len());
        let chunks = slab.chunk_slices();
        let mut parts: Vec<&[u8]> = Vec::with_capacity(1 + chunks.len());
        parts.push(&prelude);
        parts.extend_from_slice(&chunks);
        let slot = store.write_vectored(&parts).unwrap(); // 0 copies
        let reloaded = store.read_into_slab(slot, 9, &pool).unwrap(); // 1 staging copy
        std::hint::black_box(reloaded.len());
        store.free(slot);
    }
    let direct_spill = t0.elapsed();
    let direct_copied = pool.bounce_bytes() - staged_before;
    println!(
        "spill+reload   {ITERS} x {} KiB: baseline {:?} ({} MiB memcpy) vs direct {:?} ({} MiB memcpy) — {:.1}x",
        PAYLOAD >> 10,
        base_spill,
        base_copied >> 20,
        direct_spill,
        direct_copied >> 20,
        base_spill.as_secs_f64() / direct_spill.as_secs_f64().max(1e-9),
    );
    println!(
        "(copies eliminated per round trip: exchange 2 -> 0, spill 4 -> 1 — the remaining\n \
         copy is the reload landing in page-locked memory, which is the point of §3.4)"
    );
}

// ------------------------------------------------------------------ 7
fn shuffle_coalescing() {
    use theseus::exec::operators::{kernels, ShuffleCoalescer};
    use theseus::exec::WorkerCtx;
    use theseus::executors::network::{stage_encoded, Outbox};
    use theseus::metrics::Metrics;
    use theseus::types::{Column, RecordBatch};
    use theseus::util::rng::Rng;

    println!("== shuffle coalescing (§3.4/§4.1): fragmented vs destination-coalesced ==");
    const BATCHES: usize = 64;
    const ROWS: usize = 4096;
    // must exceed the largest worker count below, or dsts beyond
    // PARTS-1 never receive rows (dst = partition % workers) and the
    // 64-worker row would silently measure a 16-way fan-out
    const PARTS: u32 = 256;
    const FLUSH: usize = 4 << 20;
    const FRAME_HEADER: usize = 21;

    let ctx = WorkerCtx::test();
    let mut rng = Rng::new(0xBE7C4);
    let batches: Vec<RecordBatch> = (0..BATCHES)
        .map(|_| {
            RecordBatch::new(vec![
                Column::i64("k", (0..ROWS).map(|_| rng.gen_i64(0, 1 << 30)).collect()),
                Column::f32("v", (0..ROWS).map(|_| rng.gen_f32(0.0, 1e5)).collect()),
            ])
            .unwrap()
        })
        .collect();
    let total_bytes: usize = batches.iter().map(|b| b.byte_size()).sum();
    println!(
        "input: {BATCHES} batches x {ROWS} rows ({} MiB); flush threshold {} MiB",
        total_bytes >> 20,
        FLUSH >> 20
    );
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>14} {:>14} {:>12} {:>12} {:>12}",
        "workers", "frag frames", "stat frames", "adpt frames", "frag wire", "coal wire",
        "frag time", "stat time", "adpt time"
    );

    // one coalesced pass: scatter every batch through `co`, staging
    // each flushed sub-batch the way the exchange send path would
    let run_coalesced = |co: &ShuffleCoalescer| -> (u64, u64, Duration) {
        let pool = PinnedPool::new(256 << 10, 64).unwrap();
        let t0 = Instant::now();
        let mut frames = 0u64;
        let mut wire = 0u64;
        {
            let mut send = |batch: &RecordBatch| {
                let staged = stage_encoded(batch, Some(&pool));
                frames += 1;
                wire += (staged.len() + FRAME_HEADER) as u64;
                std::hint::black_box(&staged);
            };
            for b in &batches {
                let keys = b.column("k").unwrap().data.as_i64().unwrap();
                let plan =
                    kernels::partition_scatter(&ctx, keys, PARTS, co.num_dests()).unwrap();
                for (_, flushed) in co.append(b, &plan).unwrap() {
                    send(&flushed);
                }
            }
            for (_, flushed) in co.flush_all() {
                send(&flushed);
            }
        }
        (frames, wire, t0.elapsed())
    };

    let mut json_runs: Vec<String> = Vec::new();
    for workers in [4usize, 16, 64] {
        // ---- fragmented (seed): per-batch per-destination take + encode
        let t0 = Instant::now();
        let mut frag_frames = 0u64;
        let mut frag_wire = 0u64;
        for b in &batches {
            let keys = b.column("k").unwrap().data.as_i64().unwrap();
            let ids = kernels::partition_ids(&ctx, keys, PARTS).unwrap();
            let mut by_dst: Vec<Vec<u32>> = vec![Vec::new(); workers];
            for (row, &p) in ids.iter().enumerate() {
                by_dst[p as usize % workers].push(row as u32);
            }
            for idx in by_dst {
                if idx.is_empty() {
                    continue;
                }
                let sub = b.take(&idx).unwrap();
                let encoded = sub.encode(); // the seed's heap bounce
                frag_frames += 1;
                frag_wire += (encoded.len() + FRAME_HEADER) as u64;
                std::hint::black_box(&encoded);
            }
        }
        let frag_time = t0.elapsed();

        // ---- static coalesced: fixed flush threshold (floor == ceiling
        // pins the controller; this is the pre-adaptive behavior)
        let metrics = std::sync::Arc::new(Metrics::default());
        let co = ShuffleCoalescer::new(workers, FLUSH, None, metrics.clone());
        let (stat_frames, stat_wire, stat_time) = run_coalesced(&co);
        drop(co);

        assert_eq!(metrics.counter_value("exchange.flush_total"), stat_frames);
        assert_eq!(
            metrics.counter_value("exchange.coalesced_bytes"),
            total_bytes as u64
        );
        let bound = (total_bytes.div_ceil(FLUSH) + workers) as u64;
        assert!(
            stat_frames <= bound,
            "{stat_frames} frames exceeds the ceil(total/flush)+workers bound {bound}"
        );

        // ---- adaptive coalesced: the feedback controller watches an
        // (idle) outbox. Uncongested, thresholds must hold at the
        // ceiling — same frame bound, no regression vs static.
        let adpt_metrics = std::sync::Arc::new(Metrics::default());
        let outbox = std::sync::Arc::new(Outbox::new(64));
        let co = ShuffleCoalescer::with_policy(
            workers,
            FLUSH,
            FLUSH / 4,
            FLUSH,
            None,
            Some(outbox),
            None,
            adpt_metrics.clone(),
        );
        let (adpt_frames, adpt_wire, adpt_time) = run_coalesced(&co);
        drop(co);
        assert_eq!(
            adpt_metrics.counter_value("exchange.coalesced_bytes"),
            total_bytes as u64
        );
        assert!(
            adpt_frames <= bound,
            "adaptive uncongested: {adpt_frames} frames exceeds the bound {bound}"
        );
        assert_eq!(adpt_wire, stat_wire, "uncongested adaptive must match static bytes");

        println!(
            "{:>8} {:>12} {:>12} {:>12} {:>13}K {:>13}K {:>12?} {:>12?} {:>12?}",
            workers,
            frag_frames,
            stat_frames,
            adpt_frames,
            frag_wire >> 10,
            stat_wire >> 10,
            frag_time,
            stat_time,
            adpt_time
        );
        for (mode, frames, wire, time) in [
            ("fragmented", frag_frames, frag_wire, frag_time),
            ("static", stat_frames, stat_wire, stat_time),
            ("adaptive", adpt_frames, adpt_wire, adpt_time),
        ] {
            json_runs.push(format!(
                "    {{\"workers\": {workers}, \"mode\": \"{mode}\", \"frames\": {frames}, \
                 \"wire_bytes\": {wire}, \"wall_ns\": {}}}",
                time.as_nanos()
            ));
        }
    }
    println!(
        "(the seed emits batches x workers tiny frames — per-frame header/codec/syscall\n \
         overhead scales with the cluster; coalescing bounds frames by total/flush + one\n \
         tail frame per destination, and every flushed payload encodes straight into the\n \
         pinned pool. Adaptive = feedback controller over an idle outbox: it must hold\n \
         at the ceiling and match static exactly on the uncongested path)\n"
    );

    // CI artifact: BENCH_SHUFFLE_JSON=<path> writes the runs out
    if let Ok(path) = std::env::var("BENCH_SHUFFLE_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"shuffle_coalescing\",\n  \"flush_bytes\": {FLUSH},\n  \
             \"coalesced_bytes\": {total_bytes},\n  \"runs\": [\n{}\n  ]\n}}\n",
            json_runs.join(",\n")
        );
        std::fs::write(&path, json).unwrap();
        println!("wrote {path}");
    }

    // the shuffle's send path crosses the net_send fault gate on every
    // frame; with no plan installed the disabled-injector fast path
    // must add nothing — zero firings across every run above
    assert_eq!(
        theseus::fault::injected_total(),
        0,
        "disabled fault injector must not fire in benches"
    );
}

// ------------------------------------------------------------------ 8
fn serving_cache() {
    println!("== serving cache (PR 7): cold vs warm-exact vs fragment-hit ==");
    let sf = std::env::var("SERVING_SF")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02);
    let cfg = WorkerConfig {
        num_workers: 4,
        profile: HwProfile::on_prem(),
        time_scale: 0.1,
        result_cache_bytes: 64 << 20,
        fragment_cache_bytes: 64 << 20,
        ..WorkerConfig::default()
    };
    let store = tpch_store(&cfg, sf);
    let gw = gateway(cfg, store);
    let tasks = |r: &QueryResult| -> u64 {
        r.worker_stats.iter().map(|s| s.tasks_executed).sum()
    };

    println!(
        "{:<14} {:<18} {:>10} {:>7}",
        "request", "kind", "elapsed", "tasks"
    );
    let mut runs: Vec<(String, &'static str, QueryResult)> = Vec::new();
    let mut json_runs = Vec::new();
    for sq in serving_mix(3) {
        let r = gw.submit(&sq.query).unwrap_or_else(|e| panic!("{}: {e}", sq.id));
        println!(
            "{:<14} {:<18} {:>10} {:>7}",
            sq.id,
            sq.kind,
            secs(r.elapsed),
            tasks(&r)
        );
        json_runs.push(format!(
            "    {{\"id\": \"{}\", \"kind\": \"{}\", \"elapsed_s\": {:.6}, \"tasks\": {}}}",
            sq.id,
            sq.kind,
            r.elapsed.as_secs_f64(),
            tasks(&r)
        ));
        runs.push((sq.id, sq.kind, r));
    }
    let find = |id: &str| &runs.iter().find(|(i, _, _)| i == id).unwrap().2;

    // acceptance: warm exact hit = zero cluster tasks, identical bytes
    let (cold, warm) = (find("revenue@0"), find("revenue@1"));
    assert!(tasks(cold) > 0, "cold dashboard must execute on the cluster");
    assert_eq!(tasks(warm), 0, "warm exact hit must execute zero cluster tasks");
    assert_eq!(
        cold.batch.encode(),
        warm.batch.encode(),
        "cached bytes must be identical to the cold execution"
    );
    // the rewrite variant (conjuncts/cols permuted) is also a pure hit
    assert_eq!(tasks(find("revenue-rw@1")), 0, "rewrite must share the entry");
    // fragment-hit drilldowns execute, but strictly less than cold
    let (dcold, dfrag) = (find("drill0@0"), find("drill0@1"));
    assert!(
        tasks(dfrag) > 0 && tasks(dfrag) < tasks(dcold),
        "fragment-hit drilldown must run strictly fewer tasks ({} vs {})",
        tasks(dfrag),
        tasks(dcold)
    );

    let m = gw.cache.as_ref().unwrap().metrics();
    println!(
        "hits: result {} (miss {}), fragment {} (miss {}), plan-memo {}\n\
         cold {} / warm {} / fragment-hit drill {} (cold drill {})\n",
        m.counter_value("cache.result_hit"),
        m.counter_value("cache.result_miss"),
        m.counter_value("cache.fragment_hit"),
        m.counter_value("cache.fragment_miss"),
        m.counter_value("cache.plan_memo_hit"),
        secs(cold.elapsed),
        secs(warm.elapsed),
        secs(dfrag.elapsed),
        secs(dcold.elapsed),
    );

    // CI artifact: BENCH_SERVING_JSON=<path> writes the runs out
    if let Ok(path) = std::env::var("BENCH_SERVING_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"serving_cache\",\n  \"sf\": {sf},\n  \
             \"result_hits\": {},\n  \"fragment_hits\": {},\n  \"runs\": [\n{}\n  ]\n}}\n",
            m.counter_value("cache.result_hit"),
            m.counter_value("cache.fragment_hit"),
            json_runs.join(",\n")
        );
        std::fs::write(&path, json).unwrap();
        println!("wrote {path}");
    }
}

// ------------------------------------------------------------------ 9
fn gateway_concurrency() {
    use std::collections::HashMap;
    use std::sync::Mutex;

    use theseus::exec::plan::{AggFn, AggSpec, Pred};
    use theseus::planner::Logical;
    use theseus::workload::tpch::{DATE_HI, DATE_LO};

    println!("== gateway concurrency (PR 8): QPS at N sessions, cold vs warm ==");
    const QUERIES_PER_SESSION: usize = 4;
    let sf = std::env::var("GATEWAY_SF")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02);
    // one distinct dashboard panel per (session, slot): same shape,
    // different shipdate window, so cold runs never share cache entries
    let panel = |hi_frac: f64| -> Logical {
        let hi = DATE_LO + ((DATE_HI - DATE_LO) as f64 * hi_frac) as i64;
        Logical::scan("lineitem", &["l_returnflag", "l_extendedprice", "l_shipdate"])
            .filter(Pred::RangeI64 { col: "l_shipdate".into(), lo: DATE_LO, hi })
            .aggregate("l_returnflag", vec![AggSpec::new(AggFn::Sum, "l_extendedprice")])
            .sort("l_returnflag", false)
    };

    println!(
        "{:>9} {:>10} {:>10} {:>10} {:>10} {:>9} {:>8}",
        "sessions", "cold", "cold qps", "warm", "warm qps", "admitted", "queued"
    );
    let mut json_runs: Vec<String> = Vec::new();
    for sessions in [1usize, 4, 16] {
        let cfg = WorkerConfig {
            num_workers: 2,
            profile: HwProfile::on_prem(),
            time_scale: 0.1,
            result_cache_bytes: 64 << 20,
            fragment_cache_bytes: 64 << 20,
            ..WorkerConfig::default()
        };
        let store = tpch_store(&cfg, sf);
        let gw = gateway(cfg, store);
        let total = sessions * QUERIES_PER_SESSION;
        let frac = |s: usize, i: usize| {
            0.3 + 0.6 * ((s * QUERIES_PER_SESSION + i) as f64) / (total as f64)
        };

        // one timed pass: every session thread submits its slots
        let pass = |label: &str| -> (Duration, HashMap<(usize, usize), Vec<u8>>) {
            let bytes: Mutex<HashMap<(usize, usize), Vec<u8>>> = Mutex::new(HashMap::new());
            let t0 = Instant::now();
            std::thread::scope(|scope| {
                for s in 0..sessions {
                    let gw = &gw;
                    let bytes = &bytes;
                    let (panel, frac) = (&panel, &frac);
                    scope.spawn(move || {
                        for i in 0..QUERIES_PER_SESSION {
                            let r = gw
                                .submit(&panel(frac(s, i)))
                                .unwrap_or_else(|e| panic!("{label} s{s}q{i}: {e}"));
                            bytes.lock().unwrap().insert((s, i), r.batch.encode());
                        }
                    });
                }
            });
            (t0.elapsed(), bytes.into_inner().unwrap())
        };

        let (cold, cold_bytes) = pass("cold");
        let (warm, warm_bytes) = pass("warm");
        assert_eq!(
            cold_bytes, warm_bytes,
            "warm results must be byte-identical to their cold executions"
        );
        let m = &gw.cluster.metrics;
        let admitted = m.counter_value("gateway.admitted");
        let queued = m.counter_value("gateway.queued");
        assert_eq!(
            admitted, total as u64,
            "only cold submissions consume admissions; warm hits bypass the queue"
        );
        let qps = |d: Duration| total as f64 / d.as_secs_f64().max(1e-9);
        println!(
            "{:>9} {:>10} {:>10.1} {:>10} {:>10.1} {:>9} {:>8}",
            sessions,
            secs(cold),
            qps(cold),
            secs(warm),
            qps(warm),
            admitted,
            queued
        );
        for (phase, d) in [("cold", cold), ("warm", warm)] {
            json_runs.push(format!(
                "    {{\"sessions\": {sessions}, \"phase\": \"{phase}\", \"queries\": {total}, \
                 \"wall_ns\": {}, \"qps\": {:.2}, \"admitted\": {admitted}, \
                 \"queued\": {queued}}}",
                d.as_nanos(),
                qps(d)
            ));
        }
    }
    println!(
        "(cold throughput is bounded by the workers — admission only queues submits the\n \
         device budget can't hold concurrently; warm throughput is pure gateway-side\n \
         cache service, so the cold:warm gap at 16 sessions is the serving headroom the\n \
         session layer buys)\n"
    );

    // CI artifact: BENCH_GATEWAY_JSON=<path> writes the runs out
    if let Ok(path) = std::env::var("BENCH_GATEWAY_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"gateway_concurrency\",\n  \"sf\": {sf},\n  \
             \"queries_per_session\": {QUERIES_PER_SESSION},\n  \"runs\": [\n{}\n  ]\n}}\n",
            json_runs.join(",\n")
        );
        std::fs::write(&path, json).unwrap();
        println!("wrote {path}");
    }
}
