//! Figure 5 reproduction: on-prem total cold runtime for TPC-H and
//! TPC-DS at several scale factors and node counts.
//!
//! Paper shape to reproduce (§4.2):
//!  * runtimes grow with scale factor and shrink with workers;
//!  * at the largest SF, 4x the GPUs give ~4.3-4.8x the speed
//!    (super-linear-ish because small clusters spill);
//!  * the largest SF *completes* on the smallest cluster by spilling
//!    (device memory < working set).
//!
//! Run: `cargo bench --bench fig5_scaling` (env SFS / WORKERS to vary).

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::{gateway, run_suite, secs};
use theseus::config::WorkerConfig;
use theseus::sim::{HwProfile, SimContext};
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::workload::tpcds::TpcdsGen;
use theseus::workload::{tpcds_lite_suite, tpch_suite};

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn cfg_for(workers: usize, scale: f64, fabric: f64) -> WorkerConfig {
    let mut profile = HwProfile::on_prem();
    // restore the paper's data:hardware ratio (datasets here are ~1e7x
    // smaller): modeled device/storage/wire time must dominate host
    // overheads or node scaling cannot show (see common::scale_fabric)
    common_scale(&mut profile, fabric);
    WorkerConfig {
        num_workers: workers,
        profile,
        time_scale: scale,
        // small per-worker device so the largest SF must spill on the
        // smallest cluster (the paper's 1.28 TB vs 100 TB setup)
        device_capacity: 1 << 20,
        spill_watermark: 0.8,
        ..WorkerConfig::default()
    }
}

use common::scale_fabric as common_scale;

fn main() {
    let time_scale = env_f64("TIME_SCALE", 0.3);
    let fabric = env_f64("FABRIC_SCALE", 4000.0);
    // "10k / 30k / 100k" scaled down by ~1e7
    let sfs = [0.001, 0.003, 0.01];
    let sf_names = ["10k~", "30k~", "100k~"];
    let workers = [2usize, 4, 8];
    // CI artifact rows (BENCH_FIG5_JSON=<path>)
    let mut json_rows: Vec<String> = Vec::new();

    for (bench, is_tpch) in [("TPC-H", true), ("TPC-DS", false)] {
        println!("== Fig 5: {bench} total cold runtime (on-prem profile) ==");
        print!("{:<8}", "SF\\nodes");
        for w in workers {
            print!("{:>12}", format!("{w} workers"));
        }
        println!("{:>10} {:>8}", "4x speedup", "spills@2");
        let suite = if is_tpch { tpch_suite() } else { tpcds_lite_suite() };
        for (i, &sf) in sfs.iter().enumerate() {
            print!("{:<8}", sf_names[i]);
            let mut first = None;
            let mut last = None;
            let mut spills_at_2 = 0u64;
            for &w in &workers {
                let cfg = cfg_for(w, time_scale, fabric);
                let sim = SimContext::new(cfg.profile.clone(), cfg.time_scale);
                let store = SimObjectStore::in_memory(&sim);
                let dynstore: Arc<dyn ObjectStore> = store.clone();
                if is_tpch {
                    theseus::workload::TpchGen::new(sf).write_all(&dynstore).unwrap();
                } else {
                    TpcdsGen::new(sf).write_all(&dynstore).unwrap();
                }
                let gw = gateway(cfg, store);
                let (total, per) = run_suite(&gw, &suite);
                if w == 2 {
                    spills_at_2 = per.iter().map(|(_, r)| r.total_spills()).sum();
                }
                print!("{:>12}", secs(total));
                json_rows.push(format!(
                    "    {{\"suite\": \"{bench}\", \"sf\": {sf}, \"workers\": {w}, \
                     \"total_s\": {:.6}}}",
                    total.as_secs_f64()
                ));
                first.get_or_insert(total);
                last = Some(total);
            }
            let speedup = first
                .zip(last)
                .map(|(f, l): (Duration, Duration)| f.as_secs_f64() / l.as_secs_f64())
                .unwrap_or(0.0);
            println!("{:>9.2}x {:>8}", speedup, spills_at_2);
        }
        println!();
    }
    println!(
        "(paper: 4x GPUs at the largest SF -> 4.8x TPC-DS / 4.3x TPC-H speedup;\n\
         spilling sustains the largest SF on the smallest cluster)"
    );

    if let Ok(path) = std::env::var("BENCH_FIG5_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"fig5_scaling\",\n  \"time_scale\": {time_scale},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            json_rows.join(",\n")
        );
        std::fs::write(&path, json).unwrap();
        println!("wrote {path}");
    }
}
