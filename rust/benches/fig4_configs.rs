//! Figure 4 reproduction: TPC-H suite runtime under the configuration
//! ladder — on-prem A→E (network/pinned-pool ablations) and cloud F→I
//! (datasource/pre-loading ablations).
//!
//! Paper shape to reproduce (§4.1):
//!   A→B  network compression on TCP helps        (−18%)
//!   B→C  pinned fixed-size buffers help          (−17%)
//!   C→D  RDMA helps a little while compressing   (−6%)
//!   D→E  dropping compression on RDMA helps more (−19%)  (A→E ≈ 2x)
//!   F→G  custom object-store datasource          (−75%)
//!   G→H  byte-range pre-loading                  (−20%)
//!   H→I  compute-task pre-loading                (−19%)
//!
//! Run: `cargo bench --bench fig4_configs` (optionally `SF=0.005`).

mod common;

use common::{delta_pct, gateway, run_suite, secs, tpch_store};
use theseus::config::WorkerConfig;
use theseus::storage::object_store::ObjectStore;
use theseus::workload::tpch_suite;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Fabric scale-downs restoring the paper's data:bandwidth ratios for
/// our ~1e6x-smaller datasets (see common::scale_fabric). The IPoIB-TCP
/// path is scaled harder than GPUDirect RDMA: on the real hardware the
/// TCP path is bottlenecked by per-byte host CPU work (which our scaled
/// wall-clock can't charge), while RDMA bypasses the host entirely —
/// the asymmetry *is* the D/E phenomenon under test.
const TCP_SCALE: f64 = 2000.0;
const RDMA_SCALE: f64 = 100.0;
const PCIE_SCALE: f64 = 500.0;

fn main() {
    let sf = env_f64("SF", 0.003);
    let workers = env_f64("WORKERS", 4.0) as usize;
    let suite = tpch_suite();
    let onprem_scale = env_f64("ONPREM_SCALE", 0.3);
    // Cloud: storage latency dominates (S3-like 15 ms first-byte).
    let cloud_scale = env_f64("CLOUD_SCALE", 0.3);

    println!("== Fig 4: TPC-H suite runtime by configuration ==");
    println!("sf={sf}, {workers} workers, suite of {} queries\n", suite.len());
    // CI artifact rows (BENCH_FIG4_JSON=<path>)
    let mut json_rows: Vec<String> = Vec::new();

    println!("-- on-prem (A-E), time_scale={onprem_scale} --");
    println!(
        "{:<3} {:<42} {:>10} {:>8} {:>8}",
        "cfg", "description", "total", "vs A", "vs prev"
    );
    let mut base = None;
    let mut prev = None;
    for (letter, desc) in [
        ('A', "baseline: TCP, no compression, no pinned pool"),
        ('B', "A + network compression"),
        ('C', "B + pinned fixed-size buffer pool"),
        ('D', "C + GPUDirect-RDMA fabric"),
        ('E', "D - compression (free the CPU cycles)"),
    ] {
        let mut cfg = WorkerConfig::preset(letter).unwrap();
        cfg.num_workers = workers;
        cfg.time_scale = onprem_scale;
        // keep the real-TCP medium out of the on-prem compare: shaping
        // is the ablated quantity (see network module docs)
        if cfg.transport == theseus::config::TransportKind::Tcp {
            cfg.transport = theseus::config::TransportKind::Inproc;
        }
        // restore the paper's data:fabric ratio
        let p = &mut cfg.profile;
        p.net_tcp.bytes_per_sec = (p.net_tcp.bytes_per_sec as f64 / TCP_SCALE) as u64;
        if let Some(r) = p.net_rdma.as_mut() {
            r.bytes_per_sec = (r.bytes_per_sec as f64 / RDMA_SCALE) as u64;
        }
        p.pcie.bytes_per_sec = (p.pcie.bytes_per_sec as f64 / PCIE_SCALE) as u64;
        let store = tpch_store(&cfg, sf);
        let gw = gateway(cfg, store);
        let (total, _) = run_suite(&gw, &suite);
        let vs_a = base.map(|b| delta_pct(b, total)).unwrap_or_else(|| "-".into());
        let vs_p = prev.map(|p| delta_pct(p, total)).unwrap_or_else(|| "-".into());
        println!("{:<3} {:<42} {:>10} {:>8} {:>8}", letter, desc, secs(total), vs_a, vs_p);
        json_rows.push(format!(
            "    {{\"config\": \"{letter}\", \"ladder\": \"on-prem\", \"total_s\": {:.6}}}",
            total.as_secs_f64()
        ));
        base.get_or_insert(total);
        prev = Some(total);
    }
    if let (Some(a), Some(e)) = (base, prev) {
        println!(
            "A -> E combined speedup: {:.2}x (paper: ~2x)\n",
            a.as_secs_f64() / e.as_secs_f64()
        );
    }

    println!("-- cloud (F-I), time_scale={cloud_scale} --");
    println!(
        "{:<3} {:<42} {:>10} {:>8} {:>8}",
        "cfg", "description", "total", "vs F", "vs prev"
    );
    let mut base = None;
    let mut prev = None;
    for (letter, desc) in [
        ('F', "generic datasource, no pre-loading"),
        ('G', "custom object-store datasource"),
        ('H', "G + byte-range pre-loading"),
        ('I', "H + compute-task pre-loading"),
    ] {
        let mut cfg = WorkerConfig::preset(letter).unwrap();
        cfg.num_workers = workers;
        cfg.time_scale = cloud_scale;
        cfg.transport = theseus::config::TransportKind::Inproc;
        // pre-loading needs enough I/O threads to stay ahead of the
        // compute executor ("all executors have a number of
        // configurable CPU threads", §3.3)
        cfg.preload_threads = 4;
        let store = tpch_store(&cfg, sf);
        let reqs_before = store.request_count();
        let gw = gateway(cfg, store.clone());
        let (total, _) = run_suite(&gw, &suite);
        let reqs = store.request_count() - reqs_before;
        let vs_f = base.map(|b| delta_pct(b, total)).unwrap_or_else(|| "-".into());
        let vs_p = prev.map(|p| delta_pct(p, total)).unwrap_or_else(|| "-".into());
        println!(
            "{:<3} {:<42} {:>10} {:>8} {:>8}   ({reqs} store requests)",
            letter, desc, secs(total), vs_f, vs_p
        );
        json_rows.push(format!(
            "    {{\"config\": \"{letter}\", \"ladder\": \"cloud\", \"total_s\": {:.6}, \
             \"store_requests\": {reqs}}}",
            total.as_secs_f64()
        ));
        base.get_or_insert(total);
        prev = Some(total);
    }
    if let (Some(f), Some(i)) = (base, prev) {
        println!(
            "F -> I combined speedup: {:.2}x",
            f.as_secs_f64() / i.as_secs_f64()
        );
    }

    if let Ok(path) = std::env::var("BENCH_FIG4_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"fig4_configs\",\n  \"sf\": {sf},\n  \"workers\": {workers},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            json_rows.join(",\n")
        );
        std::fs::write(&path, json).unwrap();
        println!("wrote {path}");
    }
}
