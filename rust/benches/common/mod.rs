//! Shared bench harness: cluster setup, suite timing, table printing.
//!
//! All benches run in *scaled modeled time* (`time_scale > 0`): wall
//! clock then reflects the calibrated device/wire/storage speeds of the
//! paper's testbeds rather than this host's CPU, so configuration
//! ratios — the quantity every figure reports — carry over. Absolute
//! seconds are not comparable to the paper's (its clusters are ~3
//! orders of magnitude larger); *shapes* are.

use std::sync::Arc;
use std::time::Duration;

use theseus::cluster::{Cluster, Gateway, QueryResult};
use theseus::config::WorkerConfig;
use theseus::runtime::KernelRegistry;
use theseus::sim::SimContext;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::workload::{QueryDef, TpchGen};

/// Scale a hardware profile's bandwidths down by `f` (latencies
/// unchanged). Benches run datasets ~1e6-1e7x smaller than the paper's;
/// unscaled multi-GiB/s modeled links would make every transfer free
/// and erase the fabric effects the figures measure. Dividing bandwidth
/// by the data scale-down restores the paper's data:fabric ratio.
pub fn scale_fabric(p: &mut theseus::sim::HwProfile, f: f64) {
    let s = |spec: &mut theseus::sim::LinkSpec| {
        spec.bytes_per_sec = ((spec.bytes_per_sec as f64 / f) as u64).max(1);
    };
    s(&mut p.pcie);
    s(&mut p.net_tcp);
    if let Some(r) = p.net_rdma.as_mut() {
        s(r);
    }
    s(&mut p.storage);
    s(&mut p.device_compute);
}

/// Generate TPC-H into a fresh store shaped by `cfg`.
pub fn tpch_store(cfg: &WorkerConfig, sf: f64) -> Arc<SimObjectStore> {
    let sim = SimContext::new(cfg.profile.clone(), cfg.time_scale);
    let store = SimObjectStore::in_memory(&sim);
    let dynstore: Arc<dyn ObjectStore> = store.clone();
    TpchGen::new(sf).write_all(&dynstore).expect("datagen");
    store
}

/// Run a suite sequentially (as §4 does); returns (total, per-query).
pub fn run_suite(
    gw: &Gateway,
    suite: &[QueryDef],
) -> (Duration, Vec<(String, QueryResult)>) {
    let mut total = Duration::ZERO;
    let mut per = Vec::new();
    for q in suite {
        let r = gw.submit(&q.logical()).unwrap_or_else(|e| panic!("{}: {e}", q.id));
        total += r.elapsed;
        per.push((q.id.to_string(), r));
    }
    (total, per)
}

/// Launch a cluster + gateway over `store`.
pub fn gateway(cfg: WorkerConfig, store: Arc<SimObjectStore>) -> Gateway {
    let registry = KernelRegistry::shared().ok();
    let cluster =
        Cluster::launch(cfg, store, registry).expect("cluster launch");
    Gateway::new(cluster)
}

/// `12.3%` / `4.46x`-style delta formatting vs a baseline duration.
pub fn delta_pct(base: Duration, d: Duration) -> String {
    if base.is_zero() {
        return "-".into();
    }
    let pct = 100.0 * (base.as_secs_f64() - d.as_secs_f64()) / base.as_secs_f64();
    format!("{pct:+.1}%")
}

pub fn secs(d: Duration) -> String {
    format!("{:.3}s", d.as_secs_f64())
}
