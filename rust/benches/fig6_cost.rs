//! Figure 6 + Table 1 reproduction: performance vs cost of Theseus on
//! GPU cloud nodes against a Photon-like CPU engine on memory-heavy
//! CPU nodes, at matched cluster $/hour.
//!
//! Paper shape to reproduce (§4.3):
//!  * Table 1's cluster pairings, $/h and memory totals (exact);
//!  * Theseus wins at every scale factor and cluster size;
//!  * the margin grows with scale: +12.3% at the smallest pairing to
//!    ~4.46x at the largest.
//!
//! The Photon stand-in is our single-threaded CPU engine; a Photon
//! *cluster* of N nodes is modeled as baseline_time / (N * 0.85)
//! (85% parallel efficiency — generous to the comparator; see
//! DESIGN.md substitution #3). Theseus runtimes are measured, with the
//! paper's cloud node counts mapped 4:1 onto local workers.
//!
//! Run: `cargo bench --bench fig6_cost`.

mod common;

use common::{gateway, run_suite, tpch_store};
use theseus::config::WorkerConfig;
use theseus::sim::cost::{CostModel, G6_4XLARGE, R7GD_12XLARGE, TABLE1_PAIRS};
use theseus::sim::HwProfile;
use theseus::workload::{tpch_suite, CpuEngine};

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

const PHOTON_PARALLEL_EFF: f64 = 0.85;

fn main() {
    let time_scale = env_f64("TIME_SCALE", 0.05);
    // "1k / 3k / 10k / 30k" scaled down by ~1e6
    let sfs = [0.001, 0.003, 0.01, 0.03];
    let sf_names = ["1k~", "3k~", "10k~", "30k~"];
    let suite = tpch_suite();

    // ---------------- Table 1
    println!("== Table 1: cluster pairings ==");
    println!(
        "{:>8} {:>10} {:>10} | {:>8} {:>10} {:>10}",
        "Theseus", "Memory", "Cost", "Photon", "Memory", "Cost"
    );
    for (t_nodes, p_nodes) in TABLE1_PAIRS {
        let t = CostModel::new(G6_4XLARGE, t_nodes);
        let p = CostModel::new(R7GD_12XLARGE, p_nodes);
        println!(
            "{:>8} {:>9}G {:>8.2}$ | {:>8} {:>9}G {:>8.2}$",
            t_nodes,
            t.total_memory_gib(),
            t.usd_per_hour(),
            p_nodes,
            p.total_memory_gib(),
            p.usd_per_hour()
        );
    }

    // ---------------- Figure 6
    // CI artifact rows (BENCH_FIG6_JSON=<path>)
    let mut json_rows: Vec<String> = Vec::new();
    println!("\n== Fig 6: TPC-H suite, performance vs cost (time_scale={time_scale}) ==");
    println!(
        "{:<5} {:>7} {:>7} {:>12} {:>12} {:>12} {:>10}",
        "SF", "t-nodes", "p-nodes", "theseus", "photon-like", "$/run ratio", "at-parity"
    );
    for (i, &sf) in sfs.iter().enumerate() {
        // measure the CPU baseline once per sf (single node)
        let probe_cfg = WorkerConfig {
            profile: HwProfile::cloud(),
            time_scale,
            ..WorkerConfig::default()
        };
        let store = tpch_store(&probe_cfg, sf);
        let engine = CpuEngine::new(store);
        let mut single_node = std::time::Duration::ZERO;
        for q in &suite {
            single_node += engine.run(&q.logical()).unwrap().elapsed;
        }

        for (pair, (t_nodes, p_nodes)) in TABLE1_PAIRS.into_iter().enumerate() {
            // map the paper's {8,16,32} cloud nodes to {2,4,8} workers.
            // The fabric is deliberately NOT scaled down here: this
            // figure compares engine against engine, and the baseline's
            // compute runs at real CPU speed — scaling only Theseus's
            // modeled device would break the GPU:CPU throughput ratio
            // the figure is about. Caveat (EXPERIMENTS.md): with all
            // workers sharing one host core, the largest local cluster
            // under-scales; the per-pairing SF gradient is the claim
            // under test.
            let workers = (t_nodes / 4) as usize;
            let cfg = WorkerConfig {
                num_workers: workers,
                profile: HwProfile::cloud(),
                time_scale,
                device_capacity: 48 << 20,
                ..WorkerConfig::default()
            };
            let store = tpch_store(&cfg, sf);
            let gw = gateway(cfg, store);
            let (t_total, _) = run_suite(&gw, &suite);

            let p_total = single_node.as_secs_f64()
                / (p_nodes as f64 * PHOTON_PARALLEL_EFF);
            let t_cost = CostModel::new(G6_4XLARGE, t_nodes);
            let p_cost = CostModel::new(R7GD_12XLARGE, p_nodes);
            let parity =
                t_cost.speedup_at_cost_parity(t_total.as_secs_f64(), &p_cost, p_total);
            let dollar_ratio = p_cost.usd_for_run(p_total)
                / t_cost.usd_for_run(t_total.as_secs_f64()).max(1e-12);
            println!(
                "{:<5} {:>7} {:>7} {:>11.3}s {:>11.3}s {:>11.2}x {:>9.2}x",
                if pair == 0 { sf_names[i] } else { "" },
                t_nodes,
                p_nodes,
                t_total.as_secs_f64(),
                p_total,
                dollar_ratio,
                parity,
            );
            json_rows.push(format!(
                "    {{\"sf\": {sf}, \"theseus_nodes\": {t_nodes}, \
                 \"photon_nodes\": {p_nodes}, \"theseus_s\": {:.6}, \
                 \"photon_s\": {p_total:.6}, \"dollar_ratio\": {dollar_ratio:.4}, \
                 \"at_parity\": {parity:.4}}}",
                t_total.as_secs_f64()
            ));
        }
    }
    println!("\n(paper: Theseus ahead at every point; 12.3% at the smallest pairing,\n 4.46x at the largest — margin grows with scale)");

    if let Ok(path) = std::env::var("BENCH_FIG6_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"fig6_cost\",\n  \"time_scale\": {time_scale},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            json_rows.join(",\n")
        );
        std::fs::write(&path, json).unwrap();
        println!("wrote {path}");
    }
}
