//! L1/L2: lock-hierarchy and condvar-discipline checks.
//!
//! A per-file, intra-procedural walker over `syn` ASTs. It tracks
//! which declared locks are held at every expression, resolving
//! receivers by *trailing field name* against the locks `lockorder.toml`
//! declares for the file being checked. The deliberate consequences:
//!
//! * Cross-file nesting is invisible (a method on another struct may
//!   acquire its own locks; the runtime `OrderedMutex` twin catches
//!   those orderings in debug builds).
//! * A one-level call-graph expansion covers the common intra-file
//!   case: `self.helper()` is charged with the locks `helper` acquires
//!   directly in the same file.
//!
//! Checks emitted here:
//! * `lock-order`   — an acquisition whose rank is not strictly greater
//!                    than every held rank (same-rank nesting included).
//! * `unranked-lock`— a `Mutex`/`RwLock`/`OrderedMutex` struct field
//!                    with no `lockorder.toml` entry.
//! * `condvar-wait` — a `wait`/`wait_timeout` on a declared condvar
//!                    outside a loop (`wait_while` loops internally and
//!                    is exempt).
//! * `condvar-notify` — a zero-arg `notify_*` on a declared condvar
//!                    while its paired lock is not held (the ordered
//!                    API takes the guard, so one-arg calls are
//!                    structurally safe).
//! * `condvar-unpaired` — a `Condvar` field no declared lock claims.
//! * `stale-decl`   — a `lockorder.toml` entry whose struct/field no
//!                    longer exists in the file it names.
//!
//! Escape hatch: a `// lint: lock-ok(<reason>)` comment on the same or
//! the preceding line suppresses any violation at that line.

use std::collections::{HashMap, HashSet};

use syn::spanned::Spanned;
use syn::visit::{self, Visit};
use syn::{
    Block, Expr, ImplItem, Item, ItemStruct, Member, Pat, Stmt, TraitItem, Type,
};

use crate::lockorder::{LockDecl, LockOrder};
use crate::Violation;

/// Lint one source file. `rel` is the path relative to `rust/` (the
/// same spelling `lockorder.toml` uses, e.g. `src/memory/pinned.rs`).
pub fn check_file(rel: &str, src: &str, order: &LockOrder, out: &mut Vec<Violation>) {
    let suppressed = suppressed_lines(src);
    let ast = match syn::parse_file(src) {
        Ok(a) => a,
        Err(e) => {
            out.push(Violation {
                rule: "parse",
                file: rel.to_string(),
                line: e.span().start().line,
                msg: format!("failed to parse: {e}"),
            });
            return;
        }
    };

    let decls: Vec<LockDecl> = order
        .locks_in_file(rel)
        .into_iter()
        .cloned()
        .collect();
    let mut fields: HashMap<String, Vec<LockDecl>> = HashMap::new();
    let mut conds: HashMap<String, Vec<LockDecl>> = HashMap::new();
    for d in &decls {
        fields.entry(d.field.clone()).or_default().push(d.clone());
        for c in &d.condvars {
            conds.entry(c.clone()).or_default().push(d.clone());
        }
    }

    // Pass 1: what does each method in this file acquire directly?
    // Feeds the one-level `self.helper()` expansion in pass 2.
    let mut fn_ranks: HashMap<String, Vec<(u16, String)>> = HashMap::new();
    collect_fn_ranks(&ast.items, &fields, &mut fn_ranks);

    // Pass 2: walk every non-test fn body; check every struct.
    let mut seen: HashSet<(String, String)> = HashSet::new();
    let mut w = Walker {
        rel,
        suppressed: &suppressed,
        fields: &fields,
        conds: &conds,
        fn_ranks: &fn_ranks,
        held: Vec::new(),
        bound_stack: Vec::new(),
        next_id: 0,
        loop_depth: 0,
        out,
    };
    lint_items(&ast.items, &mut w, &decls, &mut seen);

    for d in &decls {
        if !seen.contains(&(d.strukt.clone(), d.field.clone())) {
            out.push(Violation {
                rule: "stale-decl",
                file: rel.to_string(),
                line: 0,
                msg: format!(
                    "lockorder.toml declares `{}` as {}::{} but no such lock field exists",
                    d.name, d.strukt, d.field
                ),
            });
        }
    }
}

/// Lines carrying a `// lint: lock-ok(<reason>)` marker. A marker
/// suppresses violations on its own line and the following line.
pub(crate) fn suppressed_lines(src: &str) -> HashSet<usize> {
    src.lines()
        .enumerate()
        .filter(|(_, l)| l.contains("lint: lock-ok("))
        .map(|(i, _)| i + 1)
        .collect()
}

pub(crate) fn is_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        a.path().is_ident("cfg")
            && match &a.meta {
                syn::Meta::List(l) => l.tokens.to_string().contains("test"),
                _ => false,
            }
    })
}

/// The last field name in a receiver chain: `self.inner.free` → `free`,
/// `self.shards[i]` → `shards`, a bare local → its name (covers
/// `let q = &self.q; q.lock()` aliasing within a fn).
fn trailing_name(e: &Expr) -> Option<String> {
    match e {
        Expr::Field(f) => Some(match &f.member {
            Member::Named(i) => i.to_string(),
            Member::Unnamed(ix) => ix.index.to_string(),
        }),
        Expr::Paren(p) => trailing_name(&p.expr),
        Expr::Group(g) => trailing_name(&g.expr),
        Expr::Reference(r) => trailing_name(&r.expr),
        Expr::Unary(u) => trailing_name(&u.expr),
        Expr::Index(ix) => trailing_name(&ix.expr),
        Expr::MethodCall(m) if m.method == "clone" => trailing_name(&m.receiver),
        Expr::Path(p) => p.path.get_ident().map(|i| i.to_string()),
        _ => None,
    }
}

fn is_self_path(e: &Expr) -> bool {
    matches!(e, Expr::Path(p) if p.path.is_ident("self"))
}

fn pat_ident(pat: &Pat) -> Option<String> {
    match pat {
        Pat::Ident(p) => Some(p.ident.to_string()),
        Pat::Type(t) => pat_ident(&t.pat),
        _ => None,
    }
}

enum FieldClass {
    Lock,
    Condvar,
}

/// Does this type contain a lock or condvar? Recurses through wrappers
/// (`Arc<Mutex<T>>`, `Vec<Mutex<T>>`, `[Mutex<T>; N]`, tuples, refs).
fn classify_type(ty: &Type) -> Option<FieldClass> {
    match ty {
        Type::Path(tp) => {
            let seg = tp.path.segments.last()?;
            match seg.ident.to_string().as_str() {
                "Mutex" | "RwLock" | "OrderedMutex" => Some(FieldClass::Lock),
                "Condvar" | "OrderedCondvar" => Some(FieldClass::Condvar),
                _ => {
                    if let syn::PathArguments::AngleBracketed(ab) = &seg.arguments {
                        for arg in &ab.args {
                            if let syn::GenericArgument::Type(t) = arg {
                                if let Some(c) = classify_type(t) {
                                    return Some(c);
                                }
                            }
                        }
                    }
                    None
                }
            }
        }
        Type::Reference(r) => classify_type(&r.elem),
        Type::Paren(p) => classify_type(&p.elem),
        Type::Group(g) => classify_type(&g.elem),
        Type::Slice(s) => classify_type(&s.elem),
        Type::Array(a) => classify_type(&a.elem),
        Type::Tuple(t) => t.elems.iter().find_map(classify_type),
        _ => None,
    }
}

/// Pass 1 visitor: direct acquisitions of a fn body, closures excluded
/// (a closure's body runs later, under whatever is held *then*).
struct AcqCollector<'a> {
    fields: &'a HashMap<String, Vec<LockDecl>>,
    acqs: Vec<(u16, String)>,
}

impl<'ast, 'a> Visit<'ast> for AcqCollector<'a> {
    fn visit_expr_closure(&mut self, _node: &'ast syn::ExprClosure) {}

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        if node.args.is_empty()
            && matches!(node.method.to_string().as_str(), "lock" | "read" | "write")
        {
            if let Some(name) = trailing_name(&node.receiver) {
                if let Some(v) = self.fields.get(&name) {
                    if v.len() == 1 {
                        self.acqs.push((v[0].rank, v[0].name.clone()));
                    }
                }
            }
        }
        visit::visit_expr_method_call(self, node);
    }
}

fn collect_fn_ranks(
    items: &[Item],
    fields: &HashMap<String, Vec<LockDecl>>,
    map: &mut HashMap<String, Vec<(u16, String)>>,
) {
    for item in items {
        match item {
            Item::Impl(i) if !is_cfg_test(&i.attrs) => {
                for ii in &i.items {
                    if let ImplItem::Fn(f) = ii {
                        if is_cfg_test(&f.attrs) {
                            continue;
                        }
                        let mut c = AcqCollector { fields, acqs: Vec::new() };
                        c.visit_block(&f.block);
                        if !c.acqs.is_empty() {
                            map.entry(f.sig.ident.to_string()).or_default().extend(c.acqs);
                        }
                    }
                }
            }
            Item::Mod(m) if !is_cfg_test(&m.attrs) && m.ident != "tests" => {
                if let Some((_, sub)) = &m.content {
                    collect_fn_ranks(sub, fields, map);
                }
            }
            _ => {}
        }
    }
}

fn lint_items(
    items: &[Item],
    w: &mut Walker<'_>,
    decls: &[LockDecl],
    seen: &mut HashSet<(String, String)>,
) {
    for item in items {
        match item {
            Item::Struct(s) => {
                if !is_cfg_test(&s.attrs) {
                    check_struct(s, decls, w, seen);
                }
            }
            Item::Impl(i) => {
                if is_cfg_test(&i.attrs) {
                    continue;
                }
                for ii in &i.items {
                    if let ImplItem::Fn(f) = ii {
                        if !is_cfg_test(&f.attrs) {
                            w.run_fn(&f.block);
                        }
                    }
                }
            }
            Item::Fn(f) => {
                if !is_cfg_test(&f.attrs) {
                    w.run_fn(&f.block);
                }
            }
            Item::Trait(t) => {
                if is_cfg_test(&t.attrs) {
                    continue;
                }
                for ti in &t.items {
                    if let TraitItem::Fn(f) = ti {
                        if let Some(b) = &f.default {
                            w.run_fn(b);
                        }
                    }
                }
            }
            Item::Mod(m) => {
                if !is_cfg_test(&m.attrs) && m.ident != "tests" {
                    if let Some((_, sub)) = &m.content {
                        lint_items(sub, w, decls, seen);
                    }
                }
            }
            _ => {}
        }
    }
}

fn check_struct(
    s: &ItemStruct,
    decls: &[LockDecl],
    w: &mut Walker<'_>,
    seen: &mut HashSet<(String, String)>,
) {
    let sname = s.ident.to_string();
    for (idx, f) in s.fields.iter().enumerate() {
        let fname = f
            .ident
            .as_ref()
            .map(|i| i.to_string())
            .unwrap_or_else(|| idx.to_string());
        let line = f.span().start().line;
        match classify_type(&f.ty) {
            Some(FieldClass::Lock) => {
                seen.insert((sname.clone(), fname.clone()));
                if !decls.iter().any(|d| d.strukt == sname && d.field == fname) {
                    w.push_violation(
                        "unranked-lock",
                        line,
                        format!(
                            "`{sname}::{fname}` is a lock with no rank in lockorder.toml \
                             (declare it, or mark the line `// lint: lock-ok(<reason>)`)"
                        ),
                    );
                }
            }
            Some(FieldClass::Condvar) => {
                if !decls
                    .iter()
                    .any(|d| d.strukt == sname && d.condvars.iter().any(|c| c == &fname))
                {
                    w.push_violation(
                        "condvar-unpaired",
                        line,
                        format!(
                            "`{sname}::{fname}` is a Condvar no declared lock pairs with \
                             (add it to a lockorder.toml `condvars` list)"
                        ),
                    );
                }
            }
            None => {}
        }
    }
}

/// One held lock: `id` keys its drop scope, `var` its binding (if any).
struct Held {
    id: usize,
    rank: u16,
    name: String,
    var: Option<String>,
}

struct Walker<'a> {
    rel: &'a str,
    suppressed: &'a HashSet<usize>,
    fields: &'a HashMap<String, Vec<LockDecl>>,
    conds: &'a HashMap<String, Vec<LockDecl>>,
    fn_ranks: &'a HashMap<String, Vec<(u16, String)>>,
    held: Vec<Held>,
    /// One frame per lexical block: acquisition ids bound to `let`
    /// guards in that block, released when the block ends.
    bound_stack: Vec<Vec<usize>>,
    next_id: usize,
    loop_depth: usize,
    out: &'a mut Vec<Violation>,
}

impl<'a> Walker<'a> {
    fn run_fn(&mut self, block: &Block) {
        self.held.clear();
        self.bound_stack.clear();
        self.loop_depth = 0;
        self.walk_block(block);
    }

    fn push_violation(&mut self, rule: &'static str, line: usize, msg: String) {
        if self.suppressed.contains(&line) || (line > 1 && self.suppressed.contains(&(line - 1))) {
            return;
        }
        self.out.push(Violation {
            rule,
            file: self.rel.to_string(),
            line,
            msg,
        });
    }

    fn remove_ids(&mut self, ids: &[usize]) {
        if !ids.is_empty() {
            self.held.retain(|h| !ids.contains(&h.id));
        }
    }

    fn resolve_lock(&self, recv: &Expr) -> Option<(u16, String)> {
        let name = trailing_name(recv)?;
        let v = self.fields.get(&name)?;
        if v.len() == 1 {
            Some((v[0].rank, v[0].name.clone()))
        } else {
            None
        }
    }

    /// Paired lock names for a condvar receiver, if it resolves.
    fn resolve_cond(&self, recv: &Expr) -> Option<Vec<String>> {
        let name = trailing_name(recv)?;
        let v = self.conds.get(&name)?;
        Some(v.iter().map(|d| d.name.clone()).collect())
    }

    fn check_order(&mut self, rank: u16, name: &str, line: usize, via: Option<&str>) {
        let offenders: Vec<(u16, String)> = self
            .held
            .iter()
            .filter(|h| h.rank >= rank)
            .map(|h| (h.rank, h.name.clone()))
            .collect();
        for (hrank, hname) in offenders {
            let via_note = via.map(|m| format!(" via `self.{m}()`")).unwrap_or_default();
            self.push_violation(
                "lock-order",
                line,
                format!(
                    "acquiring `{name}` (rank {rank}){via_note} while `{hname}` \
                     (rank {hrank}) is held — ranks must strictly increase inward"
                ),
            );
        }
    }

    fn walk_block(&mut self, block: &Block) {
        self.bound_stack.push(Vec::new());
        for stmt in &block.stmts {
            self.walk_stmt(stmt);
        }
        let frame = self.bound_stack.pop().unwrap_or_default();
        self.remove_ids(&frame);
    }

    fn walk_stmt(&mut self, stmt: &Stmt) {
        match stmt {
            Stmt::Local(local) => {
                if let Some(init) = &local.init {
                    let mut temps = Vec::new();
                    let ret = self.walk_expr(&init.expr, &mut temps);
                    if let Some((_, div)) = &init.diverge {
                        let mut t = Vec::new();
                        self.walk_expr(div, &mut t);
                        self.remove_ids(&t);
                    }
                    if let (Some(name), Some(id)) = (pat_ident(&local.pat), ret) {
                        self.promote(id, name, &mut temps);
                    }
                    self.remove_ids(&temps);
                }
            }
            Stmt::Expr(e, _) => {
                let mut temps = Vec::new();
                self.walk_expr(e, &mut temps);
                self.remove_ids(&temps);
            }
            // Macro bodies and nested items are opaque to held-tracking.
            Stmt::Macro(_) | Stmt::Item(_) => {}
        }
    }

    /// Bind acquisition `id` to `var` and move it from statement-temp
    /// scope to the enclosing block's scope.
    fn promote(&mut self, id: usize, var: String, temps: &mut Vec<usize>) {
        if let Some(h) = self.held.iter_mut().find(|h| h.id == id) {
            h.var = Some(var);
        }
        temps.retain(|&t| t != id);
        if let Some(frame) = self.bound_stack.last_mut() {
            frame.push(id);
        }
    }

    /// Walk an expression; returns the held-id the expression evaluates
    /// to when it is (or forwards) a fresh guard.
    fn walk_expr(&mut self, e: &Expr, temps: &mut Vec<usize>) -> Option<usize> {
        match e {
            Expr::MethodCall(m) => self.walk_method_call(m, temps),
            Expr::Call(c) => {
                // `drop(guard)` releases a named guard early.
                if let Expr::Path(p) = &*c.func {
                    if p.path.is_ident("drop") && c.args.len() == 1 {
                        if let Expr::Path(arg) = &c.args[0] {
                            if let Some(ident) = arg.path.get_ident() {
                                let name = ident.to_string();
                                self.held.retain(|h| h.var.as_deref() != Some(name.as_str()));
                                return None;
                            }
                        }
                    }
                }
                self.walk_expr(&c.func, temps);
                for a in &c.args {
                    self.walk_expr(a, temps);
                }
                None
            }
            Expr::Assign(a) => {
                let ret = self.walk_expr(&a.right, temps);
                if let Expr::Path(p) = &*a.left {
                    if let Some(ident) = p.path.get_ident() {
                        let name = ident.to_string();
                        if let Some(id) = ret {
                            // Re-binding: the old guard (if any) drops,
                            // the fresh one takes the name.
                            self.held
                                .retain(|h| h.id == id || h.var.as_deref() != Some(name.as_str()));
                            self.promote(id, name, temps);
                        }
                        return None;
                    }
                }
                self.walk_expr(&a.left, temps);
                None
            }
            Expr::If(i) => {
                let mut cond_temps = Vec::new();
                let is_let = matches!(&*i.cond, Expr::Let(_));
                self.walk_expr(&i.cond, &mut cond_temps);
                if !is_let {
                    // Plain-if condition temporaries drop before the
                    // branch runs; if-let scrutinee temporaries live
                    // through both branches (Rust's extended scopes).
                    self.remove_ids(&cond_temps);
                    cond_temps.clear();
                }
                self.walk_block(&i.then_branch);
                if let Some((_, els)) = &i.else_branch {
                    let mut t = Vec::new();
                    self.walk_expr(els, &mut t);
                    self.remove_ids(&t);
                }
                self.remove_ids(&cond_temps);
                None
            }
            Expr::Match(m) => {
                // Scrutinee temporaries live through every arm.
                let mut scrutinee = Vec::new();
                self.walk_expr(&m.expr, &mut scrutinee);
                for arm in &m.arms {
                    if let Some((_, guard)) = &arm.guard {
                        let mut t = Vec::new();
                        self.walk_expr(guard, &mut t);
                        self.remove_ids(&t);
                    }
                    let mut t = Vec::new();
                    self.walk_expr(&arm.body, &mut t);
                    self.remove_ids(&t);
                }
                self.remove_ids(&scrutinee);
                None
            }
            Expr::While(w) => {
                let mut t = Vec::new();
                self.walk_expr(&w.cond, &mut t);
                self.remove_ids(&t);
                self.loop_depth += 1;
                self.walk_block(&w.body);
                self.loop_depth -= 1;
                None
            }
            Expr::ForLoop(f) => {
                // `for x in self.q.lock().iter()` holds the guard for
                // the whole loop body.
                let mut t = Vec::new();
                self.walk_expr(&f.expr, &mut t);
                self.loop_depth += 1;
                self.walk_block(&f.body);
                self.loop_depth -= 1;
                self.remove_ids(&t);
                None
            }
            Expr::Loop(l) => {
                self.loop_depth += 1;
                self.walk_block(&l.body);
                self.loop_depth -= 1;
                None
            }
            Expr::Closure(c) => {
                // A closure body runs under unknown future context:
                // check it standalone, with nothing held.
                let saved_held = std::mem::take(&mut self.held);
                let saved_depth = std::mem::replace(&mut self.loop_depth, 0);
                let mut t = Vec::new();
                self.walk_expr(&c.body, &mut t);
                self.remove_ids(&t);
                self.held = saved_held;
                self.loop_depth = saved_depth;
                None
            }
            Expr::Block(b) => {
                self.walk_block(&b.block);
                None
            }
            Expr::Unsafe(u) => {
                self.walk_block(&u.block);
                None
            }
            Expr::Paren(p) => self.walk_expr(&p.expr, temps),
            Expr::Group(g) => self.walk_expr(&g.expr, temps),
            Expr::Reference(r) => self.walk_expr(&r.expr, temps),
            Expr::Try(t) => self.walk_expr(&t.expr, temps),
            Expr::Unary(u) => self.walk_expr(&u.expr, temps),
            Expr::Let(l) => self.walk_expr(&l.expr, temps),
            Expr::Path(p) => {
                // A bare reference to a named guard forwards its id
                // (feeds `Assign`/`let` re-binding).
                if let Some(ident) = p.path.get_ident() {
                    let name = ident.to_string();
                    return self
                        .held
                        .iter()
                        .find(|h| h.var.as_deref() == Some(name.as_str()))
                        .map(|h| h.id);
                }
                None
            }
            Expr::Binary(b) => {
                self.walk_expr(&b.left, temps);
                self.walk_expr(&b.right, temps);
                None
            }
            Expr::Field(f) => {
                self.walk_expr(&f.base, temps);
                None
            }
            Expr::Index(ix) => {
                self.walk_expr(&ix.expr, temps);
                self.walk_expr(&ix.index, temps);
                None
            }
            Expr::Cast(c) => {
                self.walk_expr(&c.expr, temps);
                None
            }
            Expr::Tuple(t) => {
                for el in &t.elems {
                    self.walk_expr(el, temps);
                }
                None
            }
            Expr::Array(a) => {
                for el in &a.elems {
                    self.walk_expr(el, temps);
                }
                None
            }
            Expr::Struct(s) => {
                for f in &s.fields {
                    self.walk_expr(&f.expr, temps);
                }
                if let Some(rest) = &s.rest {
                    self.walk_expr(rest, temps);
                }
                None
            }
            Expr::Return(r) => {
                if let Some(inner) = &r.expr {
                    self.walk_expr(inner, temps);
                }
                None
            }
            Expr::Break(b) => {
                if let Some(inner) = &b.expr {
                    self.walk_expr(inner, temps);
                }
                None
            }
            Expr::Range(r) => {
                if let Some(s) = &r.start {
                    self.walk_expr(s, temps);
                }
                if let Some(e) = &r.end {
                    self.walk_expr(e, temps);
                }
                None
            }
            Expr::Repeat(r) => {
                self.walk_expr(&r.expr, temps);
                self.walk_expr(&r.len, temps);
                None
            }
            // Macro bodies are opaque; literals and the rest hold
            // nothing.
            _ => None,
        }
    }

    fn walk_method_call(
        &mut self,
        m: &syn::ExprMethodCall,
        temps: &mut Vec<usize>,
    ) -> Option<usize> {
        let recv_id = self.walk_expr(&m.receiver, temps);
        for a in &m.args {
            self.walk_expr(a, temps);
        }
        let method = m.method.to_string();
        let line = m.method.span().start().line;
        match method.as_str() {
            "lock" | "read" | "write" if m.args.is_empty() => {
                if let Some((rank, name)) = self.resolve_lock(&m.receiver) {
                    self.check_order(rank, &name, line, None);
                    let id = self.next_id;
                    self.next_id += 1;
                    self.held.push(Held {
                        id,
                        rank,
                        name,
                        var: None,
                    });
                    temps.push(id);
                    return Some(id);
                }
                None
            }
            // `x.lock().unwrap()` / `.expect(..)`: still the guard.
            "unwrap" | "expect" => recv_id,
            "wait" | "wait_timeout" => {
                if self.resolve_cond(&m.receiver).is_some() && self.loop_depth == 0 {
                    self.push_violation(
                        "condvar-wait",
                        line,
                        format!(
                            "`{method}` on a declared condvar outside a loop — spurious \
                             wakeups require re-checking the predicate"
                        ),
                    );
                }
                None
            }
            "notify_one" | "notify_all" if m.args.is_empty() => {
                if let Some(paired) = self.resolve_cond(&m.receiver) {
                    let held_paired = paired
                        .iter()
                        .any(|p| self.held.iter().any(|h| &h.name == p));
                    if !held_paired {
                        self.push_violation(
                            "condvar-notify",
                            line,
                            format!(
                                "`{method}` without holding the paired lock ({}) — a waiter \
                                 between its re-check and its park misses this signal",
                                paired.join(", ")
                            ),
                        );
                    }
                }
                None
            }
            _ => {
                // One-level expansion: `self.helper()` is charged with
                // helper's own direct acquisitions.
                if is_self_path(&m.receiver) {
                    if let Some(acqs) = self.fn_ranks.get(&method) {
                        let acqs = acqs.clone();
                        for (rank, name) in &acqs {
                            self.check_order(*rank, name, line, Some(&method));
                        }
                    }
                }
                None
            }
        }
    }
}
