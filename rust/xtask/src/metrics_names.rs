//! L4: metric-name registry.
//!
//! Every counter/gauge/histogram name used in `src/` (a string literal
//! as the single argument of `.counter(..)` / `.gauge(..)` /
//! `.histogram(..)`) must appear exactly once in the `METRIC_NAMES`
//! table in `src/metrics/registry.rs`, and every registry entry must
//! appear somewhere in `src/` as a string literal — names that flow
//! through variables (eviction tuple tables, exchange-mode match arms)
//! still satisfy that weaker check. Entries containing `*` are
//! wildcards for `format!`-built names (per-destination gauges) and
//! skip the usage check.
//!
//! The registry is the single place a dashboard or test can read the
//! full metric surface from; duplicate or dangling entries rot it.

use std::collections::HashSet;

use syn::spanned::Spanned;
use syn::visit::{self, Visit};
use syn::{Expr, Item, Lit};

use crate::locks::is_cfg_test;
use crate::Violation;

#[derive(Default)]
pub struct MetricsCheck {
    /// (name, line) per registry entry, in table order.
    registry: Vec<(String, usize)>,
    registry_found: bool,
    /// (file, name, line) per literal `.counter("x")`-style use.
    uses: Vec<(String, String, usize)>,
    /// Every string literal in non-test src (registry excluded).
    literals: HashSet<String>,
}

impl MetricsCheck {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse `src/metrics/registry.rs` for the `METRIC_NAMES` table.
    pub fn load_registry(&mut self, rel: &str, src: &str, out: &mut Vec<Violation>) {
        let ast = match syn::parse_file(src) {
            Ok(a) => a,
            Err(_) => return, // locks.rs reports the parse failure
        };
        for item in &ast.items {
            if let Item::Const(c) = item {
                if c.ident == "METRIC_NAMES" {
                    self.registry_found = true;
                    collect_str_elems(&c.expr, &mut self.registry);
                }
            }
        }
        if !self.registry_found {
            out.push(Violation {
                rule: "metrics-registry",
                file: rel.to_string(),
                line: 1,
                msg: "no `METRIC_NAMES` const found".to_string(),
            });
            // Treat as an (empty) registry so uses still get reported.
            self.registry_found = true;
        }
    }

    /// Collect uses and literals from one non-registry source file.
    pub fn collect_file(&mut self, rel: &str, src: &str) {
        let Ok(ast) = syn::parse_file(src) else { return };
        let mut v = UseCollector {
            file: rel,
            check: self,
        };
        for item in &ast.items {
            v.visit_item(item);
        }
    }

    /// Run the cross-file checks. No-op unless a registry was loaded.
    pub fn finish(self, out: &mut Vec<Violation>) {
        if !self.registry_found {
            return;
        }
        let mut seen: HashSet<&str> = HashSet::new();
        for (name, line) in &self.registry {
            if !seen.insert(name.as_str()) {
                out.push(Violation {
                    rule: "metrics-registry",
                    file: "src/metrics/registry.rs".to_string(),
                    line: *line,
                    msg: format!("duplicate METRIC_NAMES entry `{name}`"),
                });
            }
            if !name.contains('*') && !self.literals.contains(name) {
                out.push(Violation {
                    rule: "metrics-registry",
                    file: "src/metrics/registry.rs".to_string(),
                    line: *line,
                    msg: format!(
                        "METRIC_NAMES entry `{name}` never appears as a string literal in src/"
                    ),
                });
            }
        }
        for (file, name, line) in &self.uses {
            if !self.registry.iter().any(|(n, _)| n == name) {
                out.push(Violation {
                    rule: "metrics-registry",
                    file: file.clone(),
                    line: *line,
                    msg: format!(
                        "metric `{name}` is not in METRIC_NAMES (src/metrics/registry.rs)"
                    ),
                });
            }
        }
    }
}

/// Pull string literals out of `&["a", "b", ...]` (references, arrays,
/// and nested groups peeled).
fn collect_str_elems(e: &Expr, out: &mut Vec<(String, usize)>) {
    match e {
        Expr::Reference(r) => collect_str_elems(&r.expr, out),
        Expr::Paren(p) => collect_str_elems(&p.expr, out),
        Expr::Group(g) => collect_str_elems(&g.expr, out),
        Expr::Array(a) => {
            for el in &a.elems {
                collect_str_elems(el, out);
            }
        }
        Expr::Lit(l) => {
            if let Lit::Str(s) = &l.lit {
                out.push((s.value(), s.span().start().line));
            }
        }
        _ => {}
    }
}

struct UseCollector<'a> {
    file: &'a str,
    check: &'a mut MetricsCheck,
}

impl<'ast, 'a> Visit<'ast> for UseCollector<'a> {
    fn visit_item_mod(&mut self, m: &'ast syn::ItemMod) {
        if is_cfg_test(&m.attrs) || m.ident == "tests" {
            return;
        }
        visit::visit_item_mod(self, m);
    }

    fn visit_item_fn(&mut self, f: &'ast syn::ItemFn) {
        if is_cfg_test(&f.attrs) {
            return;
        }
        visit::visit_item_fn(self, f);
    }

    fn visit_item_impl(&mut self, i: &'ast syn::ItemImpl) {
        if is_cfg_test(&i.attrs) {
            return;
        }
        visit::visit_item_impl(self, i);
    }

    fn visit_impl_item_fn(&mut self, f: &'ast syn::ImplItemFn) {
        if is_cfg_test(&f.attrs) {
            return;
        }
        visit::visit_impl_item_fn(self, f);
    }

    fn visit_lit_str(&mut self, l: &'ast syn::LitStr) {
        self.check.literals.insert(l.value());
    }

    fn visit_expr_method_call(&mut self, m: &'ast syn::ExprMethodCall) {
        if m.args.len() == 1
            && matches!(
                m.method.to_string().as_str(),
                "counter" | "gauge" | "histogram"
            )
        {
            if let Expr::Lit(el) = &m.args[0] {
                if let Lit::Str(s) = &el.lit {
                    self.check.uses.push((
                        self.file.to_string(),
                        s.value(),
                        s.span().start().line,
                    ));
                }
            }
        }
        visit::visit_expr_method_call(self, m);
    }
}
