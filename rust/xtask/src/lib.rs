//! Project lints for the engine crate (`cargo xtask lint`).
//!
//! Four families, all driven by `rust/lockorder.toml`:
//!
//! * L1 (`lock-order`, `unranked-lock`, `stale-decl`) — static
//!   lock-hierarchy enforcement over `src/`.
//! * L2 (`condvar-wait`, `condvar-notify`, `condvar-unpaired`) —
//!   condvar discipline: waits loop, notifies hold the paired lock.
//! * L3 (`config-*`) — every `WorkerConfig` knob is documented,
//!   settable, and validated; default clamps run after the knobs they
//!   depend on.
//! * L4 (`metrics-registry`) — every metric name lives exactly once in
//!   `src/metrics/registry.rs`.
//!
//! Plus `ranks-drift`: `src/sync/ranks.rs` (the runtime checker's rank
//! table) must stay generated-equal to the `runtime = true` entries in
//! `lockorder.toml`.
//!
//! The lint is deliberately a plain library function over a directory
//! so the self-tests can point it at fixture trees.

use std::collections::HashMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

pub mod config_knobs;
pub mod lockorder;
pub mod locks;
pub mod metrics_names;

#[derive(Debug, Clone)]
pub struct Violation {
    /// Stable rule slug, e.g. `lock-order`.
    pub rule: &'static str,
    /// Path relative to the crate root (`src/...`).
    pub file: String,
    /// 1-based; 0 when the violation has no anchor line.
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Lint the crate rooted at `root` (the directory holding
/// `lockorder.toml` and `src/`). Returns violations sorted by file and
/// line; `Err` only for infrastructure failures (unreadable files, a
/// malformed `lockorder.toml`).
pub fn run(root: &Path) -> Result<Vec<Violation>, String> {
    let toml_path = root.join("lockorder.toml");
    let text = fs::read_to_string(&toml_path)
        .map_err(|e| format!("{}: {e}", toml_path.display()))?;
    let order = lockorder::parse(&text)?;

    let mut files = Vec::new();
    collect_rs(&root.join("src"), &mut files)?;
    files.sort();

    let registry_rel = "src/metrics/registry.rs";
    let has_registry = files
        .iter()
        .any(|p| rel_of(root, p).as_deref() == Some(registry_rel));

    let mut out = Vec::new();
    let mut metrics = metrics_names::MetricsCheck::new();
    for path in &files {
        let Some(rel) = rel_of(root, path) else { continue };
        let src =
            fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        locks::check_file(&rel, &src, &order, &mut out);
        if rel == "src/config/mod.rs" {
            config_knobs::check_file(&rel, &src, &order.config, &mut out);
        }
        if rel == "src/sync/ranks.rs" {
            check_ranks_drift(&rel, &src, &order, &mut out);
        }
        if has_registry {
            if rel == registry_rel {
                metrics.load_registry(&rel, &src, &mut out);
            } else {
                metrics.collect_file(&rel, &src);
            }
        }
    }
    metrics.finish(&mut out);

    out.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    Ok(out)
}

fn rel_of(root: &Path, path: &Path) -> Option<String> {
    path.strip_prefix(root)
        .ok()
        .map(|p| p.to_string_lossy().replace('\\', "/"))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// `src/sync/ranks.rs` must be generated-equal to the `runtime = true`
/// declarations: same constant set (name uppercased, `.` → `_`), same
/// values. Drift would let the static and runtime checkers enforce two
/// different hierarchies.
fn check_ranks_drift(rel: &str, src: &str, order: &lockorder::LockOrder, out: &mut Vec<Violation>) {
    let ast = match syn::parse_file(src) {
        Ok(a) => a,
        Err(_) => return, // locks.rs reports the parse failure
    };
    let mut consts: HashMap<String, (u16, usize)> = HashMap::new();
    for item in &ast.items {
        if let syn::Item::Const(c) = item {
            let value = match &*c.expr {
                syn::Expr::Lit(l) => match &l.lit {
                    syn::Lit::Int(i) => i.base10_parse::<u16>().ok(),
                    _ => None,
                },
                _ => None,
            };
            if let Some(v) = value {
                use syn::spanned::Spanned;
                consts.insert(c.ident.to_string(), (v, c.ident.span().start().line));
            }
        }
    }
    let mut expected: HashMap<String, u16> = HashMap::new();
    for d in order.locks.iter().filter(|d| d.runtime) {
        expected.insert(d.name.to_uppercase().replace('.', "_"), d.rank);
    }
    for (cname, rank) in &expected {
        match consts.get(cname) {
            None => out.push(Violation {
                rule: "ranks-drift",
                file: rel.to_string(),
                line: 1,
                msg: format!("missing `pub const {cname}: u16 = {rank};` (runtime lock)"),
            }),
            Some((v, line)) if v != rank => out.push(Violation {
                rule: "ranks-drift",
                file: rel.to_string(),
                line: *line,
                msg: format!("`{cname}` is {v} but lockorder.toml declares rank {rank}"),
            }),
            _ => {}
        }
    }
    for (cname, (_, line)) in &consts {
        if !expected.contains_key(cname) {
            out.push(Violation {
                rule: "ranks-drift",
                file: rel.to_string(),
                line: *line,
                msg: format!(
                    "`{cname}` matches no `runtime = true` lock in lockorder.toml"
                ),
            });
        }
    }
}
