//! L3: config-knob completeness for `WorkerConfig`.
//!
//! Every field of `WorkerConfig` must be
//! * documented (`///` on the field),
//! * settable from TOML (its name appears in `apply`, as an ident or a
//!   string — `set_usize!(foo)` and `get("foo")` both count),
//! * range-checked in `validate` — or listed under
//!   `[config] allow_unvalidated` in `lockorder.toml` (enums, bools,
//!   and genuinely free-range integers).
//!
//! `[config] clamp_after = ["a<b"]` additionally pins *statement
//! order* inside `apply`: the default clamp of knob `a` (the statement
//! whose strings mention `a` and whose idents include `is_none`) must
//! run after the TOML setter of knob `b` (the last statement
//! mentioning `b` with no `is_none`). A clamp that reads its dependent
//! knob before that knob's override lands clamps against the default —
//! exactly the bug this check exists to keep fixed.

use std::collections::HashSet;

use syn::spanned::Spanned;
use syn::visit::{self, Visit};
use syn::{ImplItem, Item, Type};

use crate::lockorder::ConfigRules;
use crate::locks::suppressed_lines;
use crate::Violation;

/// Idents and string literals mentioned by one syntax node, macro
/// token streams included (`set_usize!(batch_rows)` mentions
/// `batch_rows`).
#[derive(Default)]
struct Mentions {
    idents: HashSet<String>,
    strings: HashSet<String>,
}

impl Mentions {
    fn of_stmt(stmt: &syn::Stmt) -> Self {
        let mut m = Mentions::default();
        m.visit_stmt(stmt);
        m
    }

    fn mentions(&self, name: &str) -> bool {
        self.idents.contains(name) || self.strings.contains(name)
    }
}

impl<'ast> Visit<'ast> for Mentions {
    fn visit_ident(&mut self, i: &'ast proc_macro2::Ident) {
        self.idents.insert(i.to_string());
    }

    fn visit_lit_str(&mut self, l: &'ast syn::LitStr) {
        self.strings.insert(l.value());
    }

    fn visit_macro(&mut self, m: &'ast syn::Macro) {
        collect_tokens(m.tokens.clone(), self);
        visit::visit_macro(self, m);
    }
}

fn collect_tokens(ts: proc_macro2::TokenStream, m: &mut Mentions) {
    for tt in ts {
        match tt {
            proc_macro2::TokenTree::Group(g) => collect_tokens(g.stream(), m),
            proc_macro2::TokenTree::Ident(i) => {
                m.idents.insert(i.to_string());
            }
            proc_macro2::TokenTree::Literal(l) => {
                let s = l.to_string();
                if s.len() >= 2 && s.starts_with('"') && s.ends_with('"') {
                    m.strings.insert(s[1..s.len() - 1].to_string());
                }
            }
            proc_macro2::TokenTree::Punct(_) => {}
        }
    }
}

pub fn check_file(rel: &str, src: &str, rules: &ConfigRules, out: &mut Vec<Violation>) {
    let suppressed = suppressed_lines(src);
    let ast = match syn::parse_file(src) {
        // locks.rs already reports parse failures for this file.
        Ok(a) => a,
        Err(_) => return,
    };

    let mut fields: Vec<(String, usize, bool)> = Vec::new(); // name, line, has_doc
    let mut struct_line = 0usize;
    let mut apply_stmts: Option<Vec<Mentions>> = None;
    let mut validate_mentions: Option<Mentions> = None;

    for item in &ast.items {
        match item {
            Item::Struct(s) if s.ident == "WorkerConfig" => {
                struct_line = s.ident.span().start().line;
                for f in &s.fields {
                    let Some(ident) = &f.ident else { continue };
                    let has_doc = f.attrs.iter().any(|a| a.path().is_ident("doc"));
                    fields.push((ident.to_string(), f.span().start().line, has_doc));
                }
            }
            Item::Impl(i) => {
                let is_worker_cfg = match &*i.self_ty {
                    Type::Path(tp) => tp
                        .path
                        .segments
                        .last()
                        .map(|s| s.ident == "WorkerConfig")
                        .unwrap_or(false),
                    _ => false,
                };
                if !is_worker_cfg || i.trait_.is_some() {
                    continue;
                }
                for ii in &i.items {
                    if let ImplItem::Fn(f) = ii {
                        if f.sig.ident == "apply" {
                            apply_stmts =
                                Some(f.block.stmts.iter().map(Mentions::of_stmt).collect());
                        } else if f.sig.ident == "validate" {
                            let mut m = Mentions::default();
                            m.visit_block(&f.block);
                            validate_mentions = Some(m);
                        }
                    }
                }
            }
            _ => {}
        }
    }

    if fields.is_empty() {
        return; // not the config file (fixture trees may lack it)
    }

    let mut push = |rule: &'static str, line: usize, msg: String| {
        if suppressed.contains(&line) || (line > 1 && suppressed.contains(&(line - 1))) {
            return;
        }
        out.push(Violation {
            rule,
            file: rel.to_string(),
            line,
            msg,
        });
    };

    let Some(apply_stmts) = apply_stmts else {
        push(
            "config-setter",
            struct_line,
            "WorkerConfig has no inherent `apply` method".to_string(),
        );
        return;
    };
    let Some(validate_mentions) = validate_mentions else {
        push(
            "config-validate",
            struct_line,
            "WorkerConfig has no inherent `validate` method".to_string(),
        );
        return;
    };

    for (name, line, has_doc) in &fields {
        if !has_doc {
            push(
                "config-doc",
                *line,
                format!("`WorkerConfig::{name}` has no doc comment"),
            );
        }
        if !apply_stmts.iter().any(|m| m.mentions(name)) {
            push(
                "config-setter",
                *line,
                format!("`WorkerConfig::{name}` has no TOML setter in `apply`"),
            );
        }
        if !validate_mentions.mentions(name) && !rules.allow_unvalidated.iter().any(|a| a == name)
        {
            push(
                "config-validate",
                *line,
                format!(
                    "`WorkerConfig::{name}` is neither checked in `validate` nor listed \
                     under [config] allow_unvalidated"
                ),
            );
        }
    }

    for (a, b) in &rules.clamp_after {
        // The clamp statement: mentions `a` as a *string* (the
        // `get("a").is_none()` probe) and uses `is_none`.
        let clamp_idx = apply_stmts
            .iter()
            .enumerate()
            .filter(|(_, m)| m.strings.contains(a) && m.idents.contains("is_none"))
            .map(|(i, _)| i)
            .max();
        // The setter statement: last mention of `b` outside any
        // default-clamp (no `is_none`).
        let setter_idx = apply_stmts
            .iter()
            .enumerate()
            .filter(|(_, m)| m.mentions(b) && !m.idents.contains("is_none"))
            .map(|(i, _)| i)
            .max();
        match (clamp_idx, setter_idx) {
            (None, _) => push(
                "config-clamp-order",
                struct_line,
                format!("clamp_after `{a}<{b}`: no default clamp of `{a}` found in `apply`"),
            ),
            (_, None) => push(
                "config-clamp-order",
                struct_line,
                format!("clamp_after `{a}<{b}`: no setter of `{b}` found in `apply`"),
            ),
            (Some(c), Some(s)) if c < s => push(
                "config-clamp-order",
                struct_line,
                format!(
                    "clamp_after `{a}<{b}`: the default clamp of `{a}` (stmt {c}) runs \
                     before the setter of `{b}` (stmt {s}) — it would clamp against the \
                     default, not the override"
                ),
            ),
            _ => {}
        }
    }
}
