//! `cargo xtask <command>` — project task runner.
//!
//! Commands:
//! * `lint` — run the concurrency/config/metrics lints over the engine
//!   crate (see `xtask::run`). Exits non-zero on any violation; CI
//!   treats this as a required gate.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        Some(other) => {
            eprintln!("unknown command `{other}`\n\nusage: cargo xtask lint");
            ExitCode::from(2)
        }
        None => {
            eprintln!("usage: cargo xtask lint");
            ExitCode::from(2)
        }
    }
}

fn lint() -> ExitCode {
    // xtask lives at rust/xtask; the engine crate is its parent.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask has a parent directory")
        .to_path_buf();
    match xtask::run(&root) {
        Ok(violations) if violations.is_empty() => {
            println!("xtask lint: clean");
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                eprintln!("{v}");
            }
            eprintln!("xtask lint: {} violation(s)", violations.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("xtask lint: {e}");
            ExitCode::FAILURE
        }
    }
}
