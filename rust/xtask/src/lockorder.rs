//! Hand-rolled parser for `rust/lockorder.toml`.
//!
//! The file is deliberately a small TOML subset — `[[lock]]` array
//! tables with scalar values, plus one `[config]` table holding string
//! arrays — so the lint has zero parsing dependencies and the format
//! stays too simple to rot. Anything outside that subset is a hard
//! error, not a silent skip.

/// One declared lock: the hierarchy entry for a `Mutex`/`RwLock` field.
#[derive(Debug, Clone)]
pub struct LockDecl {
    /// Hierarchy name, e.g. `outbox.q`.
    pub name: String,
    /// Lower = acquired earlier (outermost). Strictly-greater-than is
    /// required for every acquisition; equal ranks must never nest.
    pub rank: u16,
    /// Declaring file, relative to `rust/` (e.g. `src/memory/pinned.rs`).
    pub file: String,
    /// Declaring struct.
    pub strukt: String,
    /// Field name (`0`, `1`, … for tuple structs).
    pub field: String,
    /// `mutex` or `rwlock`.
    pub kind: LockKind,
    /// Condvar fields paired with this lock (same struct).
    pub condvars: Vec<String>,
    /// `true` when the field is wrapped in `OrderedMutex` and mirrored
    /// as a constant in `src/sync/ranks.rs`.
    pub runtime: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockKind {
    Mutex,
    RwLock,
}

/// The `[config]` table: L3 knob rules.
#[derive(Debug, Clone, Default)]
pub struct ConfigRules {
    /// `WorkerConfig` fields exempt from the must-appear-in-validate
    /// rule (enums, bools, free-range integers).
    pub allow_unvalidated: Vec<String>,
    /// `a<b` pairs: the default clamp of knob `a` must run after the
    /// TOML setter of knob `b` in `WorkerConfig::apply`.
    pub clamp_after: Vec<(String, String)>,
}

#[derive(Debug, Clone)]
pub struct LockOrder {
    pub locks: Vec<LockDecl>,
    pub config: ConfigRules,
}

impl LockOrder {
    /// Locks declared in `file` (path relative to the repo's `rust/`).
    pub fn locks_in_file<'a>(&'a self, file: &str) -> Vec<&'a LockDecl> {
        self.locks.iter().filter(|d| d.file == file).collect()
    }
}

#[derive(Debug, Default)]
struct PartialLock {
    name: Option<String>,
    rank: Option<u16>,
    file: Option<String>,
    strukt: Option<String>,
    field: Option<String>,
    kind: Option<LockKind>,
    condvars: Vec<String>,
    runtime: bool,
}

impl PartialLock {
    fn finish(self, line: usize) -> Result<LockDecl, String> {
        let need = |o: Option<String>, k: &str| {
            o.ok_or_else(|| format!("lockorder.toml:{line}: [[lock]] missing `{k}`"))
        };
        Ok(LockDecl {
            name: need(self.name, "name")?,
            rank: self
                .rank
                .ok_or_else(|| format!("lockorder.toml:{line}: [[lock]] missing `rank`"))?,
            file: need(self.file, "file")?,
            strukt: need(self.strukt, "struct")?,
            field: need(self.field, "field")?,
            kind: self
                .kind
                .ok_or_else(|| format!("lockorder.toml:{line}: [[lock]] missing `kind`"))?,
            condvars: self.condvars,
            runtime: self.runtime,
        })
    }
}

enum Section {
    None,
    Lock(PartialLock, usize),
    Config,
}

pub fn parse(text: &str) -> Result<LockOrder, String> {
    let mut locks: Vec<LockDecl> = Vec::new();
    let mut config = ConfigRules::default();
    let mut section = Section::None;

    // Logical lines: a `key = [` array may span physical lines until
    // its brackets balance (strings in this file never contain `[`,
    // `]`, or `#`, which keeps the scanner honest about staying simple).
    let mut pending = String::new();
    let mut pending_line = 0usize;
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let stripped = strip_comment(raw);
        let trimmed = stripped.trim();
        if trimmed.is_empty() {
            continue;
        }
        if pending.is_empty() {
            pending_line = lineno;
            pending.push_str(trimmed);
        } else {
            pending.push(' ');
            pending.push_str(trimmed);
        }
        let opens = pending.matches('[').count();
        let closes = pending.matches(']').count();
        // Section headers contain balanced brackets; unbalanced means
        // an array literal continues on the next line.
        if opens > closes {
            continue;
        }
        let line = std::mem::take(&mut pending);
        handle_line(&line, pending_line, &mut section, &mut locks, &mut config)?;
    }
    if !pending.is_empty() {
        return Err(format!(
            "lockorder.toml:{pending_line}: unterminated array"
        ));
    }
    if let Section::Lock(p, line) = section {
        locks.push(p.finish(line)?);
    }
    validate(&locks)?;
    Ok(LockOrder { locks, config })
}

fn handle_line(
    line: &str,
    lineno: usize,
    section: &mut Section,
    locks: &mut Vec<LockDecl>,
    config: &mut ConfigRules,
) -> Result<(), String> {
    if line == "[[lock]]" || line == "[config]" {
        if let Section::Lock(p, l) = std::mem::replace(section, Section::None) {
            locks.push(p.finish(l)?);
        }
        *section = if line == "[[lock]]" {
            Section::Lock(PartialLock::default(), lineno)
        } else {
            Section::Config
        };
        return Ok(());
    }
    let (key, value) = line
        .split_once('=')
        .ok_or_else(|| format!("lockorder.toml:{lineno}: expected `key = value`"))?;
    let key = key.trim();
    let value = value.trim();
    match section {
        Section::None => Err(format!(
            "lockorder.toml:{lineno}: `{key}` outside any [[lock]] or [config] table"
        )),
        Section::Lock(p, _) => {
            match key {
                "name" => p.name = Some(parse_string(value, lineno)?),
                "rank" => {
                    p.rank = Some(value.parse::<u16>().map_err(|_| {
                        format!("lockorder.toml:{lineno}: rank must be a u16, got `{value}`")
                    })?)
                }
                "file" => p.file = Some(parse_string(value, lineno)?),
                "struct" => p.strukt = Some(parse_string(value, lineno)?),
                "field" => p.field = Some(parse_string(value, lineno)?),
                "kind" => {
                    p.kind = Some(match parse_string(value, lineno)?.as_str() {
                        "mutex" => LockKind::Mutex,
                        "rwlock" => LockKind::RwLock,
                        other => {
                            return Err(format!(
                                "lockorder.toml:{lineno}: kind must be mutex|rwlock, got `{other}`"
                            ))
                        }
                    })
                }
                "condvars" => p.condvars = parse_string_array(value, lineno)?,
                "runtime" => {
                    p.runtime = match value {
                        "true" => true,
                        "false" => false,
                        other => {
                            return Err(format!(
                                "lockorder.toml:{lineno}: runtime must be true|false, got `{other}`"
                            ))
                        }
                    }
                }
                other => {
                    return Err(format!(
                        "lockorder.toml:{lineno}: unknown [[lock]] key `{other}`"
                    ))
                }
            }
            Ok(())
        }
        Section::Config => {
            match key {
                "allow_unvalidated" => {
                    config.allow_unvalidated = parse_string_array(value, lineno)?
                }
                "clamp_after" => {
                    config.clamp_after = parse_string_array(value, lineno)?
                        .into_iter()
                        .map(|s| {
                            s.split_once('<')
                                .map(|(a, b)| (a.trim().to_string(), b.trim().to_string()))
                                .ok_or_else(|| {
                                    format!(
                                        "lockorder.toml:{lineno}: clamp_after entry `{s}` \
                                         must be `a<b`"
                                    )
                                })
                        })
                        .collect::<Result<Vec<_>, _>>()?
                }
                other => {
                    return Err(format!(
                        "lockorder.toml:{lineno}: unknown [config] key `{other}`"
                    ))
                }
            }
            Ok(())
        }
    }
}

fn validate(locks: &[LockDecl]) -> Result<(), String> {
    for (i, a) in locks.iter().enumerate() {
        for b in &locks[i + 1..] {
            if a.name == b.name {
                return Err(format!("lockorder.toml: duplicate lock name `{}`", a.name));
            }
            if a.file == b.file && a.strukt == b.strukt && a.field == b.field {
                return Err(format!(
                    "lockorder.toml: duplicate declaration for {}::{}.{}",
                    a.file, a.strukt, a.field
                ));
            }
        }
    }
    Ok(())
}

/// Drop a trailing `# comment` (no string in this file contains `#`).
fn strip_comment(line: &str) -> &str {
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_string(value: &str, lineno: usize) -> Result<String, String> {
    let v = value.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        Ok(v[1..v.len() - 1].to_string())
    } else {
        Err(format!(
            "lockorder.toml:{lineno}: expected a quoted string, got `{value}`"
        ))
    }
}

fn parse_string_array(value: &str, lineno: usize) -> Result<Vec<String>, String> {
    let v = value.trim();
    if !(v.starts_with('[') && v.ends_with(']')) {
        return Err(format!(
            "lockorder.toml:{lineno}: expected an array, got `{value}`"
        ));
    }
    let inner = &v[1..v.len() - 1];
    let mut out = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue; // trailing comma
        }
        out.push(parse_string(p, lineno)?);
    }
    Ok(out)
}
