//! Lint self-tests: each fixture tree trips exactly the rule family it
//! was built for, and the real engine tree stays clean.

use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

fn rules_of(root: &Path) -> Vec<String> {
    xtask::run(root)
        .expect("lint infrastructure works")
        .iter()
        .map(|v| v.rule.to_string())
        .collect()
}

fn assert_has(rules: &[String], rule: &str) {
    assert!(
        rules.iter().any(|r| r == rule),
        "expected a `{rule}` violation, got: {rules:?}"
    );
}

#[test]
fn l1_inversion_unranked_stale_drift() {
    let rules = rules_of(&fixture("bad_l1"));
    assert_has(&rules, "lock-order");
    assert_has(&rules, "unranked-lock");
    assert_has(&rules, "stale-decl");
    assert_has(&rules, "ranks-drift");
}

#[test]
fn l2_wait_notify_unpaired() {
    let rules = rules_of(&fixture("bad_l2"));
    assert_has(&rules, "condvar-wait");
    assert_has(&rules, "condvar-notify");
    assert_has(&rules, "condvar-unpaired");
}

#[test]
fn l3_config_knobs() {
    let rules = rules_of(&fixture("bad_l3"));
    assert_has(&rules, "config-doc");
    assert_has(&rules, "config-setter");
    assert_has(&rules, "config-validate");
    assert_has(&rules, "config-clamp-order");
}

#[test]
fn l4_metric_registry() {
    let violations = xtask::run(&fixture("bad_l4")).expect("lint infrastructure works");
    let msgs: Vec<String> = violations.iter().map(|v| v.to_string()).collect();
    assert!(
        msgs.iter().any(|m| m.contains("duplicate") && m.contains("a.dup")),
        "missing duplicate-entry violation: {msgs:?}"
    );
    assert!(
        msgs.iter().any(|m| m.contains("a.unused_entry")),
        "missing unused-entry violation: {msgs:?}"
    );
    assert!(
        msgs.iter().any(|m| m.contains("a.unregistered")),
        "missing unregistered-use violation: {msgs:?}"
    );
}

/// The real tree must pass its own lint: every violation either fixed
/// or carrying an explicit `// lint: lock-ok(<reason>)` marker.
#[test]
fn engine_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits inside the engine crate");
    let violations = xtask::run(root).expect("lint infrastructure works");
    assert!(
        violations.is_empty(),
        "engine tree has lint violations:\n{}",
        violations
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
