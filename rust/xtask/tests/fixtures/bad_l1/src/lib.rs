use std::sync::Mutex;

pub struct S {
    pub outer: Mutex<u32>,
    pub inner: Mutex<u32>,
}

pub struct Naked {
    pub stray: Mutex<u8>,
}

impl S {
    pub fn inverted(&self) -> u32 {
        let i = self.inner.lock().unwrap();
        let o = self.outer.lock().unwrap();
        *i + *o
    }
}
