pub const A_OUTER: u16 = 11;
