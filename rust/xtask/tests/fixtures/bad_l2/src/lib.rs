use std::sync::{Condvar, Mutex};

pub struct Q {
    pub q: Mutex<Vec<u32>>,
    pub ready: Condvar,
}

pub struct W {
    pub w: Mutex<u8>,
    pub orphan: Condvar,
}

impl Q {
    pub fn bad_wait(&self) -> usize {
        let g = self.q.lock().unwrap();
        let g = self.ready.wait(g).unwrap();
        g.len()
    }

    pub fn bad_notify(&self, v: u32) {
        let mut g = self.q.lock().unwrap();
        g.push(v);
        drop(g);
        self.ready.notify_one();
    }
}
