pub mod registry;
