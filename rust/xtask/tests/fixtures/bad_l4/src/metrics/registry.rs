pub const METRIC_NAMES: &[&str] = &[
    "a.used",
    "a.unused_entry",
    "a.dup",
    "a.dup",
];
