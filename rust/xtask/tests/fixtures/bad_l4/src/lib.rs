pub mod metrics;

pub struct M;

impl M {
    pub fn counter(&self, _name: &'static str) -> u64 {
        0
    }
}

pub fn record(m: &M) {
    m.counter("a.used");
    m.counter("a.unregistered");
}
