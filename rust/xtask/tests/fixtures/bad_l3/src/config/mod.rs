pub struct WorkerConfig {
    /// Documented and validated.
    pub alpha: usize,
    pub beta: usize,
    /// Clamp target for `alpha`'s default.
    pub gamma: usize,
}

pub struct Doc;

impl Doc {
    pub fn get(&self, _k: &str) -> Option<usize> {
        None
    }
}

impl WorkerConfig {
    pub fn apply(&mut self, doc: &Doc) {
        if doc.get("alpha").is_none() {
            self.alpha = self.alpha.min(self.gamma);
        }
        if let Some(v) = doc.get("gamma") {
            self.gamma = v;
        }
        if let Some(v) = doc.get("alpha") {
            self.alpha = v;
        }
        self.validate();
    }

    pub fn validate(&self) {
        assert!(self.alpha > 0);
        assert!(self.gamma > 0);
    }
}
