//! Worker-to-worker transport (§3.3.5).
//!
//! The Network Executor sits above this module; here live the frame
//! format and the two back-ends:
//!
//! * [`inproc`] — in-process channels for single-process clusters,
//!   shaped by the profile's TCP or RDMA link spec. This is the default
//!   for benches: the *coordination* is identical to multi-process, and
//!   the wire speed is the modeled quantity anyway.
//! * [`tcp`] — real loopback TCP sockets with length-prefixed frames
//!   (the POSIX back-end the paper's config A uses), additionally
//!   shaped by the modeled link so cloud/on-prem ratios hold.
//!
//! The paper's RDMA back-end differs from TCP in bandwidth and
//! per-message cost, not in semantics — so both back-ends here accept a
//! [`TransportKind`] that selects which link spec shapes them.

pub mod frame;
pub mod inproc;
pub mod tcp;

pub use frame::{Frame, FrameKind, Payload, DEFAULT_MAX_FRAME_BYTES};
pub use inproc::InprocHub;
pub use tcp::{read_frame, TcpCluster};

use std::time::Duration;

use crate::memory::PinnedPool;
use crate::Result;

/// One worker's connection to the fabric.
pub trait Endpoint: Send + Sync {
    /// This worker's id.
    fn worker_id(&self) -> usize;

    /// Number of workers on the fabric.
    fn num_workers(&self) -> usize;

    /// Send a frame to `frame.dst` (modeled wire time is charged here).
    fn send(&self, frame: Frame) -> Result<()>;

    /// Hand the endpoint a page-locked pool to land received payloads
    /// in (§3.4: the pool doubles as the network bounce buffer). The
    /// default is a no-op — the in-proc fabric passes frames by value
    /// and never serializes, so it has nothing to stage.
    fn install_recv_pool(&self, _pool: PinnedPool) {}

    /// Receive the next frame addressed to this worker, waiting up to
    /// `timeout`. `Ok(None)` on timeout.
    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Frame>>;

    /// Bytes put on the wire by this endpoint (after compression).
    fn bytes_sent(&self) -> u64;

    /// Frames sent.
    fn frames_sent(&self) -> u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TransportKind;
    use crate::sim::SimContext;

    /// Both back-ends must satisfy the same contract.
    fn exercise(endpoints: Vec<Box<dyn Endpoint>>) {
        let n = endpoints.len();
        assert!(n >= 3);
        // 0 -> 1, 0 -> 2, 2 -> 1
        endpoints[0]
            .send(Frame::data(0, 1, 7, b"zero to one".to_vec()))
            .unwrap();
        endpoints[0]
            .send(Frame::data(0, 2, 7, b"zero to two".to_vec()))
            .unwrap();
        endpoints[2]
            .send(Frame::data(2, 1, 9, b"two to one".to_vec()))
            .unwrap();

        let t = Duration::from_secs(2);
        let f = endpoints[2].recv_timeout(t).unwrap().unwrap();
        assert_eq!((f.src, f.dst, f.channel), (0, 2, 7));
        assert_eq!(f.payload, b"zero to two");

        let mut got = Vec::new();
        got.push(endpoints[1].recv_timeout(t).unwrap().unwrap());
        got.push(endpoints[1].recv_timeout(t).unwrap().unwrap());
        got.sort_by_key(|f| f.src);
        assert_eq!(got[0].payload, b"zero to one");
        assert_eq!(got[1].payload, b"two to one");

        // control-plane frames cross the same wire: a credit grant
        // arrives with its kind and amount intact
        endpoints[1].send(Frame::credit(1, 0, 3, 17)).unwrap();
        let c = endpoints[0].recv_timeout(t).unwrap().unwrap();
        assert_eq!(c.kind, FrameKind::Credit);
        assert_eq!((c.src, c.dst, c.channel), (1, 0, 3));
        assert_eq!(c.credit_amount().unwrap(), 17);

        // empty inbox times out cleanly
        assert!(endpoints[0]
            .recv_timeout(Duration::from_millis(20))
            .unwrap()
            .is_none());
        assert!(endpoints[0].bytes_sent() > 0);
        assert_eq!(endpoints[0].frames_sent(), 2);
    }

    #[test]
    fn inproc_contract() {
        let hub = InprocHub::new(3, &SimContext::test(), TransportKind::Tcp);
        let eps: Vec<Box<dyn Endpoint>> = hub
            .endpoints()
            .into_iter()
            .map(|e| Box::new(e) as Box<dyn Endpoint>)
            .collect();
        exercise(eps);
    }

    #[test]
    fn tcp_contract() {
        let cluster = TcpCluster::listen(3, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps: Vec<Box<dyn Endpoint>> = cluster
            .into_endpoints()
            .into_iter()
            .map(|e| Box::new(e) as Box<dyn Endpoint>)
            .collect();
        exercise(eps);
    }
}
