//! In-process fabric: per-worker inboxes + shared link throttles.
//!
//! Semantically identical to the TCP back-end (same [`Endpoint`]
//! contract, same modeled wire time); the bytes just move through
//! memory. Used by single-process clusters, tests, and benches, where
//! the modeled link — not the loopback socket — is the quantity under
//! study.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::config::TransportKind;
use crate::network::{Endpoint, Frame};
use crate::sim::{SimContext, Throttle};
use crate::sync::{ranks, OrderedCondvar, OrderedMutex};
use crate::{Error, Result};

struct Inbox {
    q: OrderedMutex<VecDeque<Frame>>,
    ready: OrderedCondvar,
}

impl Inbox {
    fn new() -> Inbox {
        Inbox {
            q: OrderedMutex::new(ranks::INBOX_INPROC_Q, "inbox.inproc_q", VecDeque::new()),
            ready: OrderedCondvar::new(),
        }
    }
}

/// The shared fabric.
pub struct InprocHub {
    inboxes: Vec<Arc<Inbox>>,
    /// One throttle per (src, dst) directed link — concurrent sends to
    /// different peers overlap, sends on one link serialize (a NIC
    /// queue pair / socket).
    links: Vec<Vec<Throttle>>,
    kind: TransportKind,
}

impl InprocHub {
    /// Build an `n`-worker fabric shaped by `ctx` and `kind` (Tcp uses
    /// the profile's `net_tcp` spec, Rdma its `net_rdma`; Rdma falls
    /// back to tcp shaping if the profile has no RDMA — cloud).
    pub fn new(n: usize, ctx: &SimContext, kind: TransportKind) -> Arc<InprocHub> {
        let spec = match kind {
            TransportKind::Rdma => ctx
                .profile
                .net_rdma
                .clone()
                .unwrap_or_else(|| ctx.profile.net_tcp.clone()),
            _ => ctx.profile.net_tcp.clone(),
        };
        Arc::new(InprocHub {
            inboxes: (0..n).map(|_| Arc::new(Inbox::new())).collect(),
            links: (0..n)
                .map(|_| (0..n).map(|_| ctx.throttle(&spec)).collect())
                .collect(),
            kind,
        })
    }

    pub fn kind(&self) -> TransportKind {
        self.kind
    }

    pub fn num_workers(&self) -> usize {
        self.inboxes.len()
    }

    /// One endpoint per worker.
    pub fn endpoints(self: &Arc<Self>) -> Vec<InprocEndpoint> {
        (0..self.num_workers())
            .map(|id| InprocEndpoint {
                hub: self.clone(),
                id,
                bytes: Arc::new(AtomicU64::new(0)),
                frames: Arc::new(AtomicU64::new(0)),
            })
            .collect()
    }

    /// Total modeled busy time across all links (fabric utilization).
    pub fn fabric_busy(&self) -> Duration {
        self.links
            .iter()
            .flatten()
            .map(|t| t.busy())
            .sum()
    }
}

/// One worker's handle to the hub.
#[derive(Clone)]
pub struct InprocEndpoint {
    hub: Arc<InprocHub>,
    id: usize,
    bytes: Arc<AtomicU64>,
    frames: Arc<AtomicU64>,
}

impl Endpoint for InprocEndpoint {
    fn worker_id(&self) -> usize {
        self.id
    }

    fn num_workers(&self) -> usize {
        self.hub.num_workers()
    }

    fn send(&self, frame: Frame) -> Result<()> {
        let dst = frame.dst;
        if dst >= self.hub.num_workers() {
            return Err(Error::Network(format!("no worker {dst}")));
        }
        // charge the modeled wire
        self.hub.links[self.id][dst].acquire(frame.wire_len());
        self.bytes.fetch_add(frame.wire_len() as u64, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
        let inbox = &self.hub.inboxes[dst];
        // notify while the queue lock is held (lost-wakeup defense —
        // see CONCURRENCY.md on wait/notify pairings)
        let mut q = inbox.q.lock();
        q.push_back(frame);
        inbox.ready.notify_one(&q);
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Frame>> {
        let inbox = &self.hub.inboxes[self.id];
        let deadline = std::time::Instant::now() + timeout;
        let mut q = inbox.q.lock();
        loop {
            if let Some(f) = q.pop_front() {
                return Ok(Some(f));
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, _) = inbox.ready.wait_timeout(q, deadline - now);
            q = guard;
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn frames_sent(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{HwProfile, SimContext};

    #[test]
    fn self_send_works() {
        let hub = InprocHub::new(2, &SimContext::test(), TransportKind::Tcp);
        let eps = hub.endpoints();
        eps[0].send(Frame::data(0, 0, 1, vec![9])).unwrap();
        let f = eps[0].recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
        assert_eq!(f.payload, vec![9]);
    }

    #[test]
    fn unknown_destination_is_error() {
        let hub = InprocHub::new(2, &SimContext::test(), TransportKind::Tcp);
        let eps = hub.endpoints();
        assert!(eps[0].send(Frame::data(0, 5, 1, vec![])).is_err());
    }

    #[test]
    fn rdma_links_model_faster_than_tcp() {
        // With on-prem profile and a real time scale, the same bytes
        // take longer on tcp shaping than rdma shaping.
        let ctx = SimContext::new(HwProfile::on_prem(), 0.0);
        let tcp = InprocHub::new(2, &ctx, TransportKind::Tcp);
        let rdma = InprocHub::new(2, &ctx, TransportKind::Rdma);
        let te = tcp.endpoints();
        let re = rdma.endpoints();
        let payload = vec![0u8; 1 << 20];
        te[0].send(Frame::data(0, 1, 0, payload.clone())).unwrap();
        re[0].send(Frame::data(0, 1, 0, payload)).unwrap();
        assert!(
            tcp.fabric_busy() > rdma.fabric_busy(),
            "tcp {:?} vs rdma {:?}",
            tcp.fabric_busy(),
            rdma.fabric_busy()
        );
    }

    #[test]
    fn credit_frames_traverse_the_hub() {
        // Credit grants are ordinary frames to the fabric: they are
        // charged to the modeled link and delivered in order with data.
        let hub = InprocHub::new(2, &SimContext::test(), TransportKind::Tcp);
        let eps = hub.endpoints();
        eps[1].send(Frame::data(1, 0, 0, vec![42])).unwrap();
        eps[1].send(Frame::credit(1, 0, 0, 5)).unwrap();
        let d = eps[0].recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
        assert_eq!(d.payload, vec![42]);
        let c = eps[0].recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
        assert_eq!(c.kind, crate::network::FrameKind::Credit);
        assert_eq!(c.credit_amount().unwrap(), 5);
    }

    #[test]
    fn ordering_preserved_per_link() {
        let hub = InprocHub::new(2, &SimContext::test(), TransportKind::Tcp);
        let eps = hub.endpoints();
        for i in 0..50u8 {
            eps[0].send(Frame::data(0, 1, 0, vec![i])).unwrap();
        }
        for i in 0..50u8 {
            let f = eps[1].recv_timeout(Duration::from_millis(100)).unwrap().unwrap();
            assert_eq!(f.payload, vec![i]);
        }
    }

    #[test]
    fn concurrent_senders_all_arrive() {
        let hub = InprocHub::new(4, &SimContext::test(), TransportKind::Tcp);
        let eps = hub.endpoints();
        let mut handles = Vec::new();
        for src in 1..4 {
            let ep = eps[src].clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u32 {
                    ep.send(Frame::data(src, 0, i, vec![src as u8])).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut n = 0;
        while eps[0]
            .recv_timeout(Duration::from_millis(50))
            .unwrap()
            .is_some()
        {
            n += 1;
        }
        assert_eq!(n, 300);
    }
}
