//! Real loopback-TCP back-end: length-prefixed frames over
//! `TcpStream`s, one reader thread per peer connection, plus the same
//! modeled link shaping as [`super::inproc`] so configuration ablations
//! measure the modeled fabric rather than loopback quirks.
//!
//! Topology: worker `i` listens; worker `j > i` dials `i`. After setup
//! every pair shares one duplex socket.
//!
//! Data movement (§3.4): sends are a 21-byte header-encode followed by
//! one `write_vectored` of the payload's slab chunks — a slab-backed
//! payload is never reassembled into a heap `Vec`. Receives read the
//! header, then land the payload bytes straight into the worker's
//! pinned pool ([`PinnedSlab::from_reader`]) once one is installed via
//! [`Endpoint::install_recv_pool`], falling back to heap buffers while
//! the pool is dry.

use std::collections::VecDeque;
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::TransportKind;
use crate::memory::{PinnedPool, PinnedSlab, SlabSlice};
use crate::network::frame::{Payload, DEFAULT_MAX_FRAME_BYTES, FRAME_HEADER_LEN};
use crate::network::{Endpoint, Frame, FrameKind};
use crate::sim::{SimContext, Throttle};
use crate::sync::{ranks, OrderedCondvar, OrderedMutex};
use crate::{Error, Result};

struct Inbox {
    q: OrderedMutex<VecDeque<Frame>>,
    ready: OrderedCondvar,
}

impl Inbox {
    fn new() -> Inbox {
        Inbox {
            q: OrderedMutex::new(ranks::INBOX_TCP_Q, "inbox.tcp_q", VecDeque::new()),
            ready: OrderedCondvar::new(),
        }
    }
}

/// The receive-side bounce pool, installed after worker bring-up (the
/// cluster listens before workers — and their pools — exist).
#[derive(Default)]
struct RecvPool(Mutex<Option<PinnedPool>>);

struct Peer {
    /// Write half (reads happen on the reader thread).
    stream: Mutex<TcpStream>,
    throttle: Throttle,
}

/// All endpoints of a single-machine TCP cluster.
pub struct TcpCluster {
    endpoints: Vec<TcpEndpoint>,
}

impl TcpCluster {
    /// Bind `n` loopback listeners, fully connect them, spawn reader
    /// threads. Returns the cluster holding one endpoint per worker.
    /// Frames are rejected above [`DEFAULT_MAX_FRAME_BYTES`]; use
    /// [`TcpCluster::listen_with_limit`] to configure the ceiling.
    pub fn listen(n: usize, ctx: &SimContext, kind: TransportKind) -> Result<TcpCluster> {
        TcpCluster::listen_with_limit(n, ctx, kind, DEFAULT_MAX_FRAME_BYTES)
    }

    /// [`TcpCluster::listen`] with an explicit frame-size ceiling
    /// (`WorkerConfig::max_frame_bytes`): reader threads drop a
    /// connection whose length prefix claims more than
    /// `max_frame_bytes`, before allocating anything from the claim.
    pub fn listen_with_limit(
        n: usize,
        ctx: &SimContext,
        kind: TransportKind,
        max_frame_bytes: usize,
    ) -> Result<TcpCluster> {
        let spec = match kind {
            TransportKind::Rdma => ctx
                .profile
                .net_rdma
                .clone()
                .unwrap_or_else(|| ctx.profile.net_tcp.clone()),
            _ => ctx.profile.net_tcp.clone(),
        };
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0"))
            .collect::<std::io::Result<_>>()?;
        let addrs: Vec<_> = listeners
            .iter()
            .map(|l| l.local_addr())
            .collect::<std::io::Result<_>>()?;

        // peers[i][j] = socket between i and j (None for i == j)
        let mut peers: Vec<Vec<Option<TcpStream>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        // Dial from higher ids to lower ids; accept on the listener.
        // Handshake byte identifies the dialer.
        for i in 0..n {
            for j in i + 1..n {
                let mut s = TcpStream::connect(addrs[i])?;
                s.write_all(&(j as u32).to_le_bytes())?;
                peers[j][i] = Some(s);
            }
            // accept the n-1-i dialers
            for _ in i + 1..n {
                let (mut s, _) = listeners[i].accept()?;
                let mut id = [0u8; 4];
                s.read_exact(&mut id)?;
                let j = u32::from_le_bytes(id) as usize;
                peers[i][j] = Some(s);
            }
        }

        let shutdown = Arc::new(AtomicBool::new(false));
        let mut endpoints = Vec::with_capacity(n);
        for (i, row) in peers.into_iter().enumerate() {
            let inbox = Arc::new(Inbox::new());
            let recv_pool = Arc::new(RecvPool::default());
            let mut peer_handles = Vec::with_capacity(n);
            for (j, sock) in row.into_iter().enumerate() {
                match sock {
                    None => peer_handles.push(None),
                    Some(s) => {
                        s.set_nodelay(true).ok();
                        // reader thread for this connection
                        let rs = s.try_clone()?;
                        let inbox2 = inbox.clone();
                        let stop = shutdown.clone();
                        let pool = recv_pool.clone();
                        std::thread::Builder::new()
                            .name(format!("theseus-net-{i}-{j}"))
                            .spawn(move || {
                                reader_loop(rs, inbox2, stop, pool, max_frame_bytes)
                            })
                            .map_err(|e| Error::Network(e.to_string()))?;
                        peer_handles.push(Some(Peer {
                            stream: Mutex::new(s),
                            throttle: ctx.throttle(&spec),
                        }));
                    }
                }
            }
            endpoints.push(TcpEndpoint {
                id: i,
                n,
                peers: Arc::new(peer_handles),
                inbox,
                recv_pool,
                loopback_throttle: ctx.throttle(&spec),
                bytes: Arc::new(AtomicU64::new(0)),
                frames: Arc::new(AtomicU64::new(0)),
                shutdown: shutdown.clone(), // all endpoints share the flag
            });
        }
        Ok(TcpCluster { endpoints })
    }

    pub fn into_endpoints(self) -> Vec<TcpEndpoint> {
        self.endpoints
    }
}

/// `Read` adapter that retries the socket's 200 ms timeouts (unless
/// shutting down). `read_exact` through it is the one full-read
/// primitive of the receive path: length prefix, header, heap-fallback
/// payloads, and — via [`PinnedSlab::from_reader`] — pinned payloads.
struct RetryRead<'a> {
    s: &'a mut TcpStream,
    stop: &'a AtomicBool,
}

impl Read for RetryRead<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            match self.s.read(buf) {
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if self.stop.load(Ordering::Relaxed) {
                        return Err(e);
                    }
                }
                other => return other,
            }
        }
    }
}

/// Decode one frame from `r` after its 8-byte length prefix (`total` =
/// header + payload bytes) has been consumed — the receive path shared
/// by the reader threads and the frame round-trip property tests.
///
/// `total` and the header's payload length arrive from the wire and are
/// never trusted for allocation until validated: frames above
/// `max_frame_bytes` are rejected outright (a corrupt or hostile length
/// prefix must not size a buffer), and the two length fields must
/// agree.
///
/// `pool` is queried lazily, and only for Data payloads: control-plane
/// frames (estimates, plans) are tiny and would waste a whole
/// fixed-size buffer each, so they stay on the heap without ever
/// touching the pool source (the reader thread's source takes a lock).
/// Data payloads land straight in the pool when it is installed and
/// has room (§3.4 bounce buffers); a dry pool heap-falls-back
/// ([`PinnedSlab::from_reader`] fails *before* consuming the reader,
/// so the fallback still reads a whole payload).
pub fn read_frame(
    r: &mut impl Read,
    total: usize,
    max_frame_bytes: usize,
    pool: impl FnOnce() -> Option<PinnedPool>,
) -> Result<Frame> {
    if total < FRAME_HEADER_LEN {
        // A malformed length means the framing is lost — there is no
        // way to resync a length-prefixed stream; the caller must drop
        // the connection.
        return Err(Error::Network(format!("bad frame length {total}")));
    }
    if total > max_frame_bytes {
        return Err(Error::Network(format!(
            "frame length {total} exceeds max_frame_bytes {max_frame_bytes}"
        )));
    }
    let mut header = [0u8; FRAME_HEADER_LEN];
    r.read_exact(&mut header)?;
    let (kind, src, dst, channel, plen) = Frame::decode_header(&header)?;
    if plen != total - FRAME_HEADER_LEN {
        return Err(Error::Network(format!(
            "payload length {plen} disagrees with frame length {total}"
        )));
    }
    let payload = if plen == 0 {
        Payload::Heap(Vec::new())
    } else {
        let mut staged = None;
        if kind == FrameKind::Data {
            if let Some(p) = pool() {
                match PinnedSlab::from_reader(&p, r, plen) {
                    Ok(slab) => {
                        staged = Some(Payload::pinned(Vec::new(), SlabSlice::whole(slab)))
                    }
                    // dry pool fails before consuming bytes: heap below
                    Err(Error::PinnedExhausted { .. }) => {}
                    // socket died mid-payload: the stream is lost
                    Err(e) => return Err(e),
                }
            }
        }
        match staged {
            Some(p) => p,
            None => {
                let mut buf = vec![0u8; plen];
                r.read_exact(&mut buf)?;
                Payload::Heap(buf)
            }
        }
    };
    Ok(Frame { kind, src, dst, channel, payload })
}

fn reader_loop(
    mut s: TcpStream,
    inbox: Arc<Inbox>,
    stop: Arc<AtomicBool>,
    pool: Arc<RecvPool>,
    max_frame_bytes: usize,
) {
    s.set_read_timeout(Some(Duration::from_millis(200))).ok();
    let mut lenbuf = [0u8; 8];
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        if (RetryRead { s: &mut s, stop: &stop }).read_exact(&mut lenbuf).is_err() {
            return; // peer closed or shutdown
        }
        let total = u64::from_le_bytes(lenbuf) as usize;
        let mut rr = RetryRead { s: &mut s, stop: &stop };
        let frame = match read_frame(&mut rr, total, max_frame_bytes, || {
            pool.0.lock().unwrap().clone()
        }) {
            Ok(f) => f,
            Err(e) => {
                // Loudly (unless shutting down): a silent return here
                // reads as an idle peer at the exchange layer.
                if !stop.load(Ordering::Relaxed) {
                    log::warn!("tcp reader: {e}, dropping connection");
                }
                return;
            }
        };
        // Injected receive fault = the connection died mid-frame: the
        // decoded frame is discarded and the reader drops the
        // connection, exactly like a real truncated stream.
        if let Err(e) = crate::fault::check(crate::fault::FaultSite::NetRecv) {
            if !stop.load(Ordering::Relaxed) {
                log::warn!("tcp reader: {e}, dropping connection");
            }
            return;
        }
        // notify while the queue lock is held: the receiver re-checks
        // emptiness under this lock, so an unlocked notify could land
        // between its check and its park and be lost
        let mut q = inbox.q.lock();
        q.push_back(frame);
        inbox.ready.notify_one(&q);
    }
}

/// Write every part, restarting the vectored write where it left off on
/// short writes (hand-rolled: `IoSlice::advance_slices` needs a newer
/// toolchain than this crate's MSRV).
fn write_all_vectored(s: &mut TcpStream, parts: &[&[u8]]) -> std::io::Result<()> {
    let mut idx = 0usize;
    let mut off = 0usize;
    while idx < parts.len() {
        if off >= parts[idx].len() {
            idx += 1;
            off = 0;
            continue;
        }
        let mut iov: Vec<IoSlice> = Vec::with_capacity(parts.len() - idx);
        iov.push(IoSlice::new(&parts[idx][off..]));
        for p in &parts[idx + 1..] {
            if !p.is_empty() {
                iov.push(IoSlice::new(p));
            }
        }
        let mut n = s.write_vectored(&iov)?;
        if n == 0 {
            return Err(std::io::ErrorKind::WriteZero.into());
        }
        while n > 0 && idx < parts.len() {
            let rem = parts[idx].len() - off;
            if n >= rem {
                n -= rem;
                idx += 1;
                off = 0;
            } else {
                off += n;
                n = 0;
            }
        }
    }
    Ok(())
}

/// One worker's TCP endpoint.
pub struct TcpEndpoint {
    id: usize,
    n: usize,
    peers: Arc<Vec<Option<Peer>>>,
    inbox: Arc<Inbox>,
    /// Shared with this endpoint's reader threads; filled in by
    /// [`Endpoint::install_recv_pool`] once the worker's pool exists.
    recv_pool: Arc<RecvPool>,
    /// Self-sends skip the socket but still pay the modeled wire.
    loopback_throttle: Throttle,
    bytes: Arc<AtomicU64>,
    frames: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

impl Endpoint for TcpEndpoint {
    fn worker_id(&self) -> usize {
        self.id
    }

    fn num_workers(&self) -> usize {
        self.n
    }

    fn send(&self, frame: Frame) -> Result<()> {
        crate::fault::check(crate::fault::FaultSite::NetSend)?;
        let dst = frame.dst;
        if dst >= self.n {
            return Err(Error::Network(format!("no worker {dst}")));
        }
        self.bytes.fetch_add(frame.wire_len() as u64, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
        if dst == self.id {
            self.loopback_throttle.acquire(frame.wire_len());
            let mut q = self.inbox.q.lock();
            q.push_back(frame);
            self.inbox.ready.notify_one(&q);
            return Ok(());
        }
        let peer = self.peers[dst]
            .as_ref()
            .ok_or_else(|| Error::Network(format!("no connection to {dst}")))?;
        peer.throttle.acquire(frame.wire_len());
        // header-encode + one vectored write of the payload chunks: a
        // slab payload goes from pool buffers to the socket directly
        let lenb = (frame.wire_len() as u64).to_le_bytes();
        let header = frame.encode_header();
        let chunks = frame.payload.chunks();
        let mut parts: Vec<&[u8]> = Vec::with_capacity(2 + chunks.len());
        parts.push(&lenb);
        parts.push(&header);
        parts.extend_from_slice(&chunks);
        let mut s = peer.stream.lock().unwrap();
        write_all_vectored(&mut s, &parts)
            .map_err(|e| Error::Network(format!("send to {dst}: {e}")))
    }

    fn install_recv_pool(&self, pool: PinnedPool) {
        *self.recv_pool.0.lock().unwrap() = Some(pool);
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Frame>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut q = self.inbox.q.lock();
        loop {
            if let Some(f) = q.pop_front() {
                return Ok(Some(f));
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, _) = self.inbox.ready.wait_timeout(q, deadline - now);
            q = guard;
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn frames_sent(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimContext;

    #[test]
    fn two_workers_roundtrip() {
        let c = TcpCluster::listen(2, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        eps[0].send(Frame::data(0, 1, 3, vec![1, 2, 3])).unwrap();
        eps[1].send(Frame::data(1, 0, 4, vec![4])).unwrap();
        let a = eps[1].recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(a.channel, 3);
        assert_eq!(a.payload, vec![1, 2, 3]);
        let b = eps[0].recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(b.channel, 4);
    }

    #[test]
    fn slab_payload_sends_vectored_and_lands_pinned() {
        use crate::memory::{PinnedPool, PinnedSlab, SlabSlice};
        use crate::network::frame::Payload;
        let c = TcpCluster::listen(2, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        // receiver gets a bounce pool: payloads land in pinned buffers
        let rx_pool = PinnedPool::new(64, 32).unwrap();
        eps[1].install_recv_pool(rx_pool.clone());

        // sender wraps a multi-buffer slab (vectored write path)
        let tx_pool = PinnedPool::new(64, 32).unwrap();
        let body: Vec<u8> = (0..200u32).map(|i| i as u8).collect();
        let slab = PinnedSlab::write(&tx_pool, &body).unwrap();
        assert!(slab.num_buffers() > 1);
        let frame = Frame::data_payload(
            0,
            1,
            5,
            Payload::pinned(vec![0xEE], SlabSlice::whole(slab)),
        );
        eps[0].send(frame).unwrap();

        let got = eps[1].recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        let mut want = vec![0xEE];
        want.extend_from_slice(&body);
        assert_eq!(got.payload, want);
        assert!(got.payload.is_pinned(), "payload must land in the pool");
        assert!(rx_pool.acquire_count() > 0);
        drop(got);
        assert_eq!(rx_pool.free_buffers(), 32, "payload buffers returned");

        // pool exhausted: receive falls back to heap, bytes intact
        let hold: Vec<_> = (0..32).map(|_| rx_pool.try_acquire().unwrap()).collect();
        eps[0].send(Frame::data(0, 1, 6, body.clone())).unwrap();
        let got = eps[1].recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert!(!got.payload.is_pinned(), "dry pool must fall back to heap");
        assert_eq!(got.payload, body);
        drop(hold);
    }

    #[test]
    fn oversized_length_prefix_rejected_without_allocation() {
        // A hostile/corrupt length prefix must be rejected before any
        // buffer is sized from it.
        let mut cur = std::io::Cursor::new(Vec::<u8>::new());
        let r = read_frame(&mut cur, usize::MAX, DEFAULT_MAX_FRAME_BYTES, || None);
        assert!(r.is_err(), "claimed length above the ceiling must error");

        // A configured ceiling drops the connection instead of buffering.
        let c = TcpCluster::listen_with_limit(2, &SimContext::test(), TransportKind::Tcp, 64)
            .unwrap();
        let eps = c.into_endpoints();
        eps[0].send(Frame::data(0, 1, 1, vec![1, 2, 3])).unwrap();
        let got = eps[1].recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(got.payload, vec![1, 2, 3]);
        eps[0].send(Frame::data(0, 1, 2, vec![0; 256])).unwrap();
        assert!(
            eps[1].recv_timeout(Duration::from_millis(300)).unwrap().is_none(),
            "oversized frame must be dropped with its connection"
        );
    }

    #[test]
    fn credit_frames_cross_the_socket_intact() {
        // Credit grants ride the same length-prefixed wire as data;
        // the 8-byte amount must survive serialization and parse back
        // on the far side (tag 4, heap payload — never pooled).
        let c = TcpCluster::listen(2, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        let rx_pool = crate::memory::PinnedPool::new(64, 4).unwrap();
        eps[0].install_recv_pool(rx_pool.clone());
        eps[1].send(Frame::credit(1, 0, 2, 9)).unwrap();
        let f = eps[0].recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(f.kind, crate::network::FrameKind::Credit);
        assert_eq!((f.src, f.channel), (1, 2));
        assert_eq!(f.credit_amount().unwrap(), 9);
        assert!(!f.payload.is_pinned(), "control payloads stay on the heap");
    }

    #[test]
    fn self_send_via_loopback() {
        let c = TcpCluster::listen(2, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        eps[1].send(Frame::data(1, 1, 9, vec![7])).unwrap();
        let f = eps[1].recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(f.payload, vec![7]);
    }

    #[test]
    fn large_frames_cross_intact() {
        let c = TcpCluster::listen(2, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        let payload: Vec<u8> = (0..1_000_000u32).map(|i| i as u8).collect();
        eps[0].send(Frame::data(0, 1, 0, payload.clone())).unwrap();
        let f = eps[1].recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(f.payload, payload);
    }

    #[test]
    fn concurrent_sends_interleave_safely() {
        let c = TcpCluster::listen(3, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        let e1 = Arc::new(eps);
        let mut handles = Vec::new();
        for src in [0usize, 2] {
            let eps = e1.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u32 {
                    eps[src]
                        .send(Frame::data(src, 1, i, vec![src as u8; 100]))
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut n = 0;
        while e1[1]
            .recv_timeout(Duration::from_millis(300))
            .unwrap()
            .is_some()
        {
            n += 1;
        }
        assert_eq!(n, 100);
    }
}
