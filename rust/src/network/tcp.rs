//! Real loopback-TCP back-end: length-prefixed frames over
//! `TcpStream`s, one reader thread per peer connection, plus the same
//! modeled link shaping as [`super::inproc`] so configuration ablations
//! measure the modeled fabric rather than loopback quirks.
//!
//! Topology: worker `i` listens; worker `j > i` dials `i`. After setup
//! every pair shares one duplex socket.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::config::TransportKind;
use crate::network::{Endpoint, Frame};
use crate::sim::{SimContext, Throttle};
use crate::{Error, Result};

struct Inbox {
    q: Mutex<VecDeque<Frame>>,
    ready: Condvar,
}

struct Peer {
    /// Write half (reads happen on the reader thread).
    stream: Mutex<TcpStream>,
    throttle: Throttle,
}

/// All endpoints of a single-machine TCP cluster.
pub struct TcpCluster {
    endpoints: Vec<TcpEndpoint>,
}

impl TcpCluster {
    /// Bind `n` loopback listeners, fully connect them, spawn reader
    /// threads. Returns the cluster holding one endpoint per worker.
    pub fn listen(n: usize, ctx: &SimContext, kind: TransportKind) -> Result<TcpCluster> {
        let spec = match kind {
            TransportKind::Rdma => ctx
                .profile
                .net_rdma
                .clone()
                .unwrap_or_else(|| ctx.profile.net_tcp.clone()),
            _ => ctx.profile.net_tcp.clone(),
        };
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0"))
            .collect::<std::io::Result<_>>()?;
        let addrs: Vec<_> = listeners
            .iter()
            .map(|l| l.local_addr())
            .collect::<std::io::Result<_>>()?;

        // peers[i][j] = socket between i and j (None for i == j)
        let mut peers: Vec<Vec<Option<TcpStream>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        // Dial from higher ids to lower ids; accept on the listener.
        // Handshake byte identifies the dialer.
        for i in 0..n {
            for j in i + 1..n {
                let mut s = TcpStream::connect(addrs[i])?;
                s.write_all(&(j as u32).to_le_bytes())?;
                peers[j][i] = Some(s);
            }
            // accept the n-1-i dialers
            for _ in i + 1..n {
                let (mut s, _) = listeners[i].accept()?;
                let mut id = [0u8; 4];
                s.read_exact(&mut id)?;
                let j = u32::from_le_bytes(id) as usize;
                peers[i][j] = Some(s);
            }
        }

        let shutdown = Arc::new(AtomicBool::new(false));
        let mut endpoints = Vec::with_capacity(n);
        for (i, row) in peers.into_iter().enumerate() {
            let inbox = Arc::new(Inbox { q: Mutex::new(VecDeque::new()), ready: Condvar::new() });
            let mut peer_handles = Vec::with_capacity(n);
            for (j, sock) in row.into_iter().enumerate() {
                match sock {
                    None => peer_handles.push(None),
                    Some(s) => {
                        s.set_nodelay(true).ok();
                        // reader thread for this connection
                        let rs = s.try_clone()?;
                        let inbox2 = inbox.clone();
                        let stop = shutdown.clone();
                        std::thread::Builder::new()
                            .name(format!("theseus-net-{i}-{j}"))
                            .spawn(move || reader_loop(rs, inbox2, stop))
                            .map_err(|e| Error::Network(e.to_string()))?;
                        peer_handles.push(Some(Peer {
                            stream: Mutex::new(s),
                            throttle: ctx.throttle(&spec),
                        }));
                    }
                }
            }
            endpoints.push(TcpEndpoint {
                id: i,
                n,
                peers: Arc::new(peer_handles),
                inbox,
                loopback_throttle: ctx.throttle(&spec),
                bytes: Arc::new(AtomicU64::new(0)),
                frames: Arc::new(AtomicU64::new(0)),
                shutdown: shutdown.clone(), // all endpoints share the flag
            });
        }
        Ok(TcpCluster { endpoints })
    }

    pub fn into_endpoints(self) -> Vec<TcpEndpoint> {
        self.endpoints
    }
}

fn reader_loop(mut s: TcpStream, inbox: Arc<Inbox>, stop: Arc<AtomicBool>) {
    s.set_read_timeout(Some(Duration::from_millis(200))).ok();
    let mut lenbuf = [0u8; 8];
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match s.read_exact(&mut lenbuf) {
            Ok(()) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return, // peer closed
        }
        let len = u64::from_le_bytes(lenbuf) as usize;
        let mut buf = vec![0u8; len];
        // body read: spin on timeouts until complete
        let mut off = 0;
        while off < len {
            match s.read(&mut buf[off..]) {
                Ok(0) => return,
                Ok(k) => off += k,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                }
                Err(_) => return,
            }
        }
        if let Ok(f) = Frame::decode(&buf) {
            inbox.q.lock().unwrap().push_back(f);
            inbox.ready.notify_one();
        }
    }
}

/// One worker's TCP endpoint.
pub struct TcpEndpoint {
    id: usize,
    n: usize,
    peers: Arc<Vec<Option<Peer>>>,
    inbox: Arc<Inbox>,
    /// Self-sends skip the socket but still pay the modeled wire.
    loopback_throttle: Throttle,
    bytes: Arc<AtomicU64>,
    frames: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
}

impl Drop for TcpEndpoint {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

impl Endpoint for TcpEndpoint {
    fn worker_id(&self) -> usize {
        self.id
    }

    fn num_workers(&self) -> usize {
        self.n
    }

    fn send(&self, frame: Frame) -> Result<()> {
        let dst = frame.dst;
        if dst >= self.n {
            return Err(Error::Network(format!("no worker {dst}")));
        }
        self.bytes.fetch_add(frame.wire_len() as u64, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
        if dst == self.id {
            self.loopback_throttle.acquire(frame.wire_len());
            self.inbox.q.lock().unwrap().push_back(frame);
            self.inbox.ready.notify_one();
            return Ok(());
        }
        let peer = self.peers[dst]
            .as_ref()
            .ok_or_else(|| Error::Network(format!("no connection to {dst}")))?;
        peer.throttle.acquire(frame.wire_len());
        let buf = frame.encode();
        let mut s = peer.stream.lock().unwrap();
        s.write_all(&(buf.len() as u64).to_le_bytes())
            .and_then(|_| s.write_all(&buf))
            .map_err(|e| Error::Network(format!("send to {dst}: {e}")))
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Frame>> {
        let deadline = std::time::Instant::now() + timeout;
        let mut q = self.inbox.q.lock().unwrap();
        loop {
            if let Some(f) = q.pop_front() {
                return Ok(Some(f));
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, _) = self.inbox.ready.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn frames_sent(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimContext;

    #[test]
    fn two_workers_roundtrip() {
        let c = TcpCluster::listen(2, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        eps[0].send(Frame::data(0, 1, 3, vec![1, 2, 3])).unwrap();
        eps[1].send(Frame::data(1, 0, 4, vec![4])).unwrap();
        let a = eps[1].recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!((a.channel, a.payload.clone()), (3, vec![1, 2, 3]));
        let b = eps[0].recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        assert_eq!(b.channel, 4);
    }

    #[test]
    fn self_send_via_loopback() {
        let c = TcpCluster::listen(2, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        eps[1].send(Frame::data(1, 1, 9, vec![7])).unwrap();
        let f = eps[1].recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(f.payload, vec![7]);
    }

    #[test]
    fn large_frames_cross_intact() {
        let c = TcpCluster::listen(2, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        let payload: Vec<u8> = (0..1_000_000u32).map(|i| i as u8).collect();
        eps[0].send(Frame::data(0, 1, 0, payload.clone())).unwrap();
        let f = eps[1].recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(f.payload, payload);
    }

    #[test]
    fn concurrent_sends_interleave_safely() {
        let c = TcpCluster::listen(3, &SimContext::test(), TransportKind::Tcp).unwrap();
        let eps = c.into_endpoints();
        let e1 = Arc::new(eps);
        let mut handles = Vec::new();
        for src in [0usize, 2] {
            let eps = e1.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u32 {
                    eps[src]
                        .send(Frame::data(src, 1, i, vec![src as u8; 100]))
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut n = 0;
        while e1[1]
            .recv_timeout(Duration::from_millis(300))
            .unwrap()
            .is_some()
        {
            n += 1;
        }
        assert_eq!(n, 100);
    }
}
