//! Wire frame: the unit the Network Executor sends and receives.
//!
//! A frame's payload is an encoded (and possibly compressed)
//! [`crate::types::RecordBatch`]; control frames (size estimates for
//! the Adaptive Exchange, end-of-stream markers) carry small payloads.
//! The codec tag travels inside the payload (see
//! `storage::compression`), so sender and receiver never need matching
//! configuration.

use crate::util::bytes::{Reader, Writer};
use crate::{Error, Result};

/// What a frame means to the receiving worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// A batch of rows for `channel`.
    Data,
    /// The sender's estimated total bytes for this exchange (§3.2: the
    /// Adaptive Exchange broadcasts estimates before phase two).
    SizeEstimate,
    /// Sender will produce no more data frames on `channel`.
    Finish,
    /// Cluster control (plan distribution, query lifecycle).
    Control,
}

impl FrameKind {
    fn tag(self) -> u8 {
        match self {
            FrameKind::Data => 0,
            FrameKind::SizeEstimate => 1,
            FrameKind::Finish => 2,
            FrameKind::Control => 3,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => FrameKind::Data,
            1 => FrameKind::SizeEstimate,
            2 => FrameKind::Finish,
            3 => FrameKind::Control,
            _ => return Err(Error::Network(format!("bad frame kind {t}"))),
        })
    }
}

/// One message on the fabric.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub src: usize,
    pub dst: usize,
    /// Logical channel: identifies the exchange edge within the query
    /// DAG (operator id on the receiving side).
    pub channel: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn data(src: usize, dst: usize, channel: u32, payload: Vec<u8>) -> Frame {
        Frame { kind: FrameKind::Data, src, dst, channel, payload }
    }

    pub fn finish(src: usize, dst: usize, channel: u32) -> Frame {
        Frame { kind: FrameKind::Finish, src, dst, channel, payload: Vec::new() }
    }

    pub fn size_estimate(src: usize, dst: usize, channel: u32, bytes: u64) -> Frame {
        Frame {
            kind: FrameKind::SizeEstimate,
            src,
            dst,
            channel,
            payload: bytes.to_le_bytes().to_vec(),
        }
    }

    pub fn control(src: usize, dst: usize, payload: Vec<u8>) -> Frame {
        Frame { kind: FrameKind::Control, src, dst, channel: 0, payload }
    }

    /// Estimate payload for a SizeEstimate frame.
    pub fn estimate_bytes(&self) -> Result<u64> {
        if self.kind != FrameKind::SizeEstimate || self.payload.len() != 8 {
            return Err(Error::Network("not a size-estimate frame".into()));
        }
        Ok(u64::from_le_bytes(self.payload[..8].try_into().unwrap()))
    }

    /// Bytes on the wire (header + payload) — what throttles charge.
    pub fn wire_len(&self) -> usize {
        FRAME_HEADER_LEN + self.payload.len()
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(self.wire_len());
        w.u8(self.kind.tag());
        w.u32(self.src as u32);
        w.u32(self.dst as u32);
        w.u32(self.channel);
        w.bytes(&self.payload);
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<Frame> {
        let mut r = Reader::new(buf);
        let kind = FrameKind::from_tag(r.u8()?)?;
        let src = r.u32()? as usize;
        let dst = r.u32()? as usize;
        let channel = r.u32()?;
        let payload = r.bytes()?.to_vec();
        Ok(Frame { kind, src, dst, channel, payload })
    }
}

/// kind(1) + src(4) + dst(4) + channel(4) + len(8)
pub const FRAME_HEADER_LEN: usize = 21;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip_all_kinds() {
        let frames = vec![
            Frame::data(1, 2, 42, vec![1, 2, 3]),
            Frame::finish(0, 3, 7),
            Frame::size_estimate(2, 0, 9, 123_456_789),
            Frame::control(0, 1, b"plan".to_vec()),
        ];
        for f in frames {
            let buf = f.encode();
            assert_eq!(buf.len(), f.wire_len());
            assert_eq!(Frame::decode(&buf).unwrap(), f);
        }
    }

    #[test]
    fn size_estimate_accessor() {
        let f = Frame::size_estimate(0, 1, 2, 999);
        assert_eq!(f.estimate_bytes().unwrap(), 999);
        assert!(Frame::finish(0, 1, 2).estimate_bytes().is_err());
    }

    #[test]
    fn truncated_frame_rejected() {
        let buf = Frame::data(0, 1, 2, vec![5; 100]).encode();
        assert!(Frame::decode(&buf[..10]).is_err());
        assert!(Frame::decode(&buf[..buf.len() - 1]).is_err());
    }

    #[test]
    fn bad_kind_rejected() {
        let mut buf = Frame::finish(0, 1, 2).encode();
        buf[0] = 99;
        assert!(Frame::decode(&buf).is_err());
    }
}
