//! Wire frame: the unit the Network Executor sends and receives.
//!
//! A frame's payload is an encoded (and possibly compressed)
//! [`crate::types::RecordBatch`]; control frames (size estimates for
//! the Adaptive Exchange, end-of-stream markers) carry small payloads.
//! The codec tag travels inside the payload (see
//! `storage::compression`), so sender and receiver never need matching
//! configuration.
//!
//! Payloads are carried as [`Payload`]: either heap bytes or a
//! slab-backed view into the §3.4 pinned bounce pool. The wire format
//! is a fixed 21-byte header ([`Frame::encode_header`]) followed by the
//! payload bytes; the TCP back-end `write_vectored`s the header and the
//! slab's buffers in one syscall instead of reassembling them (the old
//! `encode()`-to-one-`Vec` path), and the receive side lands payloads
//! straight into pool buffers ([`crate::memory::PinnedSlab::from_reader`]).

use std::borrow::Cow;

use crate::memory::SlabSlice;
use crate::{Error, Result};

/// What a frame means to the receiving worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// A batch of rows for `channel`.
    Data,
    /// The sender's estimated total bytes for this exchange (§3.2: the
    /// Adaptive Exchange broadcasts estimates before phase two).
    SizeEstimate,
    /// Sender will produce no more data frames on `channel`.
    Finish,
    /// Cluster control (plan distribution, query lifecycle).
    Control,
    /// Flow-control grant: the receiver has drained delivered batches
    /// and returns that many send credits to `dst` (the original
    /// sender). Senders stop popping data frames for a destination at
    /// zero credit, so a slow receiver throttles its senders instead of
    /// growing their outboxes. Credit frames themselves are exempt from
    /// credit accounting, like Finish and Control.
    Credit,
}

impl FrameKind {
    fn tag(self) -> u8 {
        match self {
            FrameKind::Data => 0,
            FrameKind::SizeEstimate => 1,
            FrameKind::Finish => 2,
            FrameKind::Control => 3,
            FrameKind::Credit => 4,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => FrameKind::Data,
            1 => FrameKind::SizeEstimate,
            2 => FrameKind::Finish,
            3 => FrameKind::Control,
            4 => FrameKind::Credit,
            _ => return Err(Error::Network(format!("bad frame kind {t}"))),
        })
    }
}

/// A frame's payload bytes.
pub enum Payload {
    /// Plain heap bytes (control frames, pool-dry fallback).
    Heap(Vec<u8>),
    /// A short heap prelude (codec framing, built at send time)
    /// followed by slab-backed body bytes. The send path wraps a Batch
    /// Holder's slab here without copying; the receive path lands whole
    /// payloads here with an empty prelude.
    Pinned { prelude: Vec<u8>, body: SlabSlice },
}

impl Payload {
    pub fn pinned(prelude: Vec<u8>, body: SlabSlice) -> Payload {
        Payload::Pinned { prelude, body }
    }

    pub fn len(&self) -> usize {
        match self {
            Payload::Heap(v) => v.len(),
            Payload::Pinned { prelude, body } => prelude.len() + body.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_pinned(&self) -> bool {
        matches!(self, Payload::Pinned { .. })
    }

    /// The payload bytes as vectored chunks (no reassembly): the
    /// prelude (if any) followed by the slab's per-buffer slices.
    pub fn chunks(&self) -> Vec<&[u8]> {
        match self {
            Payload::Heap(v) if v.is_empty() => Vec::new(),
            Payload::Heap(v) => vec![v.as_slice()],
            Payload::Pinned { prelude, body } => {
                let body_chunks = body.chunks();
                let mut out = Vec::with_capacity(1 + body_chunks.len());
                if !prelude.is_empty() {
                    out.push(prelude.as_slice());
                }
                out.extend(body_chunks);
                out
            }
        }
    }

    /// Contiguous view (copies only for multi-chunk pinned payloads).
    pub fn contiguous(&self) -> Cow<'_, [u8]> {
        match self {
            Payload::Heap(v) => Cow::Borrowed(v),
            Payload::Pinned { prelude, body } if prelude.is_empty() => body.contiguous(),
            Payload::Pinned { .. } => Cow::Owned(self.to_vec()),
        }
    }

    /// Reassemble into a heap `Vec` (tests, control decoding).
    pub fn to_vec(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len());
        for c in self.chunks() {
            out.extend_from_slice(c);
        }
        out
    }
}

impl From<Vec<u8>> for Payload {
    fn from(v: Vec<u8>) -> Payload {
        Payload::Heap(v)
    }
}

impl Clone for Payload {
    /// Cloning materializes to heap bytes — slab buffers have one
    /// owner; clones are for tests and control-plane bookkeeping.
    fn clone(&self) -> Payload {
        Payload::Heap(self.to_vec())
    }
}

impl PartialEq for Payload {
    fn eq(&self, other: &Self) -> bool {
        *self.contiguous() == *other.contiguous()
    }
}

impl PartialEq<Vec<u8>> for Payload {
    fn eq(&self, other: &Vec<u8>) -> bool {
        *self.contiguous() == other[..]
    }
}

impl<const N: usize> PartialEq<&[u8; N]> for Payload {
    fn eq(&self, other: &&[u8; N]) -> bool {
        *self.contiguous() == other[..]
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Payload::Heap(v) => write!(f, "Payload::Heap({} bytes)", v.len()),
            Payload::Pinned { prelude, body } => write!(
                f,
                "Payload::Pinned({}+{} bytes)",
                prelude.len(),
                body.len()
            ),
        }
    }
}

/// One message on the fabric.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub src: usize,
    pub dst: usize,
    /// Logical channel: identifies the exchange edge within the query
    /// DAG (operator id on the receiving side).
    pub channel: u32,
    pub payload: Payload,
}

impl Frame {
    pub fn data(src: usize, dst: usize, channel: u32, payload: Vec<u8>) -> Frame {
        Frame { kind: FrameKind::Data, src, dst, channel, payload: Payload::Heap(payload) }
    }

    /// A data frame around an already-staged payload (the Network
    /// Executor's slab-backed send path).
    pub fn data_payload(src: usize, dst: usize, channel: u32, payload: Payload) -> Frame {
        Frame { kind: FrameKind::Data, src, dst, channel, payload }
    }

    pub fn finish(src: usize, dst: usize, channel: u32) -> Frame {
        Frame {
            kind: FrameKind::Finish,
            src,
            dst,
            channel,
            payload: Payload::Heap(Vec::new()),
        }
    }

    pub fn size_estimate(src: usize, dst: usize, channel: u32, bytes: u64) -> Frame {
        Frame {
            kind: FrameKind::SizeEstimate,
            src,
            dst,
            channel,
            payload: Payload::Heap(bytes.to_le_bytes().to_vec()),
        }
    }

    pub fn control(src: usize, dst: usize, payload: Vec<u8>) -> Frame {
        Frame {
            kind: FrameKind::Control,
            src,
            dst,
            channel: 0,
            payload: Payload::Heap(payload),
        }
    }

    /// A credit grant: `amount` data-frame credits returned to `dst`
    /// for traffic it sends back toward `src` (the granting receiver).
    pub fn credit(src: usize, dst: usize, channel: u32, amount: u64) -> Frame {
        Frame {
            kind: FrameKind::Credit,
            src,
            dst,
            channel,
            payload: Payload::Heap(amount.to_le_bytes().to_vec()),
        }
    }

    /// Estimate payload for a SizeEstimate frame.
    pub fn estimate_bytes(&self) -> Result<u64> {
        if self.kind != FrameKind::SizeEstimate || self.payload.len() != 8 {
            return Err(Error::Network("not a size-estimate frame".into()));
        }
        Ok(u64::from_le_bytes(self.payload.contiguous()[..8].try_into().unwrap()))
    }

    /// Credit amount for a Credit frame.
    pub fn credit_amount(&self) -> Result<u64> {
        if self.kind != FrameKind::Credit || self.payload.len() != 8 {
            return Err(Error::Network("not a credit frame".into()));
        }
        Ok(u64::from_le_bytes(self.payload.contiguous()[..8].try_into().unwrap()))
    }

    /// Bytes on the wire (header + payload) — what throttles charge.
    pub fn wire_len(&self) -> usize {
        FRAME_HEADER_LEN + self.payload.len()
    }

    /// The fixed wire header: kind(1) + src(4) + dst(4) + channel(4) +
    /// payload len(8). The payload bytes follow as-is, so a send is
    /// header-encode + `write_vectored` of the payload chunks.
    pub fn encode_header(&self) -> [u8; FRAME_HEADER_LEN] {
        let mut h = [0u8; FRAME_HEADER_LEN];
        h[0] = self.kind.tag();
        h[1..5].copy_from_slice(&(self.src as u32).to_le_bytes());
        h[5..9].copy_from_slice(&(self.dst as u32).to_le_bytes());
        h[9..13].copy_from_slice(&self.channel.to_le_bytes());
        h[13..21].copy_from_slice(&(self.payload.len() as u64).to_le_bytes());
        h
    }

    /// Parse a wire header: (kind, src, dst, channel, payload_len).
    pub fn decode_header(h: &[u8]) -> Result<(FrameKind, usize, usize, u32, usize)> {
        if h.len() < FRAME_HEADER_LEN {
            return Err(Error::Network(format!(
                "truncated frame header: {} of {FRAME_HEADER_LEN} bytes",
                h.len()
            )));
        }
        let kind = FrameKind::from_tag(h[0])?;
        let src = u32::from_le_bytes(h[1..5].try_into().unwrap()) as usize;
        let dst = u32::from_le_bytes(h[5..9].try_into().unwrap()) as usize;
        let channel = u32::from_le_bytes(h[9..13].try_into().unwrap());
        let plen = u64::from_le_bytes(h[13..21].try_into().unwrap()) as usize;
        Ok((kind, src, dst, channel, plen))
    }

    /// Encode to one contiguous buffer (tests and non-vectored
    /// transports; the TCP path uses `encode_header` + vectored writes
    /// of `payload.chunks()` instead).
    pub fn encode_to_vec(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_len());
        out.extend_from_slice(&self.encode_header());
        for c in self.payload.chunks() {
            out.extend_from_slice(c);
        }
        out
    }

    /// Decode a whole frame from one buffer (heap payload).
    pub fn decode(buf: &[u8]) -> Result<Frame> {
        let (kind, src, dst, channel, plen) = Frame::decode_header(buf)?;
        if buf.len() != FRAME_HEADER_LEN + plen {
            return Err(Error::Network(format!(
                "frame length mismatch: {} vs {}",
                buf.len(),
                FRAME_HEADER_LEN + plen
            )));
        }
        Ok(Frame {
            kind,
            src,
            dst,
            channel,
            payload: Payload::Heap(buf[FRAME_HEADER_LEN..].to_vec()),
        })
    }
}

/// kind(1) + src(4) + dst(4) + channel(4) + len(8)
pub const FRAME_HEADER_LEN: usize = 21;

/// Default ceiling on a whole frame (header + payload) accepted off the
/// wire. Length prefixes arrive from the network and may be corrupt or
/// hostile; receive paths reject frames above this *before* sizing any
/// buffer from the claimed length (`WorkerConfig::max_frame_bytes`
/// overrides it per deployment).
pub const DEFAULT_MAX_FRAME_BYTES: usize = 1 << 30;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::PinnedPool;

    #[test]
    fn encode_decode_roundtrip_all_kinds() {
        let frames = vec![
            Frame::data(1, 2, 42, vec![1, 2, 3]),
            Frame::finish(0, 3, 7),
            Frame::size_estimate(2, 0, 9, 123_456_789),
            Frame::control(0, 1, b"plan".to_vec()),
            Frame::credit(3, 1, 5, 17),
        ];
        for f in frames {
            let buf = f.encode_to_vec();
            assert_eq!(buf.len(), f.wire_len());
            assert_eq!(Frame::decode(&buf).unwrap(), f);
        }
    }

    #[test]
    fn slab_payload_roundtrips_across_buffers() {
        // A slab spanning several small pool buffers must hit the wire
        // byte-identically to its heap twin: same header, same chunks.
        let pool = PinnedPool::new(16, 8).unwrap();
        let body: Vec<u8> = (0..100u8).collect();
        let slab = crate::memory::PinnedSlab::write(&pool, &body).unwrap();
        assert!(slab.num_buffers() > 1, "must span buffers");
        let prelude = vec![0xAB, 0xCD];
        let pinned = Frame::data_payload(
            3,
            4,
            11,
            Payload::pinned(prelude.clone(), crate::memory::SlabSlice::whole(slab)),
        );
        let mut heap_bytes = prelude;
        heap_bytes.extend_from_slice(&body);
        let heap = Frame::data(3, 4, 11, heap_bytes);

        assert_eq!(pinned.payload, heap.payload);
        assert!(pinned.payload.chunks().len() > 2, "vectored chunks");
        let wire = pinned.encode_to_vec();
        assert_eq!(wire, heap.encode_to_vec());
        let back = Frame::decode(&wire).unwrap();
        assert_eq!(back, heap);
        assert_eq!(back.payload, pinned.payload);
    }

    #[test]
    fn pinned_payload_slice_strips_prelude_without_copy() {
        let pool = PinnedPool::new(32, 4).unwrap();
        let full: Vec<u8> = (0..60u8).collect();
        let slab = crate::memory::PinnedSlab::write(&pool, &full).unwrap();
        let body = crate::memory::SlabSlice::whole(slab);
        let tail = body.slice(9, 51);
        assert_eq!(tail.to_vec(), &full[9..]);
        let p = Payload::pinned(Vec::new(), tail);
        assert_eq!(p.len(), 51);
    }

    #[test]
    fn size_estimate_accessor() {
        let f = Frame::size_estimate(0, 1, 2, 999);
        assert_eq!(f.estimate_bytes().unwrap(), 999);
        assert!(Frame::finish(0, 1, 2).estimate_bytes().is_err());
    }

    #[test]
    fn credit_accessor() {
        let f = Frame::credit(1, 0, 7, 12);
        assert_eq!(f.credit_amount().unwrap(), 12);
        // kind check: an estimate's 8-byte payload must not parse as credit
        assert!(Frame::size_estimate(1, 0, 7, 12).credit_amount().is_err());
        assert!(Frame::finish(0, 1, 2).credit_amount().is_err());
    }

    #[test]
    fn truncated_frame_rejected() {
        let buf = Frame::data(0, 1, 2, vec![5; 100]).encode_to_vec();
        assert!(Frame::decode(&buf[..10]).is_err());
        assert!(Frame::decode(&buf[..buf.len() - 1]).is_err());
    }

    #[test]
    fn bad_kind_rejected() {
        let mut buf = Frame::finish(0, 1, 2).encode_to_vec();
        buf[0] = 99;
        assert!(Frame::decode(&buf).is_err());
    }
}
