//! # Theseus — distributed accelerator-native query engine (reproduction)
//!
//! Reproduction of "Theseus: A Distributed and Scalable GPU-Accelerated
//! Query Processing Platform Optimized for Efficient Data Movement"
//! (CS.DC 2025, Voltron Data / CMU).
//!
//! Three-layer architecture:
//!  * **L3 (this crate)** — the distributed coordinator: four asynchronous
//!    executors (Compute, Data-Movement, Pre-load, Network), Batch
//!    Holders, operator DAG, adaptive exchange, event-driven memory
//!    reservation + spilling + promotion, the fixed-size page-locked
//!    buffer pool, and the cluster runtime (Client / Gateway / Planner /
//!    Workers).
//!  * **L2 (python/compile/model.py)** — JAX compute stages for the query
//!    operators, AOT-lowered to HLO text artifacts.
//!  * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!    hot spots (filter, hash partition, aggregation, bloom/LIP),
//!    interpret-mode lowered into the same HLO.
//!
//! The "GPU" in this reproduction is a simulated device: a capacity-tracked
//! device-memory arena whose compute is performed by the AOT-compiled XLA
//! executables through the PJRT CPU client (`runtime` module), with
//! PCIe/NVLink/network data movement modeled by a calibrated
//! bandwidth+latency simulator (`sim` module). See DESIGN.md
//! §Hardware-Adaptation for the mapping.

pub mod cache;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod error;
pub mod exec;
pub mod executors;
pub mod fault;
pub mod memory;
pub mod metrics;
pub mod network;
pub mod planner;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod sync;
pub mod testing;
pub mod types;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
