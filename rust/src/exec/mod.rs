//! Query execution: physical plans, the operator DAG, and the task
//! abstraction the four executors cooperate over (§3.1–§3.3).

pub mod dag;
pub mod operators;
pub mod plan;
pub mod task;

pub use dag::QueryDag;
pub use operators::Operator;
pub use plan::{AggFn, AggSpec, OpSpec, PhysicalPlan, PlanNode, Pred};
pub use task::{Prefetch, Staging, StagingState, Task};

use std::sync::Arc;

use crate::config::WorkerConfig;
use crate::memory::batch_holder::MemEnv;
use crate::memory::MemoryGovernor;
use crate::metrics::Metrics;
use crate::runtime::KernelRegistry;
use crate::sim::Throttle;
use crate::storage::datasource::Datasource;
use crate::storage::object_store::ObjectStore;

/// Everything an operator/task needs from its worker. Cheap to clone.
#[derive(Clone)]
pub struct WorkerCtx {
    pub worker_id: usize,
    pub config: Arc<WorkerConfig>,
    pub env: MemEnv,
    pub governor: MemoryGovernor,
    /// `None` runs operators on their host fallback paths (unit tests
    /// without built artifacts); workers always set it.
    pub registry: Option<KernelRegistry>,
    pub datasource: Arc<dyn Datasource>,
    pub store: Arc<dyn ObjectStore>,
    /// Outbound network queue (drained by the Network Executor).
    pub outbox: Arc<crate::executors::network::Outbox>,
    /// Paces the modeled portion of device compute (the PJRT CPU path
    /// under-costs a real GPU; see DESIGN.md §Hardware-Adaptation).
    pub device_compute: Throttle,
    pub metrics: Arc<Metrics>,
}

impl WorkerCtx {
    /// Single-worker test context over an in-memory store, no AOT
    /// registry (host fallbacks), instant simulation.
    pub fn test() -> WorkerCtx {
        let config = Arc::new(WorkerConfig::test());
        Self::test_with(config)
    }

    pub fn test_with(config: Arc<WorkerConfig>) -> WorkerCtx {
        use crate::sim::SimContext;
        let ctx = SimContext::new(config.profile.clone(), config.time_scale);
        let store = crate::storage::object_store::SimObjectStore::in_memory(&ctx);
        let env = MemEnv::test(config.device_capacity);
        let governor = MemoryGovernor::new(env.arena.clone());
        WorkerCtx {
            worker_id: 0,
            config,
            env,
            governor,
            registry: None,
            datasource: Arc::new(crate::storage::datasource::GenericDatasource::new(
                store.clone(),
            )),
            store,
            outbox: Arc::new(crate::executors::network::Outbox::new(1)),
            device_compute: ctx.throttle(&ctx.profile.device_compute),
            metrics: Arc::new(Metrics::default()),
        }
    }

    /// Test context with the real AOT registry (requires artifacts).
    pub fn test_with_registry() -> crate::Result<WorkerCtx> {
        let mut ctx = WorkerCtx::test();
        ctx.registry = Some(KernelRegistry::shared()?);
        Ok(ctx)
    }

    pub fn num_workers(&self) -> usize {
        self.config.num_workers
    }
}
