//! Physical plans: what the Planner emits and every worker executes.
//!
//! "every worker receives the same physical execution plan with a
//! different subset of files to scan" (§3). A [`PhysicalPlan`] is a DAG
//! of [`PlanNode`]s in topological order (inputs precede users); binary
//! serde lets the Gateway ship it to workers in a control frame.

use std::sync::Arc;

use crate::types::schema::DType;
use crate::util::bytes::{Reader, Writer};
use crate::{Error, Result};

/// Filter predicate (conjunctions of column comparisons).
#[derive(Clone, Debug, PartialEq)]
pub enum Pred {
    /// `lo <= col < hi` over any i64-backed column.
    RangeI64 { col: String, lo: i64, hi: i64 },
    /// `lo <= col < hi` over f32.
    RangeF32 { col: String, lo: f32, hi: f32 },
    /// `col == val` over any i64-backed column (incl. dict codes).
    EqI64 { col: String, val: i64 },
    And(Box<Pred>, Box<Pred>),
}

impl Pred {
    pub fn and(self, other: Pred) -> Pred {
        Pred::And(Box::new(self), Box::new(other))
    }

    /// Columns the predicate touches.
    pub fn columns(&self) -> Vec<&str> {
        match self {
            Pred::RangeI64 { col, .. }
            | Pred::RangeF32 { col, .. }
            | Pred::EqI64 { col, .. } => vec![col],
            Pred::And(a, b) => {
                let mut v = a.columns();
                v.extend(b.columns());
                v
            }
        }
    }

    /// Flatten the conjunction tree into leaves.
    pub fn conjuncts(&self) -> Vec<&Pred> {
        match self {
            Pred::And(a, b) => {
                let mut v = a.conjuncts();
                v.extend(b.conjuncts());
                v
            }
            leaf => vec![leaf],
        }
    }

    fn encode(&self, w: &mut Writer) {
        match self {
            Pred::RangeI64 { col, lo, hi } => {
                w.u8(0);
                w.str(col);
                w.i64(*lo);
                w.i64(*hi);
            }
            Pred::RangeF32 { col, lo, hi } => {
                w.u8(1);
                w.str(col);
                w.f32(*lo);
                w.f32(*hi);
            }
            Pred::EqI64 { col, val } => {
                w.u8(2);
                w.str(col);
                w.i64(*val);
            }
            Pred::And(a, b) => {
                w.u8(3);
                a.encode(w);
                b.encode(w);
            }
        }
    }

    fn decode(r: &mut Reader) -> Result<Pred> {
        Ok(match r.u8()? {
            0 => Pred::RangeI64 { col: r.str()?, lo: r.i64()?, hi: r.i64()? },
            1 => Pred::RangeF32 { col: r.str()?, lo: r.f32()?, hi: r.f32()? },
            2 => Pred::EqI64 { col: r.str()?, val: r.i64()? },
            3 => Pred::And(Box::new(Pred::decode(r)?), Box::new(Pred::decode(r)?)),
            t => return Err(Error::Format(format!("bad pred tag {t}"))),
        })
    }
}

/// Aggregate functions over one column.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFn {
    Sum,
    Count,
    Min,
    Max,
}

impl AggFn {
    pub fn name(self) -> &'static str {
        match self {
            AggFn::Sum => "sum",
            AggFn::Count => "count",
            AggFn::Min => "min",
            AggFn::Max => "max",
        }
    }

    fn tag(self) -> u8 {
        match self {
            AggFn::Sum => 0,
            AggFn::Count => 1,
            AggFn::Min => 2,
            AggFn::Max => 3,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => AggFn::Sum,
            1 => AggFn::Count,
            2 => AggFn::Min,
            3 => AggFn::Max,
            _ => return Err(Error::Format(format!("bad aggfn tag {t}"))),
        })
    }
}

/// One aggregate output: `func(col) as name`.
#[derive(Clone, Debug, PartialEq)]
pub struct AggSpec {
    pub func: AggFn,
    pub col: String,
    pub name: String,
}

impl AggSpec {
    pub fn new(func: AggFn, col: impl Into<String>) -> AggSpec {
        let col = col.into();
        let name = format!("{}_{}", func.name(), col);
        AggSpec { func, col, name }
    }
}

/// What an Exchange is redistributing for — this decides which adaptive
/// modes are legal (§3.2: the pair "decide whether to hash partition or
/// broadcast the data").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeRole {
    /// Aggregation shuffle: hash-partition always (broadcast would
    /// duplicate groups).
    Shuffle,
    /// Join build side: may broadcast itself when small.
    Build,
    /// Join probe side: passes through locally when its partner (the
    /// build side) broadcasts; hash-partitions otherwise. `partner` is
    /// the plan-node id of the paired Build exchange.
    Probe { partner: usize },
}

impl ExchangeRole {
    fn tag(self) -> u8 {
        match self {
            ExchangeRole::Shuffle => 0,
            ExchangeRole::Build => 1,
            ExchangeRole::Probe { .. } => 2,
        }
    }
}

/// Operator specification.
#[derive(Clone, Debug, PartialEq)]
pub enum OpSpec {
    /// Table scan over the worker's file assignment. `pred` enables
    /// row-group pruning via footer stats (the predicate itself is
    /// applied by a downstream Filter).
    Scan { table: String, cols: Vec<String>, pred: Option<Pred> },
    /// Row filter (device mask kernel + host compaction).
    Filter { pred: Pred },
    /// Column projection.
    Project { cols: Vec<String> },
    /// Adaptive exchange on a hash key (§3.2): estimate, broadcast the
    /// estimate, then hash-partition / broadcast / pass-through per the
    /// role's rules.
    Exchange { key: String, role: ExchangeRole },
    /// Hash aggregation: device pre-agg + exact host finalize.
    HashAgg { group_by: String, aggs: Vec<AggSpec> },
    /// Inner equi-join; input 0 is the build side, input 1 the probe.
    /// `lip` enables Lookahead Information Passing (bloom pushdown, §5).
    HashJoin { left_on: String, right_on: String, lip: bool },
    /// Total order by one column.
    Sort { by: String, desc: bool },
    /// Keep the first `n` rows.
    Limit { n: u64 },
    /// Cache-resident materialized subplan (serving layer): `data` is an
    /// encoded [`crate::types::RecordBatch`] — the gathered output of a
    /// previously executed scan→filter→agg fragment. A leaf like Scan;
    /// each worker emits its disjoint row slice so downstream operators
    /// (and the client gather) see exactly one copy of every row.
    Fragment { data: Arc<Vec<u8>> },
}

impl OpSpec {
    pub fn name(&self) -> &'static str {
        match self {
            OpSpec::Scan { .. } => "scan",
            OpSpec::Filter { .. } => "filter",
            OpSpec::Project { .. } => "project",
            OpSpec::Exchange { .. } => "exchange",
            OpSpec::HashAgg { .. } => "hash_agg",
            OpSpec::HashJoin { .. } => "hash_join",
            OpSpec::Sort { .. } => "sort",
            OpSpec::Limit { .. } => "limit",
            OpSpec::Fragment { .. } => "fragment",
        }
    }

    /// How many inputs this operator requires.
    pub fn arity(&self) -> usize {
        match self {
            OpSpec::Scan { .. } | OpSpec::Fragment { .. } => 0,
            OpSpec::HashJoin { .. } => 2,
            _ => 1,
        }
    }

    fn encode(&self, w: &mut Writer) {
        match self {
            OpSpec::Scan { table, cols, pred } => {
                w.u8(0);
                w.str(table);
                w.u32(cols.len() as u32);
                for c in cols {
                    w.str(c);
                }
                match pred {
                    None => w.u8(0),
                    Some(p) => {
                        w.u8(1);
                        p.encode(w);
                    }
                }
            }
            OpSpec::Filter { pred } => {
                w.u8(1);
                pred.encode(w);
            }
            OpSpec::Project { cols } => {
                w.u8(2);
                w.u32(cols.len() as u32);
                for c in cols {
                    w.str(c);
                }
            }
            OpSpec::Exchange { key, role } => {
                w.u8(3);
                w.str(key);
                w.u8(role.tag());
                if let ExchangeRole::Probe { partner } = role {
                    w.u32(*partner as u32);
                }
            }
            OpSpec::HashAgg { group_by, aggs } => {
                w.u8(4);
                w.str(group_by);
                w.u32(aggs.len() as u32);
                for a in aggs {
                    w.u8(a.func.tag());
                    w.str(&a.col);
                    w.str(&a.name);
                }
            }
            OpSpec::HashJoin { left_on, right_on, lip } => {
                w.u8(5);
                w.str(left_on);
                w.str(right_on);
                w.u8(*lip as u8);
            }
            OpSpec::Sort { by, desc } => {
                w.u8(6);
                w.str(by);
                w.u8(*desc as u8);
            }
            OpSpec::Limit { n } => {
                w.u8(7);
                w.u64(*n);
            }
            OpSpec::Fragment { data } => {
                w.u8(8);
                w.bytes(data);
            }
        }
    }

    fn decode(r: &mut Reader) -> Result<OpSpec> {
        Ok(match r.u8()? {
            0 => {
                let table = r.str()?;
                let n = r.u32()? as usize;
                let cols = (0..n).map(|_| r.str()).collect::<Result<_>>()?;
                let pred = if r.u8()? == 1 { Some(Pred::decode(r)?) } else { None };
                OpSpec::Scan { table, cols, pred }
            }
            1 => OpSpec::Filter { pred: Pred::decode(r)? },
            2 => {
                let n = r.u32()? as usize;
                OpSpec::Project { cols: (0..n).map(|_| r.str()).collect::<Result<_>>()? }
            }
            3 => {
                let key = r.str()?;
                let role = match r.u8()? {
                    0 => ExchangeRole::Shuffle,
                    1 => ExchangeRole::Build,
                    2 => ExchangeRole::Probe { partner: r.u32()? as usize },
                    t => return Err(Error::Format(format!("bad exchange role {t}"))),
                };
                OpSpec::Exchange { key, role }
            }
            4 => {
                let group_by = r.str()?;
                let n = r.u32()? as usize;
                let aggs = (0..n)
                    .map(|_| {
                        Ok(AggSpec {
                            func: AggFn::from_tag(r.u8()?)?,
                            col: r.str()?,
                            name: r.str()?,
                        })
                    })
                    .collect::<Result<_>>()?;
                OpSpec::HashAgg { group_by, aggs }
            }
            5 => OpSpec::HashJoin {
                left_on: r.str()?,
                right_on: r.str()?,
                lip: r.u8()? != 0,
            },
            6 => OpSpec::Sort { by: r.str()?, desc: r.u8()? != 0 },
            7 => OpSpec::Limit { n: r.u64()? },
            8 => OpSpec::Fragment { data: Arc::new(r.bytes()?.to_vec()) },
            t => return Err(Error::Format(format!("bad opspec tag {t}"))),
        })
    }
}

/// One DAG node.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanNode {
    pub id: usize,
    pub spec: OpSpec,
    /// Ids of input nodes (must be < id: topological order).
    pub inputs: Vec<usize>,
}

/// The whole plan. Node `len - 1` is the root whose output is the query
/// result.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PhysicalPlan {
    pub nodes: Vec<PlanNode>,
}

impl PhysicalPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a node; returns its id.
    pub fn add(&mut self, spec: OpSpec, inputs: Vec<usize>) -> usize {
        let id = self.nodes.len();
        self.nodes.push(PlanNode { id, spec, inputs });
        id
    }

    pub fn root(&self) -> Result<&PlanNode> {
        self.nodes
            .last()
            .ok_or_else(|| Error::Plan("empty plan".into()))
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Structural validation: ids sequential, inputs topological, arity
    /// correct, exactly one root (no unused outputs).
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() {
            return Err(Error::Plan("empty plan".into()));
        }
        let mut used = vec![false; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(Error::Plan(format!("node {i} has id {}", n.id)));
            }
            if n.inputs.len() != n.spec.arity() {
                return Err(Error::Plan(format!(
                    "node {i} ({}) has {} inputs, needs {}",
                    n.spec.name(),
                    n.inputs.len(),
                    n.spec.arity()
                )));
            }
            for &inp in &n.inputs {
                if inp >= i {
                    return Err(Error::Plan(format!(
                        "node {i} uses input {inp} (not topological)"
                    )));
                }
                used[inp] = true;
            }
        }
        for (i, &u) in used.iter().enumerate().take(self.nodes.len() - 1) {
            if !u {
                return Err(Error::Plan(format!("node {i} output is never consumed")));
            }
        }
        Ok(())
    }

    /// Consumers of each node (DAG-aware task priorities use depth).
    pub fn consumers(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// Distance of each node from the root (root = 0). Deeper nodes get
    /// higher compute priority: they unblock the most downstream work.
    pub fn depths(&self) -> Vec<usize> {
        let mut d = vec![0usize; self.nodes.len()];
        for n in self.nodes.iter().rev() {
            for &i in &n.inputs {
                d[i] = d[i].max(d[n.id] + 1);
            }
        }
        d
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(self.nodes.len() as u32);
        for n in &self.nodes {
            n.spec.encode(&mut w);
            w.u32(n.inputs.len() as u32);
            for &i in &n.inputs {
                w.u32(i as u32);
            }
        }
        w.finish()
    }

    pub fn decode(buf: &[u8]) -> Result<PhysicalPlan> {
        let mut r = Reader::new(buf);
        let n = r.u32()? as usize;
        let mut plan = PhysicalPlan::new();
        for _ in 0..n {
            let spec = OpSpec::decode(&mut r)?;
            let ni = r.u32()? as usize;
            let inputs = (0..ni)
                .map(|_| Ok(r.u32()? as usize))
                .collect::<Result<_>>()?;
            plan.add(spec, inputs);
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Pretty-print (logs / `theseus explain`).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for n in &self.nodes {
            let inputs: Vec<String> = n.inputs.iter().map(|i| format!("#{i}")).collect();
            s.push_str(&format!(
                "#{:<3} {:<10} <- [{}]  {:?}\n",
                n.id,
                n.spec.name(),
                inputs.join(", "),
                n.spec
            ));
        }
        s
    }
}

/// The dtype a filter stage needs for a predicate column (drives stage
/// selection in the Filter operator).
pub fn pred_stage_dtype(dtype: DType) -> &'static str {
    if dtype == DType::Float32 {
        "f32"
    } else {
        "i64"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_plan() -> PhysicalPlan {
        let mut p = PhysicalPlan::new();
        let scan_a = p.add(
            OpSpec::Scan {
                table: "orders".into(),
                cols: vec!["o_orderkey".into(), "o_totalprice".into()],
                pred: None,
            },
            vec![],
        );
        let scan_b = p.add(
            OpSpec::Scan {
                table: "lineitem".into(),
                cols: vec!["l_orderkey".into(), "l_quantity".into()],
                pred: Some(Pred::RangeI64 { col: "l_quantity".into(), lo: 0, hi: 2500 }),
            },
            vec![],
        );
        let filt = p.add(
            OpSpec::Filter {
                pred: Pred::RangeI64 { col: "l_quantity".into(), lo: 0, hi: 2500 },
            },
            vec![scan_b],
        );
        let ex_a = p.add(
            OpSpec::Exchange { key: "o_orderkey".into(), role: ExchangeRole::Build },
            vec![scan_a],
        );
        let ex_b = p.add(
            OpSpec::Exchange {
                key: "l_orderkey".into(),
                role: ExchangeRole::Probe { partner: ex_a },
            },
            vec![filt],
        );
        let join = p.add(
            OpSpec::HashJoin {
                left_on: "o_orderkey".into(),
                right_on: "l_orderkey".into(),
                lip: true,
            },
            vec![ex_a, ex_b],
        );
        let agg = p.add(
            OpSpec::HashAgg {
                group_by: "o_orderkey".into(),
                aggs: vec![AggSpec::new(AggFn::Sum, "l_quantity")],
            },
            vec![join],
        );
        p.add(OpSpec::Sort { by: "sum_l_quantity".into(), desc: true }, vec![agg]);
        p
    }

    #[test]
    fn sample_validates() {
        sample_plan().validate().unwrap();
    }

    #[test]
    fn serde_roundtrip() {
        let p = sample_plan();
        let buf = p.encode();
        let got = PhysicalPlan::decode(&buf).unwrap();
        assert_eq!(got, p);
    }

    #[test]
    fn fragment_roundtrips_and_is_a_leaf() {
        let mut p = PhysicalPlan::new();
        let f = p.add(
            OpSpec::Fragment { data: Arc::new(vec![1u8, 2, 3, 255]) },
            vec![],
        );
        p.add(OpSpec::Limit { n: 2 }, vec![f]);
        p.validate().unwrap();
        let got = PhysicalPlan::decode(&p.encode()).unwrap();
        assert_eq!(got, p);
        assert_eq!(p.nodes[0].spec.arity(), 0);
        assert_eq!(p.nodes[0].spec.name(), "fragment");
    }

    #[test]
    fn validation_catches_bad_arity_and_order() {
        let mut p = PhysicalPlan::new();
        p.add(OpSpec::Limit { n: 5 }, vec![]); // limit needs 1 input
        assert!(p.validate().is_err());

        let mut p = PhysicalPlan::new();
        p.nodes.push(PlanNode {
            id: 0,
            spec: OpSpec::Limit { n: 1 },
            inputs: vec![0], // self-reference
        });
        assert!(p.validate().is_err());
    }

    #[test]
    fn validation_catches_dangling_output() {
        let mut p = PhysicalPlan::new();
        p.add(
            OpSpec::Scan { table: "t".into(), cols: vec!["a".into()], pred: None },
            vec![],
        );
        p.add(
            OpSpec::Scan { table: "u".into(), cols: vec!["b".into()], pred: None },
            vec![],
        );
        // node 0 never consumed and is not the root
        assert!(p.validate().is_err());
    }

    #[test]
    fn depths_favor_upstream() {
        let p = sample_plan();
        let d = p.depths();
        // scans are deepest, root is 0
        assert_eq!(d[p.nodes.len() - 1], 0);
        assert!(d[0] >= 3);
        assert!(d[1] >= 4, "{d:?}");
    }

    #[test]
    fn pred_helpers() {
        let p = Pred::EqI64 { col: "a".into(), val: 1 }
            .and(Pred::RangeF32 { col: "b".into(), lo: 0.0, hi: 1.0 });
        assert_eq!(p.columns(), vec!["a", "b"]);
        assert_eq!(p.conjuncts().len(), 2);
    }

    #[test]
    fn render_mentions_every_node() {
        let s = sample_plan().render();
        for name in ["scan", "filter", "exchange", "hash_join", "hash_agg", "sort"] {
            assert!(s.contains(name), "{name} missing from render");
        }
    }
}
