//! DAG instantiation: a [`PhysicalPlan`] becomes operators wired by
//! Batch Holders (§3.1, Figure 1: "Batch Holders are conceptually
//! instantiated as edges of the DAG, where data can accumulate before
//! processing by a next operation").
//!
//! The holders built here are both registered with the Data-Movement
//! executor's [`HolderRegistry`] (so movement can pick victims and
//! beneficiaries) *and* handed to the operators as inputs, which
//! declare them on every task they submit ([`Task::inputs`]) — that is
//! how the compute queue learns which residency a queued task depends
//! on (§3.3.1). Base priorities are `depth * 1000`; the queue adds the
//! residency bonus dynamically.
//!
//! Exchange nodes additionally register a receive channel with the
//! Network Executor's router; their output holder is the channel's
//! holder, fed by peers. Channel ids are `(query_id << 16) | node_id`
//! so concurrent queries never collide.

use std::sync::Arc;

use crate::exec::operators::{
    ExchangeOp, FilterOp, FragmentOp, HashAggOp, HashJoinOp, LimitOp, Operator,
    ProjectOp, ScanOp, SortOp,
};
use crate::exec::plan::{ExchangeRole, OpSpec, PhysicalPlan};
use crate::exec::{Task, WorkerCtx};
use crate::executors::movement::HolderRegistry;
use crate::executors::network::{ChannelRx, Router};
use crate::memory::BatchHolder;
use crate::storage::format::FileFooter;
use crate::{Error, Result};

/// A worker's instantiated query.
pub struct QueryDag {
    pub query_id: u64,
    pub operators: Vec<Arc<dyn Operator>>,
    /// The root's output: the worker-local query result.
    pub output: BatchHolder,
    /// Channels registered on the router (unregistered on drop).
    channels: Vec<u32>,
    router: Arc<Router>,
    /// Exchange ops by node id (bench introspection: mode decisions).
    pub exchanges: Vec<(usize, Arc<ExchangeOp>)>,
    /// Join ops by node id (LIP metrics).
    pub joins: Vec<(usize, Arc<HashJoinOp>)>,
    /// Scan ops by node id (progress reporting).
    pub scans: Vec<(usize, Arc<ScanOp>)>,
}

impl QueryDag {
    /// Instantiate `plan` for this worker.
    pub fn build(
        plan: &PhysicalPlan,
        ctx: &WorkerCtx,
        router: &Arc<Router>,
        holders: &Arc<HolderRegistry>,
        query_id: u64,
    ) -> Result<QueryDag> {
        plan.validate()?;
        let depths = plan.depths();
        let max_inflight = ctx.config.compute_threads * 2;
        let mut outputs: Vec<BatchHolder> = Vec::with_capacity(plan.len());
        let mut operators: Vec<Arc<dyn Operator>> = Vec::with_capacity(plan.len());
        let mut channels = Vec::new();
        let mut exchanges = Vec::new();
        let mut joins = Vec::new();
        let mut scans = Vec::new();

        // Pre-pass: LIP shares — for every lip join, the probe-side
        // input (if it is an exchange) gets the slot the join will
        // publish its build bloom into (§5).
        let mut lip_of: std::collections::HashMap<usize, crate::exec::operators::join::LipShare> =
            std::collections::HashMap::new();
        for node in &plan.nodes {
            if let OpSpec::HashJoin { lip: true, .. } = &node.spec {
                let probe_input = node.inputs[1];
                if matches!(plan.nodes[probe_input].spec, OpSpec::Exchange { .. }) {
                    let share: crate::exec::operators::join::LipShare =
                        Arc::new(std::sync::RwLock::new(None));
                    lip_of.insert(probe_input, share.clone());
                    lip_of.insert(node.id, share);
                }
            }
        }

        // Pre-pass: one ChannelRx per exchange node, registered before
        // any operator runs (peers may send as soon as they start) and
        // resolvable for Probe→Build partner wiring.
        let mut rx_of: std::collections::HashMap<usize, Arc<ChannelRx>> =
            std::collections::HashMap::new();
        for node in &plan.nodes {
            if let OpSpec::Exchange { .. } = &node.spec {
                let channel = ((query_id as u32) << 16) | node.id as u32;
                let h = BatchHolder::new(
                    format!("q{query_id}.op{}.exchange.rx", node.id),
                    ctx.env.clone(),
                );
                holders.register(query_id, node.id, h.clone());
                let rx = Arc::new(ChannelRx::new(h, ctx.num_workers()));
                router.register(channel, rx.clone());
                channels.push(channel);
                rx_of.insert(node.id, rx);
            }
        }

        for node in &plan.nodes {
            let prio = depths[node.id] as i64 * 1000;
            let hname = |suffix: &str| {
                format!("q{query_id}.op{}.{}.{suffix}", node.id, node.spec.name())
            };
            let out = match &node.spec {
                // exchange output is its network-fed channel holder
                OpSpec::Exchange { .. } => rx_of[&node.id].holder.clone(),
                _ => {
                    let h = BatchHolder::new(hname("out"), ctx.env.clone());
                    holders.register(query_id, node.id, h.clone());
                    h
                }
            };

            let op: Arc<dyn Operator> = match &node.spec {
                OpSpec::Scan { table, cols, pred } => {
                    let footers = table_footers(ctx, table)?;
                    let schema = footers
                        .first()
                        .map(|(_, f)| f.schema.clone())
                        .ok_or_else(|| {
                            Error::Plan(format!("table '{table}' has no files"))
                        })?;
                    let col_idx: Vec<usize> = cols
                        .iter()
                        .map(|c| schema.index_of(c))
                        .collect::<Result<_>>()?;
                    let units = ScanOp::plan_units(
                        &footers,
                        pred.as_ref(),
                        ctx.worker_id,
                        ctx.num_workers(),
                    );
                    let op = Arc::new(ScanOp::new(
                        node.id,
                        prio,
                        max_inflight,
                        out.clone(),
                        units,
                        col_idx,
                    ));
                    scans.push((node.id, op.clone()));
                    op
                }
                OpSpec::Filter { pred } => Arc::new(FilterOp::new(
                    node.id,
                    prio,
                    max_inflight,
                    outputs[node.inputs[0]].clone(),
                    out.clone(),
                    pred.clone(),
                )),
                OpSpec::Project { cols } => Arc::new(ProjectOp::new(
                    node.id,
                    prio,
                    max_inflight,
                    outputs[node.inputs[0]].clone(),
                    out.clone(),
                    cols.clone(),
                )),
                OpSpec::Exchange { key, role } => {
                    let channel = ((query_id as u32) << 16) | node.id as u32;
                    let rx = rx_of[&node.id].clone();
                    let partner_rx = match role {
                        ExchangeRole::Probe { partner } => {
                            Some(rx_of.get(partner).cloned().ok_or_else(|| {
                                Error::Plan(format!(
                                    "probe exchange {} names missing partner {partner}",
                                    node.id
                                ))
                            })?)
                        }
                        _ => None,
                    };
                    let pending =
                        BatchHolder::new(hname("pending"), ctx.env.clone());
                    holders.register(query_id, node.id, pending.clone());
                    let op = Arc::new(ExchangeOp::new(
                        node.id,
                        prio,
                        max_inflight,
                        outputs[node.inputs[0]].clone(),
                        pending,
                        rx,
                        channel,
                        key.clone(),
                        *role,
                        partner_rx,
                        lip_of.get(&node.id).cloned(),
                    ));
                    exchanges.push((node.id, op.clone()));
                    op
                }
                OpSpec::HashAgg { group_by, aggs } => Arc::new(HashAggOp::new(
                    node.id,
                    prio,
                    max_inflight,
                    outputs[node.inputs[0]].clone(),
                    out.clone(),
                    group_by.clone(),
                    aggs.clone(),
                )),
                OpSpec::HashJoin { left_on, right_on, lip } => {
                    let op = Arc::new(HashJoinOp::new(
                        node.id,
                        prio,
                        max_inflight,
                        outputs[node.inputs[0]].clone(),
                        outputs[node.inputs[1]].clone(),
                        out.clone(),
                        left_on.clone(),
                        right_on.clone(),
                        *lip,
                        lip_of.get(&node.id).cloned(),
                    ));
                    joins.push((node.id, op.clone()));
                    op
                }
                OpSpec::Sort { by, desc } => Arc::new(SortOp::new(
                    node.id,
                    prio,
                    max_inflight,
                    outputs[node.inputs[0]].clone(),
                    out.clone(),
                    by.clone(),
                    *desc,
                )),
                OpSpec::Limit { n } => Arc::new(LimitOp::new(
                    node.id,
                    prio,
                    outputs[node.inputs[0]].clone(),
                    out.clone(),
                    *n,
                )),
                OpSpec::Fragment { data } => Arc::new(FragmentOp::new(
                    node.id,
                    prio,
                    out.clone(),
                    data.clone(),
                )),
            };
            outputs.push(out);
            operators.push(op);
        }

        Ok(QueryDag {
            query_id,
            operators,
            output: outputs.last().unwrap().clone(),
            channels,
            router: router.clone(),
            exchanges,
            joins,
            scans,
        })
    }

    /// Poll every unfinished operator for ready tasks.
    pub fn poll(&self, ctx: &WorkerCtx) -> Result<Vec<Task>> {
        let mut tasks = Vec::new();
        for op in &self.operators {
            if !op.is_done() {
                tasks.extend(op.poll(ctx)?);
            }
        }
        Ok(tasks)
    }

    /// All operators done (the root holder may still hold results).
    pub fn all_done(&self) -> bool {
        self.operators.iter().all(|o| o.is_done()) && self.output.is_finished()
    }

    /// Scan progress: (done, total) units.
    pub fn scan_progress(&self) -> (usize, usize) {
        self.scans
            .iter()
            .fold((0, 0), |(d, t), (_, s)| (d + s.units_done(), t + s.total_units()))
    }
}

impl Drop for QueryDag {
    fn drop(&mut self) {
        for &c in &self.channels {
            self.router.unregister(c);
        }
    }
}

fn table_footers(
    ctx: &WorkerCtx,
    table: &str,
) -> Result<Vec<(String, Arc<FileFooter>)>> {
    let keys = ctx.store.list(&format!("{table}/"))?;
    if keys.is_empty() {
        return Err(Error::Plan(format!("table '{table}' has no files")));
    }
    keys.into_iter()
        .map(|k| {
            let f = ctx.datasource.footer(&k)?;
            Ok((k, f))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::plan::{AggFn, AggSpec, Pred};
    use crate::storage::compression::Codec;
    use crate::storage::format::FileWriter;
    use crate::storage::object_store::ObjectStore;
    use crate::types::{Column, DType, Field, RecordBatch, Schema};

    fn ctx_with_table() -> WorkerCtx {
        let ctx = WorkerCtx::test();
        let schema = Schema::new(vec![
            Field::new("k", DType::Int64),
            Field::new("v", DType::Float32),
        ]);
        let batch = RecordBatch::new(vec![
            Column::i64("k", (0..500).collect()),
            Column::f32("v", (0..500).map(|i| i as f32).collect()),
        ])
        .unwrap();
        let mut w = FileWriter::new(schema, Codec::None, 128);
        w.write(batch).unwrap();
        ctx.store.put("t/0.ths", &w.finish().unwrap()).unwrap();
        ctx
    }

    fn plan_scan_filter_agg() -> PhysicalPlan {
        let mut p = PhysicalPlan::new();
        let s = p.add(
            OpSpec::Scan {
                table: "t".into(),
                cols: vec!["k".into(), "v".into()],
                pred: None,
            },
            vec![],
        );
        let f = p.add(
            OpSpec::Filter { pred: Pred::RangeI64 { col: "k".into(), lo: 0, hi: 250 } },
            vec![s],
        );
        p.add(
            OpSpec::HashAgg {
                group_by: "k".into(),
                aggs: vec![AggSpec::new(AggFn::Sum, "v")],
            },
            vec![f],
        );
        p
    }

    #[test]
    fn builds_and_names_operators() {
        let ctx = ctx_with_table();
        let router = Arc::new(Router::new());
        let holders = HolderRegistry::new();
        let dag =
            QueryDag::build(&plan_scan_filter_agg(), &ctx, &router, &holders, 1).unwrap();
        assert_eq!(dag.operators.len(), 3);
        assert_eq!(dag.operators[0].name(), "scan");
        assert_eq!(dag.operators[2].name(), "hash_agg");
        assert!(!dag.all_done());
    }

    #[test]
    fn single_worker_inline_execution_to_completion() {
        let ctx = ctx_with_table();
        let router = Arc::new(Router::new());
        let holders = HolderRegistry::new();
        let dag =
            QueryDag::build(&plan_scan_filter_agg(), &ctx, &router, &holders, 2).unwrap();
        // inline driver
        for _ in 0..500 {
            let tasks = dag.poll(&ctx).unwrap();
            for t in tasks {
                (t.run)(&ctx).unwrap();
            }
            if dag.all_done() {
                break;
            }
        }
        assert!(dag.all_done(), "dag did not converge");
        let result = dag.output.pop_device().unwrap().unwrap();
        assert_eq!(result.rows(), 250); // k in [0,250) grouped by k
        let (done, total) = dag.scan_progress();
        assert_eq!((done, total), (4, 4));
    }

    #[test]
    fn exchange_nodes_register_channels() {
        let ctx = ctx_with_table();
        let router = Arc::new(Router::new());
        let holders = HolderRegistry::new();
        let mut p = PhysicalPlan::new();
        let s = p.add(
            OpSpec::Scan { table: "t".into(), cols: vec!["k".into()], pred: None },
            vec![],
        );
        p.add(
            OpSpec::Exchange { key: "k".into(), role: ExchangeRole::Shuffle },
            vec![s],
        );
        let dag = QueryDag::build(&p, &ctx, &router, &holders, 3).unwrap();
        let channel = (3u32 << 16) | 1;
        assert!(router.channel(channel).is_some());
        drop(dag);
        assert!(router.channel(channel).is_none(), "channel leaked");
    }

    #[test]
    fn missing_table_is_plan_error() {
        let ctx = WorkerCtx::test();
        let router = Arc::new(Router::new());
        let holders = HolderRegistry::new();
        let mut p = PhysicalPlan::new();
        p.add(
            OpSpec::Scan { table: "nope".into(), cols: vec!["k".into()], pred: None },
            vec![],
        );
        assert!(QueryDag::build(&p, &ctx, &router, &holders, 1).is_err());
    }

    #[test]
    fn missing_column_is_plan_error() {
        let ctx = ctx_with_table();
        let router = Arc::new(Router::new());
        let holders = HolderRegistry::new();
        let mut p = PhysicalPlan::new();
        p.add(
            OpSpec::Scan { table: "t".into(), cols: vec!["zzz".into()], pred: None },
            vec![],
        );
        assert!(QueryDag::build(&p, &ctx, &router, &holders, 1).is_err());
    }
}
