//! The task abstraction shared by the Compute and Pre-load Executors.
//!
//! A [`Task`] is a unit of operator work (§3.1: "Operators spawn tasks
//! that work on a specific step of the physical query plan"). Tasks are
//! *restartable*: their closure either completes or fails without
//! consuming inputs (holder pops restore their slot on failure), so the
//! Compute Executor can retry retryable failures (§3.3.2).
//!
//! A task may expose a [`Prefetch`] describing the I/O it will need;
//! the Pre-load Executor scans queued tasks for these (§3.3.3) and
//! materializes data ahead of execution, without ever blocking the
//! Compute Executor (Insight B: if the data is not staged by the time
//! the task runs, the task fetches it itself).

use std::sync::{Arc, Mutex};

use crate::exec::WorkerCtx;
use crate::memory::BatchHolder;
use crate::storage::datasource::{ByteRange, FetchedPages};
use crate::Result;

/// State of a byte-range staging cell.
#[derive(Debug, Default)]
pub enum StagingState {
    /// Nothing fetched yet.
    #[default]
    Empty,
    /// The Pre-load Executor is fetching.
    InProgress,
    /// Fetched pages, ready for the compute task. Slab-backed when the
    /// pre-loader staged them through the pinned bounce pool — the
    /// compute decode then reads the very buffers the fetch landed in.
    Done(FetchedPages),
}

/// Shared staging cell between a scan task and the pre-loader.
pub type Staging = Arc<Mutex<StagingState>>;

/// Pre-loadable I/O of a queued task.
#[derive(Clone)]
pub enum Prefetch {
    /// Byte-Range Pre-loading (§3.3.3): fetch these ranges of `key`
    /// into `staging` ahead of the scan task.
    ByteRanges { key: String, ranges: Vec<ByteRange>, staging: Staging },
    /// Compute-Task Pre-loading: promote the next batch of `holder`
    /// toward device so the task's pop doesn't stall on disk.
    Promote { holder: BatchHolder },
}

/// The work closure: restartable, thread-safe.
pub type TaskFn = Arc<dyn Fn(&WorkerCtx) -> Result<()> + Send + Sync>;

/// One schedulable unit.
#[derive(Clone)]
pub struct Task {
    /// Operator (plan node) this task belongs to.
    pub op: usize,
    /// Base priority; higher runs earlier. Convention: `depth * 1000`,
    /// where depth is the node's distance from the root (upstream work
    /// unblocks more of the DAG). The queue adds a residency bonus on
    /// top from [`Task::inputs`] (§3.3.1: priorities consider "the
    /// memory tier that the input data resides in").
    pub priority: i64,
    /// Retry count so far.
    pub attempts: u32,
    /// Query this task belongs to. Stamped by the worker's driver loop
    /// when the task enters the queue; 0 for tasks outside any query
    /// (unit tests, maintenance). Executors key per-query counters and
    /// failure scopes on it so concurrent queries never bleed.
    pub qid: u64,
    /// Per-query priority weight (session layer): scales the residency
    /// bonus in scheduling and the promotion urgency in the movement
    /// plane, so a latency-sensitive query's holders win promotion over
    /// a batch query's. 1 = neutral (single-query behavior unchanged).
    pub weight: i64,
    /// What the pre-loader may do for this task.
    pub prefetch: Option<Prefetch>,
    /// Holders this task will pop from. The Compute Executor's queue
    /// reads their [`crate::memory::ResidencySnapshot`]s to bias
    /// ordering toward tasks whose inputs sit hot on device, and the
    /// Data-Movement executor's `ResidencyChanged` notifications re-rank
    /// queued tasks by these holder ids. Empty for source tasks (scans
    /// read the object store, not a holder).
    pub inputs: Vec<BatchHolder>,
    /// The work.
    pub run: TaskFn,
}

impl Task {
    pub fn new(op: usize, priority: i64, run: TaskFn) -> Task {
        Task {
            op,
            priority,
            attempts: 0,
            qid: 0,
            weight: 1,
            prefetch: None,
            inputs: Vec::new(),
            run,
        }
    }

    /// Stamp the owning query and its session weight (chainable).
    pub fn with_query(mut self, qid: u64, weight: i64) -> Task {
        self.qid = qid;
        self.weight = weight.max(1);
        self
    }

    pub fn with_prefetch(mut self, p: Prefetch) -> Task {
        self.prefetch = Some(p);
        self
    }

    /// Declare an input holder (chainable; multi-input tasks call it
    /// once per holder).
    pub fn with_input(mut self, holder: BatchHolder) -> Task {
        self.inputs.push(holder);
        self
    }

    /// Combined residency of all declared inputs (byte-weighted).
    pub fn input_residency(&self) -> crate::memory::ResidencySnapshot {
        let mut snap = crate::memory::ResidencySnapshot::default();
        for h in &self.inputs {
            snap.merge(&h.residency());
        }
        snap
    }

    /// True when any declared input is (a clone of) `holder_id`.
    pub fn reads_holder(&self, holder_id: usize) -> bool {
        self.inputs.iter().any(|h| h.id() == holder_id)
    }
}

impl std::fmt::Debug for Task {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Task(q{} op {}, prio {}, attempts {}, inputs {}, prefetch {})",
            self.qid,
            self.op,
            self.priority,
            self.attempts,
            self.inputs.len(),
            match &self.prefetch {
                None => "none",
                Some(Prefetch::ByteRanges { .. }) => "byte-ranges",
                Some(Prefetch::Promote { .. }) => "promote",
            }
        )
    }
}

/// Take staged pages if the pre-loader finished them; otherwise note
/// that the compute task will fetch on its own.
pub fn take_staged(staging: &Staging) -> Option<FetchedPages> {
    let mut s = staging.lock().unwrap();
    match std::mem::take(&mut *s) {
        StagingState::Done(pages) => Some(pages),
        other => {
            *s = other; // leave Empty/InProgress in place
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staging_take_semantics() {
        let s: Staging = Arc::new(Mutex::new(StagingState::Empty));
        assert!(take_staged(&s).is_none());
        *s.lock().unwrap() = StagingState::InProgress;
        assert!(take_staged(&s).is_none());
        assert!(matches!(*s.lock().unwrap(), StagingState::InProgress));
        *s.lock().unwrap() = StagingState::Done(vec![vec![1u8, 2].into()]);
        assert_eq!(take_staged(&s).unwrap(), vec![vec![1u8, 2].into()]);
        // consumed: second take sees Empty
        assert!(take_staged(&s).is_none());
    }

    #[test]
    fn task_is_cloneable_and_runnable() {
        let ran = Arc::new(std::sync::atomic::AtomicU32::new(0));
        let r2 = ran.clone();
        let t = Task::new(
            3,
            5000,
            Arc::new(move |_ctx| {
                r2.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Ok(())
            }),
        );
        let ctx = WorkerCtx::test();
        (t.run)(&ctx).unwrap();
        let t2 = t.clone();
        (t2.run)(&ctx).unwrap();
        assert_eq!(ran.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(t2.op, 3);
    }
}
