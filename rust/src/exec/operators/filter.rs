//! Filter and Project operators.
//!
//! Filter "can schedule tasks as soon as batches arrive at their
//! input" (§3.1): each task pops one batch, evaluates the predicate
//! mask on the device (AOT filter stage), compacts on the host, and
//! pushes the survivors. Project is the trivial column subset.

use std::sync::Arc;

use crate::exec::operators::{kernels, OpCommon, Operator};
use crate::exec::plan::Pred;
use crate::exec::task::{Prefetch, Task};
use crate::exec::WorkerCtx;
use crate::memory::batch_holder::DeviceBatch;
use crate::memory::BatchHolder;
use crate::Result;

pub struct FilterOp {
    common: Arc<OpCommon>,
    input: BatchHolder,
    output: BatchHolder,
    pred: Arc<Pred>,
}

impl FilterOp {
    pub fn new(
        id: usize,
        base_priority: i64,
        max_inflight: usize,
        input: BatchHolder,
        output: BatchHolder,
        pred: Pred,
    ) -> FilterOp {
        FilterOp {
            common: Arc::new(OpCommon::new(id, base_priority, max_inflight)),
            input,
            output,
            pred: Arc::new(pred),
        }
    }
}

impl Operator for FilterOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "filter"
    }

    fn poll(&self, _ctx: &WorkerCtx) -> Result<Vec<Task>> {
        if self.common.is_done() {
            return Ok(Vec::new());
        }
        let mut tasks = Vec::new();
        // one task per currently-visible batch, bounded by max_inflight
        let available = self.input.len();
        let mut budget = available.min(
            self.common
                .max_inflight
                .saturating_sub(self.common.inflight()),
        );
        while budget > 0 {
            budget -= 1;
            self.common.issue();
            let input = self.input.clone();
            let output = self.output.clone();
            let pred = self.pred.clone();
            let run = self.common.track(move |ctx: &WorkerCtx| {
                let db: DeviceBatch = match input.pop_device()? {
                    Some(db) => db,
                    None => return Ok(()), // another task drained it
                };
                let mask = kernels::pred_mask(ctx, &db.batch, &pred)?;
                let kept = db.batch.compact(&mask)?;
                drop(db); // release input device bytes before pushing
                if !kept.is_empty() {
                    output.push_batch(kept)?;
                }
                Ok(())
            });
            tasks.push(
                Task::new(self.common.id, self.common.base_priority, run)
                    .with_input(self.input.clone())
                    .with_prefetch(Prefetch::Promote { holder: self.input.clone() }),
            );
        }
        if self.input.is_exhausted() && self.common.inflight() == 0 {
            self.output.finish();
            self.common.mark_done();
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

pub struct ProjectOp {
    common: Arc<OpCommon>,
    input: BatchHolder,
    output: BatchHolder,
    cols: Arc<Vec<String>>,
}

impl ProjectOp {
    pub fn new(
        id: usize,
        base_priority: i64,
        max_inflight: usize,
        input: BatchHolder,
        output: BatchHolder,
        cols: Vec<String>,
    ) -> ProjectOp {
        ProjectOp {
            common: Arc::new(OpCommon::new(id, base_priority, max_inflight)),
            input,
            output,
            cols: Arc::new(cols),
        }
    }
}

impl Operator for ProjectOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "project"
    }

    fn poll(&self, _ctx: &WorkerCtx) -> Result<Vec<Task>> {
        if self.common.is_done() {
            return Ok(Vec::new());
        }
        let mut tasks = Vec::new();
        let mut budget = self.input.len().min(
            self.common
                .max_inflight
                .saturating_sub(self.common.inflight()),
        );
        while budget > 0 {
            budget -= 1;
            self.common.issue();
            let input = self.input.clone();
            let output = self.output.clone();
            let cols = self.cols.clone();
            let run = self.common.track(move |_ctx: &WorkerCtx| {
                let db = match input.pop_device()? {
                    Some(db) => db,
                    None => return Ok(()),
                };
                let names: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
                let projected = db.batch.project(&names)?;
                drop(db);
                output.push_batch(projected)?;
                Ok(())
            });
            tasks.push(
                Task::new(self.common.id, self.common.base_priority, run)
                    .with_input(self.input.clone()),
            );
        }
        if self.input.is_exhausted() && self.common.inflight() == 0 {
            self.output.finish();
            self.common.mark_done();
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::batch_holder::MemEnv;
    use crate::types::{Column, RecordBatch};

    fn batch(lo: i64, n: i64) -> RecordBatch {
        RecordBatch::new(vec![
            Column::i64("k", (lo..lo + n).collect()),
            Column::f32("v", (0..n).map(|i| i as f32).collect()),
        ])
        .unwrap()
    }

    fn drive(op: &dyn Operator, ctx: &WorkerCtx) {
        for _ in 0..100 {
            let tasks = op.poll(ctx).unwrap();
            for t in tasks {
                (t.run)(ctx).unwrap();
            }
            if op.is_done() {
                break;
            }
        }
    }

    #[test]
    fn filter_keeps_matching_rows() {
        let ctx = WorkerCtx::test();
        let env = MemEnv::test(8 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        input.push_batch(batch(0, 100)).unwrap();
        input.push_batch(batch(100, 100)).unwrap();
        input.finish();
        let op = FilterOp::new(
            1,
            1000,
            2,
            input,
            output.clone(),
            Pred::RangeI64 { col: "k".into(), lo: 50, hi: 150 },
        );
        drive(&op, &ctx);
        assert!(op.is_done());
        assert!(output.is_finished());
        let mut rows = 0;
        let mut keys = Vec::new();
        while let Some(db) = output.pop_device().unwrap() {
            rows += db.rows();
            keys.extend_from_slice(db.batch.column("k").unwrap().data.as_i64().unwrap());
        }
        assert_eq!(rows, 100);
        keys.sort_unstable();
        assert_eq!(keys, (50..150).collect::<Vec<_>>());
    }

    #[test]
    fn filter_drops_empty_batches() {
        let ctx = WorkerCtx::test();
        let env = MemEnv::test(8 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        input.push_batch(batch(0, 50)).unwrap();
        input.finish();
        let op = FilterOp::new(
            1,
            0,
            1,
            input,
            output.clone(),
            Pred::EqI64 { col: "k".into(), val: 9999 },
        );
        drive(&op, &ctx);
        assert!(output.is_exhausted());
    }

    #[test]
    fn filter_waits_for_input_finish() {
        let ctx = WorkerCtx::test();
        let env = MemEnv::test(8 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        input.push_batch(batch(0, 10)).unwrap();
        let op = FilterOp::new(
            1,
            0,
            1,
            input.clone(),
            output.clone(),
            Pred::RangeI64 { col: "k".into(), lo: 0, hi: 100 },
        );
        drive(&op, &ctx);
        assert!(!op.is_done(), "must not finish before input does");
        input.finish();
        drive(&op, &ctx);
        assert!(op.is_done());
    }

    #[test]
    fn project_subsets_and_orders_columns() {
        let ctx = WorkerCtx::test();
        let env = MemEnv::test(8 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        input.push_batch(batch(0, 20)).unwrap();
        input.finish();
        let op = ProjectOp::new(2, 0, 1, input, output.clone(), vec!["v".into()]);
        drive(&op, &ctx);
        let db = output.pop_device().unwrap().unwrap();
        assert_eq!(db.batch.num_columns(), 1);
        assert_eq!(db.batch.columns[0].name, "v");
    }

    #[test]
    fn project_missing_column_is_permanent_error() {
        let ctx = WorkerCtx::test();
        let env = MemEnv::test(8 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        input.push_batch(batch(0, 5)).unwrap();
        input.finish();
        let op = ProjectOp::new(2, 0, 1, input, output, vec!["nope".into()]);
        let tasks = op.poll(&ctx).unwrap();
        let e = (tasks[0].run)(&ctx).unwrap_err();
        assert!(!e.is_retryable());
    }
}
