//! Fragment operator: materializes a cache-resident subplan result
//! (serving layer, see [`crate::cache`]) into the DAG in place of the
//! scan→filter→agg pipeline that originally produced it.
//!
//! The fragment bytes travel inside the plan ([`OpSpec::Fragment`]);
//! every worker holds the full batch but emits only its disjoint row
//! slice `[wid·n/W, (wid+1)·n/W)`, so downstream operators and the
//! client-side gather see exactly one copy of every row — the same
//! contract a Scan's file assignment provides.
//!
//! [`OpSpec::Fragment`]: crate::exec::plan::OpSpec::Fragment

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::exec::operators::{OpCommon, Operator};
use crate::exec::task::Task;
use crate::exec::WorkerCtx;
use crate::memory::BatchHolder;
use crate::types::RecordBatch;
use crate::Result;

pub struct FragmentOp {
    common: Arc<OpCommon>,
    output: BatchHolder,
    /// Encoded [`RecordBatch`] (the gathered fragment result).
    data: Arc<Vec<u8>>,
    issued: AtomicBool,
}

impl FragmentOp {
    pub fn new(
        id: usize,
        base_priority: i64,
        output: BatchHolder,
        data: Arc<Vec<u8>>,
    ) -> FragmentOp {
        FragmentOp {
            common: Arc::new(OpCommon::new(id, base_priority, 1)),
            output,
            data,
            issued: AtomicBool::new(false),
        }
    }

    /// This worker's half-open row range of an `n`-row fragment.
    pub fn slice_bounds(n: usize, wid: usize, workers: usize) -> (usize, usize) {
        (wid * n / workers, (wid + 1) * n / workers)
    }
}

impl Operator for FragmentOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "fragment"
    }

    fn poll(&self, ctx: &WorkerCtx) -> Result<Vec<Task>> {
        if self.common.is_done() {
            return Ok(Vec::new());
        }
        let mut tasks = Vec::new();
        if !self.issued.swap(true, Ordering::AcqRel) {
            self.common.issue();
            let output = self.output.clone();
            let data = self.data.clone();
            let wid = ctx.worker_id;
            let workers = ctx.num_workers();
            let run = self.common.track(move |_ctx| {
                let batch = RecordBatch::decode(&data)?;
                let (lo, hi) = FragmentOp::slice_bounds(batch.rows(), wid, workers);
                if hi > lo {
                    output.push_batch(batch.slice(lo, hi - lo)?)?;
                }
                output.finish();
                Ok(())
            });
            tasks.push(Task::new(self.common.id, self.common.base_priority, run));
        }
        if self.issued.load(Ordering::Acquire) && self.common.inflight() == 0 {
            self.common.mark_done();
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::batch_holder::MemEnv;
    use crate::types::Column;

    fn drive(op: &dyn Operator, ctx: &WorkerCtx) {
        for _ in 0..50 {
            for t in op.poll(ctx).unwrap() {
                (t.run)(ctx).unwrap();
            }
            if op.is_done() {
                break;
            }
        }
    }

    #[test]
    fn slices_cover_rows_disjointly() {
        for n in [0usize, 1, 7, 100] {
            for workers in [1usize, 2, 3, 8] {
                let mut covered = 0;
                for w in 0..workers {
                    let (lo, hi) = FragmentOp::slice_bounds(n, w, workers);
                    assert!(lo <= hi && hi <= n);
                    assert_eq!(lo, covered, "gap/overlap at worker {w}");
                    covered = hi;
                }
                assert_eq!(covered, n, "rows dropped for n={n} W={workers}");
            }
        }
    }

    #[test]
    fn emits_this_workers_slice_and_finishes() {
        let ctx = WorkerCtx::test(); // worker 0 of 1
        let env = MemEnv::test(8 << 20);
        let out = BatchHolder::new("out", env);
        let batch =
            RecordBatch::new(vec![Column::i64("k", (0..10).collect())]).unwrap();
        let op = FragmentOp::new(0, 0, out.clone(), Arc::new(batch.encode()));
        drive(&op, &ctx);
        assert!(op.is_done());
        let got = out.pop_device().unwrap().unwrap();
        assert_eq!(got.batch.encode(), batch.encode());
        assert!(out.is_exhausted());
    }
}
