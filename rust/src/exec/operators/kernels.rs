//! Device-stage wrappers: chunk a batch to the AOT shape, call the
//! PJRT executable, fall back to host scalar code when no registry is
//! available (unit tests) or the dtype has no stage.
//!
//! Every wrapper charges the modeled device-compute throttle for the
//! bytes it processes — the PJRT CPU path under-costs a real GPU, so
//! the throttle restores the paper's device/wire/storage speed *ratios*
//! (DESIGN.md §Hardware-Adaptation).

use crate::exec::plan::Pred;
use crate::exec::WorkerCtx;
use crate::runtime::Value;
use crate::types::{ColumnData, DType, RecordBatch};
use crate::util::hash;
use crate::{Error, Result};

/// Rows per device launch (the AOT static shape).
pub fn batch_rows(ctx: &WorkerCtx) -> usize {
    ctx.registry
        .as_ref()
        .map(|r| r.manifest().batch_rows)
        .unwrap_or(ctx.config.batch_rows)
}

fn charge(ctx: &WorkerCtx, bytes: usize) {
    ctx.device_compute.acquire(bytes);
}

// ---------------------------------------------------------------- filter

/// Evaluate `pred` over `batch`, returning a 0/1 keep-mask.
pub fn pred_mask(ctx: &WorkerCtx, batch: &RecordBatch, pred: &Pred) -> Result<Vec<i32>> {
    let rows = batch.rows();
    let mut mask = vec![1i32; rows];
    for conjunct in pred.conjuncts() {
        apply_conjunct(ctx, batch, conjunct, &mut mask)?;
    }
    Ok(mask)
}

fn apply_conjunct(
    ctx: &WorkerCtx,
    batch: &RecordBatch,
    pred: &Pred,
    mask: &mut [i32],
) -> Result<()> {
    let rows = batch.rows();
    match pred {
        Pred::RangeF32 { col, lo, hi } => {
            let c = batch.column(col)?;
            let v = c.data.as_f32()?;
            charge(ctx, rows * 4);
            if let Some(reg) = &ctx.registry {
                let n = reg.manifest().batch_rows;
                for start in (0..rows).step_by(n) {
                    let len = n.min(rows - start);
                    let out = reg.execute(
                        "filter_range_f32",
                        &[
                            Value::F32(v[start..start + len].to_vec()),
                            Value::scalar_f32(*lo),
                            Value::scalar_f32(*hi),
                            Value::I32(mask[start..start + len].to_vec()),
                        ],
                    )?;
                    mask[start..start + len]
                        .copy_from_slice(&out[0].as_i32()?[..len]);
                }
            } else {
                for i in 0..rows {
                    if !(v[i] >= *lo && v[i] < *hi) {
                        mask[i] = 0;
                    }
                }
            }
        }
        Pred::RangeI64 { col, lo, hi } => {
            let c = batch.column(col)?;
            let v = c.data.as_i64()?;
            charge(ctx, rows * 8);
            if let Some(reg) = &ctx.registry {
                let n = reg.manifest().batch_rows;
                for start in (0..rows).step_by(n) {
                    let len = n.min(rows - start);
                    let out = reg.execute(
                        "filter_range_i64",
                        &[
                            Value::I64(v[start..start + len].to_vec()),
                            Value::I64(vec![*lo]),
                            Value::I64(vec![*hi]),
                            Value::I32(mask[start..start + len].to_vec()),
                        ],
                    )?;
                    mask[start..start + len]
                        .copy_from_slice(&out[0].as_i32()?[..len]);
                }
            } else {
                for i in 0..rows {
                    if !(v[i] >= *lo && v[i] < *hi) {
                        mask[i] = 0;
                    }
                }
            }
        }
        Pred::EqI64 { col, val } => {
            let c = batch.column(col)?;
            let v = c.data.as_i64()?;
            charge(ctx, rows * 8);
            if let Some(reg) = &ctx.registry {
                let n = reg.manifest().batch_rows;
                for start in (0..rows).step_by(n) {
                    let len = n.min(rows - start);
                    let out = reg.execute(
                        "filter_eq_i64",
                        &[
                            Value::I64(v[start..start + len].to_vec()),
                            Value::I64(vec![*val]),
                            Value::I32(mask[start..start + len].to_vec()),
                        ],
                    )?;
                    mask[start..start + len]
                        .copy_from_slice(&out[0].as_i32()?[..len]);
                }
            } else {
                for i in 0..rows {
                    if v[i] != *val {
                        mask[i] = 0;
                    }
                }
            }
        }
        Pred::And(a, b) => {
            apply_conjunct(ctx, batch, a, mask)?;
            apply_conjunct(ctx, batch, b, mask)?;
        }
    }
    Ok(())
}

// ------------------------------------------------------------- partition

/// Hash-partition ids for exchange keys; `parts` must match the AOT
/// fanout when the registry path is used.
pub fn partition_ids(ctx: &WorkerCtx, keys: &[i64], parts: u32) -> Result<Vec<i32>> {
    charge(ctx, keys.len() * 8);
    if let Some(reg) = &ctx.registry {
        if parts as usize == reg.manifest().num_parts {
            let n = reg.manifest().batch_rows;
            let mut out = Vec::with_capacity(keys.len());
            for start in (0..keys.len()).step_by(n) {
                let len = n.min(keys.len() - start);
                let r = reg.execute(
                    "hash_partition",
                    &[
                        Value::I64(keys[start..start + len].to_vec()),
                        Value::I32(vec![1; len]),
                    ],
                )?;
                out.extend_from_slice(&r[0].as_i32()?[..len]);
            }
            return Ok(out);
        }
    }
    Ok(keys
        .iter()
        .map(|&k| hash::partition_id(k, parts) as i32)
        .collect())
}

/// Destination layout of one partition scatter: `perm` lists the batch's
/// row indices grouped by destination, `offsets[d]..offsets[d+1]` being
/// destination `d`'s slice. Rows keep their batch-relative order within
/// a destination, so the scatter is stable and byte-comparable to the
/// per-destination `take` gathers it replaces.
pub struct ScatterPlan {
    perm: Vec<u32>,
    /// `dests + 1` exclusive prefix sums over the destination histogram.
    offsets: Vec<usize>,
}

impl ScatterPlan {
    pub fn dests(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn total_rows(&self) -> usize {
        self.perm.len()
    }

    /// Row indices bound for destination `dst`, in batch order.
    pub fn rows_for(&self, dst: usize) -> &[u32] {
        &self.perm[self.offsets[dst]..self.offsets[dst + 1]]
    }
}

/// Build a [`ScatterPlan`] from partition ids when the per-destination
/// histogram is already known (the device path's `hash_partition` stage
/// returns one per launch): a single placement pass over `ids`.
fn scatter_with_counts(ids: &[i32], counts: &[usize]) -> ScatterPlan {
    let dests = counts.len();
    let mut offsets = vec![0usize; dests + 1];
    for d in 0..dests {
        offsets[d + 1] = offsets[d] + counts[d];
    }
    let mut cursor = offsets[..dests].to_vec();
    let mut perm = vec![0u32; ids.len()];
    for (row, &p) in ids.iter().enumerate() {
        let d = (p as u32 as usize) % dests;
        perm[cursor[d]] = row as u32;
        cursor[d] += 1;
    }
    ScatterPlan { perm, offsets }
}

/// Histogram → exclusive prefix sum → scatter over precomputed
/// partition ids (rows for partition `p` go to destination
/// `p % dests`). Pure host reference used by the fallback path and the
/// shuffle property tests.
pub fn scatter_plan(ids: &[i32], dests: usize) -> ScatterPlan {
    let mut counts = vec![0usize; dests.max(1)];
    for &p in ids {
        counts[(p as u32 as usize) % dests.max(1)] += 1;
    }
    scatter_with_counts(ids, &counts)
}

/// Single-pass partition scatter for the coalescing exchange: partition
/// `keys` into `parts` and return the per-destination row layout in one
/// go. The device path reuses the `hash_partition` stage's histogram
/// output (the host never re-counts the ids — it only places them);
/// without a registry, ids and the destination histogram are computed
/// together in one host pass. Replaces `route`'s per-destination
/// `Vec<Vec<u32>>` push loop + N independent `take` gathers.
pub fn partition_scatter(
    ctx: &WorkerCtx,
    keys: &[i64],
    parts: u32,
    dests: usize,
) -> Result<ScatterPlan> {
    charge(ctx, keys.len() * 8);
    let dests = dests.max(1);
    if let Some(reg) = &ctx.registry {
        if parts as usize == reg.manifest().num_parts {
            let n = reg.manifest().batch_rows;
            let mut ids = Vec::with_capacity(keys.len());
            let mut counts = vec![0usize; dests];
            for start in (0..keys.len()).step_by(n) {
                let len = n.min(keys.len() - start);
                let r = reg.execute(
                    "hash_partition",
                    &[
                        Value::I64(keys[start..start + len].to_vec()),
                        Value::I32(vec![1; len]),
                    ],
                )?;
                ids.extend_from_slice(&r[0].as_i32()?[..len]);
                for (p, &c) in r[1].as_i32()?.iter().enumerate() {
                    counts[p % dests] += c as usize;
                }
            }
            if counts.iter().sum::<usize>() == ids.len() {
                return Ok(scatter_with_counts(&ids, &counts));
            }
            // a histogram that disagrees with the id count would make
            // the placement pass write out of bounds — recount on host
            // (correctness over the saved pass) and say so
            log::warn!("hash_partition histogram/id mismatch; host recount");
            return Ok(scatter_plan(&ids, dests));
        }
    }
    // host fallback: ids and the destination histogram in one pass,
    // then the placement pass
    let mut ids = Vec::with_capacity(keys.len());
    let mut counts = vec![0usize; dests];
    for &k in keys {
        let p = hash::partition_id(k, parts) as i32;
        counts[(p as usize) % dests] += 1;
        ids.push(p);
    }
    Ok(scatter_with_counts(&ids, &counts))
}

// ----------------------------------------------------------------- bloom

/// Build a bloom filter over `keys` (OR-merged across launches).
pub fn bloom_build(ctx: &WorkerCtx, keys: &[i64], bits: usize) -> Result<Vec<u32>> {
    charge(ctx, keys.len() * 8);
    if let Some(reg) = &ctx.registry {
        if bits == reg.manifest().bloom_bits {
            let n = reg.manifest().batch_rows;
            let mut cells = vec![0u32; bits];
            for start in (0..keys.len()).step_by(n) {
                let len = n.min(keys.len() - start);
                let r = reg.execute(
                    "bloom_build",
                    &[
                        Value::I64(keys[start..start + len].to_vec()),
                        Value::I32(vec![1; len]),
                    ],
                )?;
                for (c, &v) in cells.iter_mut().zip(r[0].as_u32()?) {
                    *c |= v;
                }
            }
            return Ok(cells);
        }
    }
    let mut cells = vec![0u32; bits];
    for &k in keys {
        let (a, b) = hash::bloom_lanes(k, bits as u64);
        cells[a] = 1;
        cells[b] = 1;
    }
    Ok(cells)
}

/// Probe: 1 where the key may be present.
pub fn bloom_probe(ctx: &WorkerCtx, keys: &[i64], cells: &[u32]) -> Result<Vec<i32>> {
    charge(ctx, keys.len() * 8);
    if let Some(reg) = &ctx.registry {
        if cells.len() == reg.manifest().bloom_bits {
            let n = reg.manifest().batch_rows;
            let mut out = Vec::with_capacity(keys.len());
            for start in (0..keys.len()).step_by(n) {
                let len = n.min(keys.len() - start);
                let r = reg.execute(
                    "bloom_probe",
                    &[
                        Value::I64(keys[start..start + len].to_vec()),
                        Value::I32(vec![1; len]),
                        Value::U32(cells.to_vec()),
                    ],
                )?;
                out.extend_from_slice(&r[0].as_i32()?[..len]);
            }
            return Ok(out);
        }
    }
    Ok(keys
        .iter()
        .map(|&k| {
            let (a, b) = hash::bloom_lanes(k, cells.len() as u64);
            (cells[a] != 0 && cells[b] != 0) as i32
        })
        .collect())
}

// ------------------------------------------------------------------ agg

/// Device pre-aggregation result for one launch.
pub struct PreAgg {
    pub bucket_of_row: Vec<i32>,
    pub sums: Vec<f32>,
    pub counts: Vec<i32>,
    pub mins: Vec<f32>,
    pub maxs: Vec<f32>,
}

/// Run the device pre-aggregation over (keys, f32 vals). Returns `None`
/// when no registry (callers host-aggregate instead).
pub fn bucket_preagg(
    ctx: &WorkerCtx,
    keys: &[i64],
    vals: &[f32],
) -> Result<Option<Vec<PreAgg>>> {
    charge(ctx, keys.len() * 12);
    let reg = match &ctx.registry {
        Some(r) => r,
        None => return Ok(None),
    };
    let n = reg.manifest().batch_rows;
    let mut out = Vec::new();
    for start in (0..keys.len()).step_by(n) {
        let len = n.min(keys.len() - start);
        let r = reg.execute(
            "bucket_preagg",
            &[
                Value::I64(keys[start..start + len].to_vec()),
                Value::F32(vals[start..start + len].to_vec()),
                Value::I32(vec![1; len]),
            ],
        )?;
        out.push(PreAgg {
            bucket_of_row: r[0].as_i32()?[..len].to_vec(),
            sums: r[1].as_f32()?.to_vec(),
            counts: r[2].as_i32()?.to_vec(),
            mins: r[3].as_f32()?.to_vec(),
            maxs: r[4].as_f32()?.to_vec(),
        });
    }
    Ok(Some(out))
}

// ------------------------------------------------------------- utilities

/// Extract i64-backed key column or fail with a plan error.
pub fn key_column<'a>(batch: &'a RecordBatch, col: &str) -> Result<&'a [i64]> {
    let c = batch.column(col)?;
    if c.dtype == DType::Float32 || c.dtype == DType::Float64 {
        return Err(Error::Plan(format!(
            "column '{col}' is {}, not a valid hash key",
            c.dtype
        )));
    }
    c.data.as_i64()
}

/// Value column as f32 for the device agg path (f32 columns only).
pub fn f32_column(batch: &RecordBatch, col: &str) -> Option<Vec<f32>> {
    batch
        .column(col)
        .ok()
        .and_then(|c| match &c.data {
            ColumnData::F32(v) => Some(v.clone()),
            _ => None,
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Column;

    fn batch() -> RecordBatch {
        RecordBatch::new(vec![
            Column::i64("k", (0..100).collect()),
            Column::f32("v", (0..100).map(|i| i as f32).collect()),
        ])
        .unwrap()
    }

    #[test]
    fn host_fallback_pred_mask() {
        let ctx = WorkerCtx::test();
        let b = batch();
        let pred = Pred::RangeI64 { col: "k".into(), lo: 10, hi: 20 }
            .and(Pred::RangeF32 { col: "v".into(), lo: 0.0, hi: 15.0 });
        let m = pred_mask(&ctx, &b, &pred).unwrap();
        let kept: Vec<usize> = (0..100).filter(|&i| m[i] != 0).collect();
        assert_eq!(kept, (10..15).collect::<Vec<_>>());
    }

    #[test]
    fn host_fallback_partition_matches_util_hash() {
        let ctx = WorkerCtx::test();
        let keys: Vec<i64> = (0..50).map(|i| i * 13).collect();
        let ids = partition_ids(&ctx, &keys, 8).unwrap();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(ids[i] as u32, hash::partition_id(k, 8));
        }
    }

    #[test]
    fn host_fallback_bloom_no_false_negatives() {
        let ctx = WorkerCtx::test();
        let keys: Vec<i64> = (0..100).map(|i| i * 3 + 1).collect();
        let cells = bloom_build(&ctx, &keys, 4096).unwrap();
        let hits = bloom_probe(&ctx, &keys, &cells).unwrap();
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn device_paths_match_host_fallbacks() {
        // Requires artifacts; the registry path must agree with host.
        let Ok(dev) = WorkerCtx::test_with_registry() else {
            return;
        };
        let host = WorkerCtx::test();
        let b = batch();
        let pred = Pred::RangeF32 { col: "v".into(), lo: 5.0, hi: 50.0 };
        assert_eq!(
            pred_mask(&dev, &b, &pred).unwrap(),
            pred_mask(&host, &b, &pred).unwrap()
        );
        let keys: Vec<i64> = (0..200).map(|i| i * 7 - 3).collect();
        assert_eq!(
            partition_ids(&dev, &keys, 16).unwrap(),
            partition_ids(&host, &keys, 16).unwrap()
        );
        let bits = dev.registry.as_ref().unwrap().manifest().bloom_bits;
        let dc = bloom_build(&dev, &keys, bits).unwrap();
        let hc = bloom_build(&host, &keys, bits).unwrap();
        assert_eq!(dc, hc);
        assert_eq!(
            bloom_probe(&dev, &keys, &dc).unwrap(),
            bloom_probe(&host, &keys, &hc).unwrap()
        );
    }

    #[test]
    fn scatter_plan_matches_per_destination_take_lists() {
        // The scatter must reproduce the seed routing exactly: rows for
        // partition p at destination p % workers, in batch order.
        let ctx = WorkerCtx::test();
        let keys: Vec<i64> = (0..333).map(|i| i * 31 - 77).collect();
        for workers in [1usize, 2, 5, 8] {
            let plan = partition_scatter(&ctx, &keys, 16, workers).unwrap();
            assert_eq!(plan.dests(), workers);
            assert_eq!(plan.total_rows(), keys.len());
            let mut by_dst: Vec<Vec<u32>> = vec![Vec::new(); workers];
            for (row, &k) in keys.iter().enumerate() {
                by_dst[hash::partition_id(k, 16) as usize % workers].push(row as u32);
            }
            for (dst, want) in by_dst.iter().enumerate() {
                assert_eq!(plan.rows_for(dst), &want[..], "dst {dst} of {workers}");
            }
        }
    }

    #[test]
    fn scatter_plan_handles_empty_and_single_dest() {
        let plan = scatter_plan(&[], 4);
        assert_eq!(plan.total_rows(), 0);
        for d in 0..4 {
            assert!(plan.rows_for(d).is_empty());
        }
        let plan = scatter_plan(&[3, 1, 2], 1);
        assert_eq!(plan.rows_for(0), &[0, 1, 2]);
    }

    #[test]
    fn device_scatter_matches_host_fallback() {
        let Ok(dev) = WorkerCtx::test_with_registry() else {
            return;
        };
        let host = WorkerCtx::test();
        let keys: Vec<i64> = (0..20_000).map(|i| i * 7 - 3).collect();
        let parts = dev.registry.as_ref().unwrap().manifest().num_parts as u32;
        for workers in [3usize, 16] {
            let d = partition_scatter(&dev, &keys, parts, workers).unwrap();
            let h = partition_scatter(&host, &keys, parts, workers).unwrap();
            assert_eq!(d.total_rows(), h.total_rows());
            for dst in 0..workers {
                assert_eq!(d.rows_for(dst), h.rows_for(dst), "dst {dst}");
            }
        }
    }

    #[test]
    fn key_column_rejects_floats() {
        let b = batch();
        assert!(key_column(&b, "k").is_ok());
        assert!(key_column(&b, "v").is_err());
        assert!(key_column(&b, "nope").is_err());
    }
}
