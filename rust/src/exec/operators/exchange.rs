//! Adaptive Exchange (§3.2).
//!
//! "An Adaptive Exchange operator exists as a pair, one for each side
//! of a join. ... First, it waits to accumulate enough input batches to
//! estimate the total bytes it will receive, and broadcasts that
//! information to paired Adaptive Exchange operators in all workers.
//! These operators are adaptive because based on the estimates, they
//! decide whether to hash partition or broadcast the data in the second
//! phase. ... The algorithm using an estimate of the data sizes to
//! arrive instead of waiting for all the data to arrive minimizes
//! interruption of data flow through the DAG by allowing phase two
//! tasks to be scheduled sooner."
//!
//! Phases: `Accumulate` (stage the first K batches in a spillable
//! holder and count bytes) → `WaitEstimates` (estimate broadcast to all
//! peers, wait for theirs) → `Stream` (hash-partition or broadcast each
//! batch through the Network Executor) → `Done` (Finish sent to all
//! peers). The receiving side is the [`ChannelRx`] holder the worker
//! registered for this operator's channel; it finishes when every
//! peer's Finish arrives.
//!
//! ## Destination-coalesced shuffle (§3.4, §4.1)
//!
//! Hash-partitioning used to fragment every input batch into
//! per-destination slivers of a few hundred rows, each encoded, framed,
//! compressed, and sent as its own message — `batches × workers` tiny
//! frames, each paying header + codec + syscall overhead. The `Stream`
//! phase now scatters rows in a single pass
//! ([`kernels::partition_scatter`]: histogram → prefix sum → placement,
//! reusing the device `hash_partition` stage's histogram when
//! available) into per-destination [`ShuffleCoalescer`] buffers
//! (append-only [`crate::types::BatchBuilder`] column accumulators). A
//! destination flushes only when
//!
//! * its buffer crosses that destination's *current* flush threshold
//!   (adaptive — see below),
//! * the upstream finishes (final drain before Finish), or
//! * the worker's memory-pressure epoch advances
//!   ([`crate::memory::PressureEvent::memory_raise_count`], installed
//!   by the Data-Movement executor) — buffered shuffle state drains
//!   *early* under pressure instead of deepening a spill cycle.
//!
//! Flushes are slab-native:
//! [`send_batch_pooled`](crate::executors::network::Outbox::send_batch_pooled)
//! encodes the coalesced batch straight into a
//! `SlabWriter` from the worker's bounce pool (heap fallback when dry,
//! counted), so the old `StagedBytes::Heap(batch.encode())` bounce is
//! gone from the shuffle path. Metrics: `exchange.flush_total`,
//! `exchange.coalesced_bytes`, `exchange.pressure_flush_total`, plus
//! the live `exchange.buffered_bytes` gauge and the per-destination
//! `exchange.flush_bytes_current{dst=N}` gauges.
//!
//! ## Feedback-driven flush control (§3.3: when/where/how from
//! observed state)
//!
//! The flush point is a per-destination *controller*, not a static
//! knob. Each destination's threshold starts at
//! `exchange_flush_bytes` and adapts inside
//! `[exchange_flush_floor_bytes, exchange_flush_ceiling_bytes]` (the
//! ceiling is clamped to `max_frame_bytes / 2` by config validation;
//! floor == ceiling pins the threshold and disables adaptation — what
//! [`ShuffleCoalescer::new`] does for tests and benches).
//!
//! **Signals** — sampled from the worker's [`Outbox`] on every append
//! to the destination:
//! * *outbox depth* ([`Outbox::queued_for`]): frames already queued for
//!   this destination that its sender lane has not popped;
//! * *send latency* ([`Outbox::send_latency_ns`]): the lanes' EWMA of
//!   `endpoint.send` wall time toward this destination, compared
//!   against the best (lowest) EWMA ever observed for it — the
//!   uncongested wire baseline.
//!
//! **Rule** — congestion (depth ≥ 2, or latency above 2× the baseline)
//! halves the threshold toward the floor: a congested path flushes
//! small and early so buffered rows don't sit behind a slow peer and
//! credit-gated lanes get finer-grained frames to interleave. An idle
//! path (depth 0, no spike) grows the threshold by ¼ toward the
//! ceiling: a fast path coalesces bigger, slab-friendlier frames.
//! Anything in between holds. Every move is published on the
//! `exchange.flush_bytes_current{dst=N}` gauge.
//!
//! **Governor accounting** — builder bytes are no longer invisible heap:
//! each destination shard holds a [`Reservation`] that grows on append
//! and shrinks on flush, so buffered shuffle state competes with
//! compute reservations in [`MemoryGovernor`] accounting. When a grow
//! is refused, the shard raises pressure non-blockingly
//! ([`MemoryGovernor::raise_pressure`]) — which advances the very
//! pressure epoch the coalescer's early-flush trigger polls, so a
//! self-induced squeeze makes the exchange shed its own buffers.
//!
//! **Sharding** — builders live behind per-destination locks rather
//! than one per-exchange mutex. This matters exactly when the exchange
//! is busiest: several stream tasks scatter concurrently and their
//! gather-append memcpys land on different destinations, so they no
//! longer serialize on a single lock (they only ever collide on the
//! same destination shard). The pressure-epoch claim is a lone atomic
//! compare-exchange, so a sweep is claimed by exactly one task.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::sync::{ranks, OrderedMutex};

use crate::exec::operators::kernels::ScatterPlan;
use crate::exec::operators::{kernels, OpCommon, Operator};
use crate::exec::plan::ExchangeRole;
use crate::exec::task::{Prefetch, Task};
use crate::exec::WorkerCtx;
use crate::executors::network::{ChannelRx, Outbox};
use crate::memory::{BatchHolder, MemoryGovernor, PressureEvent, Reservation};
use crate::metrics::Metrics;
use crate::types::{BatchBuilder, RecordBatch};
use crate::Result;

/// Phase-two routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Rows routed to `hash(key) % workers`.
    HashPartition,
    /// Every batch goes to every worker (small join build side).
    Broadcast,
    /// Rows stay on this worker (probe side of a broadcast join).
    PassThrough,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Accumulate,
    WaitEstimates,
    Stream,
    Done,
}

/// Growth factor applied to early-seen bytes when the input hasn't
/// finished (the paper estimates from a prefix; upstream totals are
/// unknown at this point in the DAG).
const EST_GROWTH: f64 = 4.0;

/// A destination at least this many frames deep in the outbox is
/// congested: its sender lane is not keeping up (or is credit-gated),
/// so flushing smaller helps nothing pile up behind it.
const CONGESTED_DEPTH: usize = 2;

/// Send-latency EWMA above this multiple of the best-ever EWMA toward
/// the destination counts as a latency spike.
const LAT_SPIKE_MULT: u64 = 2;

/// One destination's coalescing state, behind its own lock (see the
/// module doc's sharding note).
struct DestShard {
    builder: BatchBuilder,
    /// Current adaptive flush threshold (within `[floor, ceiling]`).
    flush_bytes: usize,
    /// Lowest send-latency EWMA ever observed toward this destination —
    /// the uncongested baseline a spike is measured against.
    base_latency_ns: Option<u64>,
    /// Governor reservation covering the builder's buffered bytes
    /// (created on first use; `None` until then or in static mode).
    reservation: Option<Reservation>,
}

/// Per-destination shuffle coalescing buffers (see the module doc).
///
/// One instance per hash-partitioning exchange, shared by its stream
/// tasks: appends are scatter placements into per-destination
/// [`BatchBuilder`] shards (each behind its own lock), and the three
/// flush triggers (adaptive size threshold, final drain,
/// memory-pressure epoch advance) hand back whole coalesced
/// `RecordBatch`es for the caller to send. The pressure check is one
/// atomic compare-exchange against the epoch observed last time — no
/// subscription, no callback plumbing, and exactly one concurrent task
/// claims each epoch's sweep.
pub struct ShuffleCoalescer {
    /// All shards share one rank (`exchange.shard`): a task holds at
    /// most one at a time, and the runtime checker enforces exactly
    /// that (same-rank nesting panics).
    shards: Vec<OrderedMutex<DestShard>>,
    /// Adaptation bounds; `floor == ceiling` pins the threshold
    /// (static mode — [`ShuffleCoalescer::new`]).
    floor: usize,
    ceiling: usize,
    /// Congestion-signal source; `None` disables adaptation.
    outbox: Option<Arc<Outbox>>,
    /// Builder bytes reserve here; `None` leaves them unaccounted.
    governor: Option<MemoryGovernor>,
    pressure: Option<Arc<PressureEvent>>,
    /// Memory-pressure epoch at the last sweep; an advance flushes.
    seen_epoch: AtomicU64,
    metrics: Arc<Metrics>,
}

/// Leaked-once gauge name `exchange.flush_bytes_current{dst=N}` — the
/// metrics registry keys on `&'static str`, and the set of destinations
/// is bounded by cluster width, so the leak is a one-time cost per
/// process, not a growth path.
fn flush_gauge_name(dst: usize) -> &'static str {
    static NAMES: OnceLock<Mutex<HashMap<usize, &'static str>>> = OnceLock::new();
    let cache = NAMES.get_or_init(|| Mutex::new(HashMap::new()));
    let mut cache = cache.lock().unwrap();
    cache
        .entry(dst)
        .or_insert_with(|| {
            Box::leak(
                format!("exchange.flush_bytes_current{{dst={dst}}}").into_boxed_str(),
            )
        })
}

impl ShuffleCoalescer {
    /// Static-threshold coalescer: floor == ceiling == `flush_bytes`,
    /// no signal source, no governor accounting. What tests, benches,
    /// and the static-vs-adaptive comparison use.
    pub fn new(
        dests: usize,
        flush_bytes: usize,
        pressure: Option<Arc<PressureEvent>>,
        metrics: Arc<Metrics>,
    ) -> ShuffleCoalescer {
        Self::with_policy(dests, flush_bytes, flush_bytes, flush_bytes, pressure, None, None, metrics)
    }

    /// Full feedback-driven coalescer: per-destination thresholds start
    /// at `start` and adapt inside `[floor, ceiling]` from `outbox`
    /// depth/latency signals; builder bytes are accounted against
    /// `governor` when present. See the module doc for the rule.
    #[allow(clippy::too_many_arguments)]
    pub fn with_policy(
        dests: usize,
        start: usize,
        floor: usize,
        ceiling: usize,
        pressure: Option<Arc<PressureEvent>>,
        outbox: Option<Arc<Outbox>>,
        governor: Option<MemoryGovernor>,
        metrics: Arc<Metrics>,
    ) -> ShuffleCoalescer {
        let floor = floor.max(1);
        let ceiling = ceiling.max(floor);
        let start = start.clamp(floor, ceiling);
        let seen_epoch = pressure.as_ref().map_or(0, |e| e.memory_raise_count());
        ShuffleCoalescer {
            shards: (0..dests.max(1))
                .map(|_| {
                    OrderedMutex::new(
                        ranks::EXCHANGE_SHARD,
                        "exchange.shard",
                        DestShard {
                            builder: BatchBuilder::new(),
                            flush_bytes: start,
                            base_latency_ns: None,
                            reservation: None,
                        },
                    )
                })
                .collect(),
            floor,
            ceiling,
            outbox,
            governor,
            pressure,
            seen_epoch: AtomicU64::new(seen_epoch),
            metrics,
        }
    }

    pub fn buffered_rows(&self) -> usize {
        self.shards.iter().map(|s| s.lock().builder.rows()).sum()
    }

    /// Number of destinations this coalescer scatters to.
    pub fn num_dests(&self) -> usize {
        self.shards.len()
    }

    /// The current flush threshold for `dst` (test/bench
    /// observability; also published on
    /// `exchange.flush_bytes_current{dst=N}`).
    pub fn flush_threshold(&self, dst: usize) -> usize {
        self.shards[dst].lock().flush_bytes
    }

    /// Keep the worker-level `exchange.buffered_bytes` gauge in step
    /// with the builders (the governor reservation is per-exchange
    /// accounting; the gauge is the worker-wide view).
    fn note_buffered(&self, delta: i64) {
        if delta != 0 {
            self.metrics.gauge("exchange.buffered_bytes").add(delta);
        }
    }

    /// Builder grew by `delta` bytes: mirror it into the gauge and the
    /// shard's governor reservation. A refused grow cannot block an
    /// append mid-scatter, so it raises device pressure instead — the
    /// pressure epoch advances, and the coalescer's own early-flush
    /// trigger drains the buffers it just failed to reserve for.
    fn account_grow(&self, shard: &mut DestShard, delta: usize) {
        self.note_buffered(delta as i64);
        let Some(gov) = &self.governor else { return };
        if shard.reservation.is_none() {
            shard.reservation = gov.try_reserve(0);
        }
        let grown = match shard.reservation.as_mut() {
            Some(res) => res.grow(delta).is_ok(),
            None => false,
        };
        if !grown {
            gov.raise_pressure(delta);
        }
    }

    /// Builder shed `delta` bytes (flush or drop): settle the gauge and
    /// hand the reservation back. The shrink clamps to what is actually
    /// held, so bytes whose grow was refused never over-release.
    fn account_shrink(&self, shard: &mut DestShard, delta: usize) {
        self.note_buffered(-(delta as i64));
        if let Some(res) = shard.reservation.as_mut() {
            res.shrink(delta);
        }
    }

    /// Re-aim `dst`'s flush threshold from the outbox's depth and
    /// latency signals (no-op in static mode).
    fn adapt(&self, dst: usize, shard: &mut DestShard) {
        let Some(outbox) = &self.outbox else { return };
        if self.floor == self.ceiling {
            return;
        }
        let depth = outbox.queued_for(dst);
        let latency = outbox.send_latency_ns(dst);
        let spike = match (latency, shard.base_latency_ns) {
            (Some(l), Some(base)) => l > base.saturating_mul(LAT_SPIKE_MULT),
            _ => false,
        };
        if let Some(l) = latency {
            shard.base_latency_ns = Some(shard.base_latency_ns.map_or(l, |b| b.min(l)));
        }
        let cur = shard.flush_bytes;
        let next = if depth >= CONGESTED_DEPTH || spike {
            (cur / 2).max(self.floor)
        } else if depth == 0 && !spike {
            cur.saturating_add((cur / 4).max(1)).min(self.ceiling)
        } else {
            cur
        };
        if next != cur {
            shard.flush_bytes = next;
            self.metrics.gauge(flush_gauge_name(dst)).set(next as i64);
        }
    }

    fn flush_shard(&self, shard: &mut DestShard) -> RecordBatch {
        let batch = shard.builder.finish();
        self.metrics.counter("exchange.flush_total").inc();
        self.metrics
            .counter("exchange.coalesced_bytes")
            .add(batch.byte_size() as u64);
        self.account_shrink(shard, batch.byte_size());
        batch
    }

    /// Scatter `batch`'s rows into the destination buffers per `plan`,
    /// returning every `(dst, coalesced_batch)` that must go out now:
    /// pressure-stale buffers first, then destinations whose fill
    /// crossed their current threshold.
    pub fn append(
        &self,
        batch: &RecordBatch,
        plan: &ScatterPlan,
    ) -> Result<Vec<(usize, RecordBatch)>> {
        let mut out = self.take_pressure_flushes();
        for dst in 0..self.shards.len() {
            let rows = plan.rows_for(dst);
            if rows.is_empty() {
                continue;
            }
            let mut shard = self.shards[dst].lock();
            let before = shard.builder.byte_size();
            shard.builder.append_gather(batch, rows)?;
            let delta = shard.builder.byte_size() - before;
            self.account_grow(&mut shard, delta);
            self.adapt(dst, &mut shard);
            if shard.builder.byte_size() >= shard.flush_bytes {
                let flushed = self.flush_shard(&mut shard);
                out.push((dst, flushed));
            }
        }
        Ok(out)
    }

    /// Flush everything buffered when the memory-pressure epoch moved
    /// since the last look (also polled between appends, so buffers
    /// drain under pressure even while the upstream is quiet). The
    /// epoch is claimed with a compare-exchange, so concurrent stream
    /// tasks never double-sweep.
    pub fn take_pressure_flushes(&self) -> Vec<(usize, RecordBatch)> {
        let Some(event) = &self.pressure else {
            return Vec::new();
        };
        let epoch = event.memory_raise_count();
        let seen = self.seen_epoch.load(Ordering::Acquire);
        if epoch == seen
            || self
                .seen_epoch
                .compare_exchange(seen, epoch, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
        {
            return Vec::new();
        }
        let mut out = Vec::new();
        for (dst, slot) in self.shards.iter().enumerate() {
            let mut shard = slot.lock();
            if !shard.builder.is_empty() {
                self.metrics.counter("exchange.pressure_flush_total").inc();
                let flushed = self.flush_shard(&mut shard);
                out.push((dst, flushed));
            }
        }
        out
    }

    /// Final drain: every non-empty destination buffer, regardless of
    /// size (the upstream finished).
    pub fn flush_all(&self) -> Vec<(usize, RecordBatch)> {
        let mut out = Vec::new();
        for (dst, slot) in self.shards.iter().enumerate() {
            let mut shard = slot.lock();
            if !shard.builder.is_empty() {
                let flushed = self.flush_shard(&mut shard);
                out.push((dst, flushed));
            }
        }
        out
    }
}

impl Drop for ShuffleCoalescer {
    fn drop(&mut self) {
        // an aborted query drops buffered rows without flushing: settle
        // the gauge so it keeps meaning "bytes currently buffered" (the
        // reservations release themselves on drop)
        let left: usize = self
            .shards
            .iter()
            .map(|s| s.lock().builder.byte_size())
            .sum();
        self.note_buffered(-(left as i64));
    }
}

pub struct ExchangeOp {
    common: Arc<OpCommon>,
    input: BatchHolder,
    /// Batches staged during estimation (spillable, like any holder).
    pending: BatchHolder,
    /// This exchange's receive side.
    rx: Arc<ChannelRx>,
    /// Wire channel id (shared by the operator pair across workers).
    channel: u32,
    key: Arc<String>,
    role: ExchangeRole,
    /// For `Probe` role: the paired Build exchange's receive side,
    /// whose estimates drive the broadcast/partition decision.
    partner_rx: Option<Arc<ChannelRx>>,
    /// LIP (§5): once the downstream join publishes its build bloom
    /// here, probe batches are pre-filtered *before* crossing the wire.
    lip_filter: Option<crate::exec::operators::join::LipShare>,
    lip_cut_rows: Arc<AtomicU64>,
    state: Mutex<Phase>,
    mode: Mutex<Option<ExchangeMode>>,
    seen_bytes: Arc<AtomicU64>,
    seen_batches: Arc<AtomicU64>,
    sent_batches: Arc<AtomicU64>,
    /// Per-destination coalescing buffers (HashPartition mode only;
    /// built lazily on the first routed batch, shared by stream tasks —
    /// no outer lock: the coalescer's own shards serialize appends).
    coalescer: Arc<OnceLock<ShuffleCoalescer>>,
}

impl ExchangeOp {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        base_priority: i64,
        max_inflight: usize,
        input: BatchHolder,
        pending: BatchHolder,
        rx: Arc<ChannelRx>,
        channel: u32,
        key: String,
        role: ExchangeRole,
        partner_rx: Option<Arc<ChannelRx>>,
        lip_filter: Option<crate::exec::operators::join::LipShare>,
    ) -> ExchangeOp {
        ExchangeOp {
            common: Arc::new(OpCommon::new(id, base_priority, max_inflight)),
            input,
            pending,
            rx,
            channel,
            key: Arc::new(key),
            role,
            partner_rx,
            lip_filter,
            lip_cut_rows: Arc::new(AtomicU64::new(0)),
            state: Mutex::new(Phase::Accumulate),
            mode: Mutex::new(None),
            seen_bytes: Arc::new(AtomicU64::new(0)),
            seen_batches: Arc::new(AtomicU64::new(0)),
            sent_batches: Arc::new(AtomicU64::new(0)),
            coalescer: Arc::new(OnceLock::new()),
        }
    }

    /// The decided mode, once known (bench assertions).
    pub fn mode(&self) -> Option<ExchangeMode> {
        *self.mode.lock().unwrap()
    }

    pub fn sent_batches(&self) -> u64 {
        self.sent_batches.load(Ordering::Relaxed)
    }

    /// Probe rows eliminated before the wire by LIP (§5 metric).
    pub fn lip_cut_rows(&self) -> u64 {
        self.lip_cut_rows.load(Ordering::Relaxed)
    }

    /// Rows currently buffered in the shuffle coalescing builders
    /// (bench/test observability).
    pub fn buffered_shuffle_rows(&self) -> usize {
        self.coalescer.get().map_or(0, |c| c.buffered_rows())
    }

    /// Send one coalesced flush slab-native (heap fallback when the
    /// pool is dry or absent — counted by the pool gauge).
    ///
    /// A flush can overshoot `exchange_flush_bytes` by the *last
    /// appended batch's* per-destination share, which nothing bounds
    /// (an upstream operator may emit one huge batch skewed to one
    /// destination). The config validation's 2× headroom covers the
    /// common overshoot; the hard guarantee that no frame trips the
    /// receiver's `max_frame_bytes` guard is this split.
    fn send_flushed(
        ctx: &WorkerCtx,
        channel: u32,
        dst: usize,
        batch: RecordBatch,
        sent: &AtomicU64,
    ) -> Result<()> {
        let cap = (ctx.config.max_frame_bytes / 2).max(1);
        let chunks = if batch.byte_size() > cap {
            let per = ((batch.rows() * cap) / batch.byte_size()).max(1);
            let chunks = batch.split(per);
            ctx.metrics
                .counter("exchange.oversize_split_total")
                .add((chunks.len() - 1) as u64);
            chunks
        } else {
            vec![batch]
        };
        for b in chunks {
            ctx.outbox
                .send_batch_pooled(dst, channel, &b, ctx.env.pinned.as_ref())?;
            sent.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Package a set of coalescer flushes as one tracked compute task
    /// — shared by the Stream pressure sweep and the final drain.
    /// `poll` runs on the worker's single driver thread, and
    /// `Outbox::push` blocks when the queue is full: sending inline
    /// would park *every* operator on this worker behind a slow peer,
    /// exactly during the pressure episodes the sweep exists for. As a
    /// tracked task the send blocks only one compute thread, and the
    /// held `inflight` keeps the completion branch from racing a
    /// Finish past a still-draining flush.
    fn spawn_drain(&self, flushes: Vec<(usize, RecordBatch)>, tasks: &mut Vec<Task>) {
        if flushes.is_empty() {
            return;
        }
        self.common.issue();
        let payload = Arc::new(Mutex::new(Some(flushes)));
        let channel = self.channel;
        let sent = self.sent_batches.clone();
        let run = self.common.track(move |ctx: &WorkerCtx| {
            if let Some(flushes) = payload.lock().unwrap().take() {
                for (dst, coalesced) in flushes {
                    Self::send_flushed(ctx, channel, dst, coalesced, &sent)?;
                }
            }
            Ok(())
        });
        tasks.push(Task::new(self.common.id, self.common.base_priority, run));
    }

    /// Route one batch according to `mode`.
    fn route(
        ctx: &WorkerCtx,
        mode: ExchangeMode,
        channel: u32,
        key: &str,
        batch: &RecordBatch,
        sent: &AtomicU64,
        coalescer: &OnceLock<ShuffleCoalescer>,
    ) -> Result<()> {
        let workers = ctx.num_workers();
        match mode {
            ExchangeMode::Broadcast => {
                for dst in 0..workers {
                    ctx.outbox.send_batch(dst, channel, batch)?;
                    sent.fetch_add(1, Ordering::Relaxed);
                }
            }
            ExchangeMode::PassThrough => {
                ctx.outbox.send_batch(ctx.worker_id, channel, batch)?;
                sent.fetch_add(1, Ordering::Relaxed);
            }
            ExchangeMode::HashPartition => {
                let keys = kernels::key_column(batch, key)?;
                let parts = ctx
                    .registry
                    .as_ref()
                    .map(|r| r.manifest().num_parts as u32)
                    .unwrap_or(16);
                // single-pass scatter: rows for partition p belong to
                // worker p % workers, laid out per destination
                let plan = kernels::partition_scatter(ctx, keys, parts, workers)?;
                // full feedback policy: thresholds adapt between the
                // configured floor/ceiling from this worker's outbox
                // signals, and builder bytes reserve from the governor
                let co = coalescer.get_or_init(|| {
                    ShuffleCoalescer::with_policy(
                        workers,
                        ctx.config.exchange_flush_bytes,
                        ctx.config.exchange_flush_floor_bytes,
                        ctx.config.exchange_flush_ceiling_bytes,
                        ctx.env.arena.pressure_event(),
                        Some(ctx.outbox.clone()),
                        Some(ctx.governor.clone()),
                        ctx.metrics.clone(),
                    )
                });
                let flushes = co.append(batch, &plan)?;
                // send outside the shard locks: outbox backpressure must
                // pace this task without also parking its siblings
                for (dst, coalesced) in flushes {
                    Self::send_flushed(ctx, channel, dst, coalesced, sent)?;
                }
            }
        }
        Ok(())
    }
}

impl Operator for ExchangeOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "exchange"
    }

    fn poll(&self, ctx: &WorkerCtx) -> Result<Vec<Task>> {
        let phase = *self.state.lock().unwrap();
        let mut tasks = Vec::new();
        match phase {
            Phase::Accumulate => {
                // stage arrivals; count bytes
                let mut budget = self.input.len().min(
                    self.common
                        .max_inflight
                        .saturating_sub(self.common.inflight()),
                );
                while budget > 0 {
                    budget -= 1;
                    self.common.issue();
                    let input = self.input.clone();
                    let pending = self.pending.clone();
                    let seen_bytes = self.seen_bytes.clone();
                    let seen_batches = self.seen_batches.clone();
                    let run = self.common.track(move |_ctx: &WorkerCtx| {
                        if let Some(enc) = input.pop_encoded()? {
                            seen_bytes.fetch_add(enc.len() as u64, Ordering::Relaxed);
                            seen_batches.fetch_add(1, Ordering::Relaxed);
                            // slab-backed bytes move holder-to-holder
                            // without a copy
                            pending.push_host_bytes(enc)?;
                        }
                        Ok(())
                    });
                    tasks.push(
                        Task::new(self.common.id, self.common.base_priority, run)
                            .with_input(self.input.clone()),
                    );
                }
                // transition?
                let enough = self.seen_batches.load(Ordering::Relaxed)
                    >= ctx.config.exchange_estimate_batches as u64;
                if (enough || self.input.is_exhausted()) && self.common.inflight() == 0 {
                    let seen = self.seen_bytes.load(Ordering::Relaxed);
                    let estimate = if self.input.is_exhausted() {
                        seen
                    } else {
                        (seen as f64 * EST_GROWTH) as u64
                    };
                    for dst in 0..ctx.num_workers() {
                        ctx.outbox.send_estimate(dst, self.channel, estimate)?;
                    }
                    *self.state.lock().unwrap() = Phase::WaitEstimates;
                }
            }
            Phase::WaitEstimates => {
                // Which channel's estimates decide? Build/Shuffle: our
                // own; Probe: the paired build exchange's (all workers
                // see identical estimate sets, so every worker reaches
                // the same decision independently).
                let decider = self.partner_rx.as_ref().unwrap_or(&self.rx);
                let (count, total) = decider.estimates();
                if count >= ctx.num_workers() {
                    let small = total as usize <= ctx.config.broadcast_threshold;
                    let mode = match self.role {
                        ExchangeRole::Shuffle => ExchangeMode::HashPartition,
                        ExchangeRole::Build if small => ExchangeMode::Broadcast,
                        ExchangeRole::Build => ExchangeMode::HashPartition,
                        ExchangeRole::Probe { .. } if small => ExchangeMode::PassThrough,
                        ExchangeRole::Probe { .. } => ExchangeMode::HashPartition,
                    };
                    *self.mode.lock().unwrap() = Some(mode);
                    ctx.metrics
                        .counter(match mode {
                            ExchangeMode::Broadcast => "exchange.broadcast",
                            ExchangeMode::HashPartition => "exchange.partition",
                            ExchangeMode::PassThrough => "exchange.passthrough",
                        })
                        .inc();
                    *self.state.lock().unwrap() = Phase::Stream;
                }
            }
            Phase::Stream => {
                let mode = self.mode.lock().unwrap().expect("mode decided");
                // LIP hold-off (§5): in PassThrough mode the rows stay
                // local and the build side (broadcast, small) completes
                // quickly — waiting for its bloom costs little and lets
                // every probe row be pre-filtered. The join always
                // publishes once its build input is exhausted, so this
                // cannot stall indefinitely.
                if mode == ExchangeMode::PassThrough {
                    if let Some(share) = &self.lip_filter {
                        if share.read().unwrap().is_none() {
                            return Ok(tasks);
                        }
                    }
                }
                // Pressure sweep (driver frequency): when the worker's
                // memory-pressure epoch advanced, drain the coalescing
                // buffers even if no new input arrives — buffered
                // shuffle rows must never sit on a worker that is busy
                // spilling.
                if mode == ExchangeMode::HashPartition {
                    let flushes = self
                        .coalescer
                        .get()
                        .map_or_else(Vec::new, |co| co.take_pressure_flushes());
                    self.spawn_drain(flushes, &mut tasks);
                }
                let avail = self.pending.len() + self.input.len();
                let mut budget = avail.min(
                    self.common
                        .max_inflight
                        .saturating_sub(self.common.inflight()),
                );
                while budget > 0 {
                    budget -= 1;
                    self.common.issue();
                    let pending = self.pending.clone();
                    let input = self.input.clone();
                    let channel = self.channel;
                    let key = self.key.clone();
                    let sent = self.sent_batches.clone();
                    let lip = self.lip_filter.clone();
                    let lip_cut = self.lip_cut_rows.clone();
                    let coalescer = self.coalescer.clone();
                    let run = self.common.track(move |ctx: &WorkerCtx| {
                        // Bytes-level fast path: Broadcast and
                        // un-filtered PassThrough never look at rows, so
                        // the encoded batch — often a pinned slab —
                        // moves holder → outbox → wire with no device
                        // promotion, no decode, no re-encode. Slab
                        // clones are Arc-shared views, so a broadcast
                        // stages one payload, not one per peer.
                        let needs_rows = mode == ExchangeMode::HashPartition
                            || (mode == ExchangeMode::PassThrough && lip.is_some());
                        if !needs_rows {
                            let enc = match pending.pop_encoded()? {
                                Some(e) => Some(e),
                                None => input.pop_encoded()?,
                            };
                            if let Some(enc) = enc {
                                if mode == ExchangeMode::Broadcast {
                                    // clone for all peers but the last
                                    // (slab clones are Arc-shared)
                                    let n = ctx.num_workers();
                                    for dst in 0..n - 1 {
                                        ctx.outbox.send_encoded(dst, channel, enc.clone())?;
                                        sent.fetch_add(1, Ordering::Relaxed);
                                    }
                                    ctx.outbox.send_encoded(n - 1, channel, enc)?;
                                } else {
                                    ctx.outbox.send_encoded(ctx.worker_id, channel, enc)?;
                                }
                                sent.fetch_add(1, Ordering::Relaxed);
                            }
                            return Ok(());
                        }
                        // row-level path: partitioning and LIP need
                        // decoded rows on device
                        let db = match pending.pop_device()? {
                            Some(db) => Some(db),
                            None => input.pop_device()?,
                        };
                        if let Some(db) = db {
                            // LIP pre-filter: drop rows that cannot join
                            // before they cost wire bytes (§5). Only
                            // sound in PassThrough mode: the build side
                            // was broadcast, so the local join's bloom
                            // covers the *entire* build relation. In
                            // HashPartition mode each worker's bloom
                            // covers only its partition and would drop
                            // joinable rows.
                            let mut batch = db.batch.clone();
                            drop(db);
                            if let (Some(share), ExchangeMode::PassThrough) = (&lip, mode) {
                                let cells = share.read().unwrap().clone();
                                if let Some(cells) = cells {
                                    let keys = kernels::key_column(&batch, &key)?;
                                    let mask = kernels::bloom_probe(ctx, keys, &cells)?;
                                    let before = batch.rows();
                                    batch = batch.compact(&mask)?;
                                    lip_cut.fetch_add(
                                        (before - batch.rows()) as u64,
                                        Ordering::Relaxed,
                                    );
                                }
                            }
                            if !batch.is_empty() {
                                Self::route(
                                    ctx, mode, channel, &key, &batch, &sent, &coalescer,
                                )?;
                            }
                        }
                        Ok(())
                    });
                    tasks.push(
                        Task::new(self.common.id, self.common.base_priority, run)
                            // stream tasks pop from both holders
                            .with_input(self.pending.clone())
                            .with_input(self.input.clone())
                            .with_prefetch(Prefetch::Promote {
                                holder: self.pending.clone(),
                            }),
                    );
                }
                if self.input.is_exhausted()
                    && self.pending.is_empty()
                    && self.common.inflight() == 0
                {
                    // final drain: every buffered destination goes out
                    // before any peer sees our Finish. Non-empty
                    // buffers become one more tracked task (its held
                    // inflight defers this branch); Finish goes out
                    // only once the coalescer has fully drained.
                    let flushes =
                        self.coalescer.get().map_or_else(Vec::new, |co| co.flush_all());
                    if !flushes.is_empty() {
                        self.spawn_drain(flushes, &mut tasks);
                    } else {
                        for dst in 0..ctx.num_workers() {
                            ctx.outbox.send_finish(dst, self.channel)?;
                        }
                        *self.state.lock().unwrap() = Phase::Done;
                        self.common.mark_done();
                    }
                }
            }
            Phase::Done => {}
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::config::{TransportKind, WorkerConfig};
    use crate::executors::network::{NetworkExecutor, Outbox, Router};
    use crate::memory::batch_holder::MemEnv;
    use crate::network::InprocHub;
    use crate::sim::SimContext;
    use crate::types::Column;
    use crate::util::hash;

    #[test]
    fn mode_constants() {
        assert_ne!(ExchangeMode::Broadcast, ExchangeMode::HashPartition);
    }

    fn keyed_batch(rows: usize, salt: i64) -> RecordBatch {
        RecordBatch::new(vec![
            Column::i64("k", (0..rows as i64).map(|i| i * 31 + salt).collect()),
            Column::i64("w", (0..rows as i64).map(|i| i + salt * 1000).collect()),
        ])
        .unwrap()
    }

    /// Reference routing: the seed's per-batch per-destination take
    /// lists, as a sorted row multiset per destination.
    fn reference_rows(batches: &[RecordBatch], workers: usize) -> Vec<Vec<(i64, i64)>> {
        let mut by_dst = vec![Vec::new(); workers];
        for b in batches {
            let k = b.column("k").unwrap().data.as_i64().unwrap();
            let w = b.column("w").unwrap().data.as_i64().unwrap();
            for i in 0..b.rows() {
                let dst = hash::partition_id(k[i], 16) as usize % workers;
                by_dst[dst].push((k[i], w[i]));
            }
        }
        for d in &mut by_dst {
            d.sort_unstable();
        }
        by_dst
    }

    fn collected_rows(batches: &[RecordBatch]) -> Vec<(i64, i64)> {
        let mut rows = Vec::new();
        for b in batches {
            let k = b.column("k").unwrap().data.as_i64().unwrap();
            let w = b.column("w").unwrap().data.as_i64().unwrap();
            rows.extend(k.iter().copied().zip(w.iter().copied()));
        }
        rows.sort_unstable();
        rows
    }

    #[test]
    fn coalescer_flushes_on_threshold_and_preserves_routing() {
        let ctx = crate::exec::WorkerCtx::test();
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let workers = 3;
        // 2 i64 columns -> 16 bytes/row; flush after ~32 rows/dst
        let co = ShuffleCoalescer::new(workers, 512, None, metrics.clone());
        let batches: Vec<RecordBatch> = (0..5).map(|s| keyed_batch(100, s)).collect();
        let mut got: Vec<Vec<RecordBatch>> = vec![Vec::new(); workers];
        for b in &batches {
            let keys = b.column("k").unwrap().data.as_i64().unwrap();
            let plan = kernels::partition_scatter(&ctx, keys, 16, workers).unwrap();
            for (dst, flushed) in co.append(b, &plan).unwrap() {
                assert!(flushed.byte_size() >= 512, "flush crossed the threshold");
                got[dst].push(flushed);
            }
        }
        for (dst, flushed) in co.flush_all() {
            got[dst].push(flushed);
        }
        assert_eq!(co.buffered_rows(), 0, "flush_all drains everything");
        let reference = reference_rows(&batches, workers);
        let mut total_flushes = 0;
        for dst in 0..workers {
            assert_eq!(collected_rows(&got[dst]), reference[dst], "dst {dst}");
            total_flushes += got[dst].len();
        }
        assert_eq!(metrics.counter_value("exchange.flush_total"), total_flushes as u64);
        assert_eq!(
            metrics.counter_value("exchange.coalesced_bytes"),
            batches.iter().map(|b| b.byte_size() as u64).sum::<u64>()
        );
        assert_eq!(metrics.counter_value("exchange.pressure_flush_total"), 0);
    }

    #[test]
    fn pressure_epoch_advance_flushes_buffers_early() {
        let ctx = crate::exec::WorkerCtx::test();
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let event = PressureEvent::new();
        // threshold far above anything appended here
        let co = ShuffleCoalescer::new(2, 1 << 30, Some(event.clone()), metrics.clone());
        let b = keyed_batch(64, 7);
        let keys = b.column("k").unwrap().data.as_i64().unwrap();
        let plan = kernels::partition_scatter(&ctx, keys, 16, 2).unwrap();
        assert!(co.append(&b, &plan).unwrap().is_empty(), "below threshold");
        assert_eq!(co.buffered_rows(), 64);
        assert_eq!(
            metrics.gauge_value("exchange.buffered_bytes"),
            b.byte_size() as i64,
            "buffered heap must be visible on the gauge"
        );
        assert!(co.take_pressure_flushes().is_empty(), "no pressure yet");

        event.raise_host(1);
        let flushed = co.take_pressure_flushes();
        assert!(!flushed.is_empty(), "epoch advance must flush");
        assert_eq!(flushed.iter().map(|(_, b)| b.rows()).sum::<usize>(), 64);
        assert_eq!(co.buffered_rows(), 0);
        assert_eq!(
            metrics.counter_value("exchange.pressure_flush_total"),
            flushed.len() as u64
        );
        assert_eq!(metrics.gauge_value("exchange.buffered_bytes"), 0);
        // the epoch was consumed: quiet again until the next raise
        assert!(co.take_pressure_flushes().is_empty());
        event.raise_device(1);
        assert!(co.take_pressure_flushes().is_empty(), "nothing buffered");

        // dropping a part-filled coalescer settles the gauge
        let plan = kernels::partition_scatter(&ctx, keys, 16, 2).unwrap();
        assert!(co.append(&b, &plan).unwrap().is_empty());
        assert!(metrics.gauge_value("exchange.buffered_bytes") > 0);
        drop(co);
        assert_eq!(metrics.gauge_value("exchange.buffered_bytes"), 0);
    }

    #[test]
    fn adaptive_threshold_tracks_outbox_depth() {
        let ctx = crate::exec::WorkerCtx::test();
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let outbox = Arc::new(Outbox::new(64));
        let co = ShuffleCoalescer::with_policy(
            2,
            1024,
            256,
            4096,
            None,
            Some(outbox.clone()),
            None,
            metrics.clone(),
        );
        assert_eq!(co.flush_threshold(0), 1024);
        assert_eq!(co.flush_threshold(1), 1024);

        let b = keyed_batch(64, 1);
        let keys = b.column("k").unwrap().data.as_i64().unwrap();
        let plan = kernels::partition_scatter(&ctx, keys, 16, 2).unwrap();

        // congest dst 0 only: two undrained frames ≥ CONGESTED_DEPTH
        outbox.send_finish(0, 0).unwrap();
        outbox.send_finish(0, 0).unwrap();
        for _ in 0..40 {
            co.append(&b, &plan).unwrap();
        }
        assert_eq!(
            co.flush_threshold(0),
            256,
            "congested path must halve down to the floor and stop there"
        );
        assert_eq!(
            metrics.gauge_value("exchange.flush_bytes_current{dst=0}"),
            256,
            "every threshold move is published"
        );
        assert_eq!(
            co.flush_threshold(1),
            4096,
            "idle path must grow up to the ceiling and stop there"
        );
        assert_eq!(metrics.gauge_value("exchange.flush_bytes_current{dst=1}"), 4096);
    }

    #[test]
    fn governor_accounts_builder_bytes_and_squeeze_self_flushes() {
        let ctx = crate::exec::WorkerCtx::test();
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let b = keyed_batch(64, 3);
        let keys = b.column("k").unwrap().data.as_i64().unwrap();
        let plan = kernels::partition_scatter(&ctx, keys, 16, 2).unwrap();

        // roomy governor: the reservation tracks builder bytes exactly
        let gov =
            MemoryGovernor::new(crate::memory::DeviceArena::new(1 << 20));
        let co = ShuffleCoalescer::with_policy(
            2,
            1 << 30,
            1 << 30,
            1 << 30,
            None,
            None,
            Some(gov.clone()),
            metrics.clone(),
        );
        assert!(co.append(&b, &plan).unwrap().is_empty());
        assert_eq!(gov.reserved(), b.byte_size(), "builder bytes must be reserved");
        let flushed = co.flush_all();
        assert_eq!(
            flushed.iter().map(|(_, f)| f.byte_size()).sum::<usize>(),
            b.byte_size()
        );
        assert_eq!(gov.reserved(), 0, "flush must hand the reservation back");
        // dropping a part-filled coalescer releases via RAII
        assert!(co.append(&b, &plan).unwrap().is_empty());
        assert_eq!(gov.reserved(), b.byte_size());
        drop(co);
        assert_eq!(gov.reserved(), 0);

        // squeezed governor: a refused grow raises the pressure epoch,
        // and the coalescer's own early-flush trigger fires from it —
        // the self-induced-squeeze loop the tentpole closes
        let tiny = MemoryGovernor::new(crate::memory::DeviceArena::new(64));
        let event = PressureEvent::new();
        tiny.install_pressure(event.clone());
        let co = ShuffleCoalescer::with_policy(
            2,
            1 << 30,
            1 << 30,
            1 << 30,
            Some(event.clone()),
            None,
            Some(tiny.clone()),
            metrics.clone(),
        );
        let epoch0 = event.memory_raise_count();
        assert!(co.append(&b, &plan).unwrap().is_empty(), "append still buffers");
        assert!(
            event.memory_raise_count() > epoch0,
            "a refused grow must raise pressure"
        );
        let flushed = co.take_pressure_flushes();
        assert_eq!(
            flushed.iter().map(|(_, f)| f.rows()).sum::<usize>(),
            64,
            "the squeeze the coalescer caused must drain the coalescer"
        );
        assert_eq!(co.buffered_rows(), 0);
    }

    /// Acceptance: a multi-batch hash-partition shuffle emits at most
    /// ⌈total_bytes / exchange_flush_bytes⌉ + workers frames (the seed
    /// emitted batches × workers), every payload slab-backed, and the
    /// per-destination row multiset identical to the seed routing.
    #[test]
    fn coalesced_shuffle_bounds_frames_and_stays_pinned() {
        const WORKERS: usize = 2;
        const BATCHES: usize = 8;
        const ROWS: usize = 512;
        const FLUSH: usize = 16 << 10;

        let cfg = WorkerConfig {
            num_workers: WORKERS,
            exchange_estimate_batches: 1,
            exchange_flush_bytes: FLUSH,
            ..WorkerConfig::test()
        };
        let mut ctx = crate::exec::WorkerCtx::test_with(Arc::new(cfg));
        let pool = ctx.env.pinned.clone().unwrap();

        let hub = InprocHub::new(WORKERS, &SimContext::test(), TransportKind::Tcp);
        let mut exes = Vec::new();
        let mut routers = Vec::new();
        for ep in hub.endpoints() {
            let router = Arc::new(Router::new());
            let outbox = Arc::new(Outbox::new(64));
            routers.push(router.clone());
            exes.push(NetworkExecutor::start(
                Arc::new(ep),
                outbox,
                router,
                None,
                Some(pool.clone()),
                1,
            ));
        }
        ctx.outbox = exes[0].outbox().clone();

        let rx_env = MemEnv { pinned: Some(pool.clone()), ..ctx.env.clone() };
        let rx_holders: Vec<BatchHolder> = (0..WORKERS)
            .map(|w| BatchHolder::new(format!("rx{w}"), rx_env.clone()))
            .collect();
        let rx0 = Arc::new(ChannelRx::new(rx_holders[0].clone(), 1));
        routers[0].register(7, rx0.clone());
        routers[1].register(7, Arc::new(ChannelRx::new(rx_holders[1].clone(), 1)));

        let input = BatchHolder::new("in", ctx.env.clone());
        let pending = BatchHolder::new("pending", ctx.env.clone());
        let batches: Vec<RecordBatch> =
            (0..BATCHES as i64).map(|s| keyed_batch(ROWS, s)).collect();
        for b in &batches {
            input.push_batch_host(b.clone()).unwrap();
        }
        input.finish();

        let op = ExchangeOp::new(
            0,
            1000,
            2,
            input,
            pending,
            rx0,
            7,
            "k".into(),
            ExchangeRole::Shuffle,
            None,
            None,
        );
        // the missing peer's estimate (worker 1 runs no exchange here)
        exes[1].outbox().send_estimate(0, 7, 0).unwrap();

        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !op.is_done() {
            assert!(std::time::Instant::now() < deadline, "exchange stalled");
            for t in op.poll(&ctx).unwrap() {
                (t.run)(&ctx).unwrap();
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(exes[0].flush(Duration::from_secs(2)));
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !rx_holders.iter().all(|h| h.is_finished()) {
            assert!(std::time::Instant::now() < deadline, "finish lost");
            std::thread::sleep(Duration::from_millis(2));
        }

        // frame bound: ⌈total/flush⌉ + workers, far below batches×workers
        let total_bytes: usize = batches.iter().map(|b| b.byte_size()).sum();
        let bound = total_bytes.div_ceil(FLUSH) + WORKERS;
        let frames = op.sent_batches();
        assert!(
            frames as usize <= bound,
            "{frames} frames > bound {bound} (seed: {})",
            BATCHES * WORKERS
        );
        assert!(frames >= 1);
        assert_eq!(
            ctx.metrics.counter_value("exchange.flush_total"),
            frames,
            "every sent frame is one coalesced flush"
        );
        assert_eq!(
            ctx.metrics.counter_value("exchange.coalesced_bytes"),
            total_bytes as u64
        );
        assert_eq!(ctx.metrics.counter_value("exchange.pressure_flush_total"), 0);
        assert_eq!(op.buffered_shuffle_rows(), 0, "final drain left nothing behind");
        // zero heap on the shuffle path: no pooled-send fallback fired
        assert_eq!(pool.codec_heap_fallback_bytes(), 0);

        // routing identity vs the seed per-batch take path
        let reference = reference_rows(&batches, WORKERS);
        for (dst, holder) in rx_holders.iter().enumerate() {
            assert!(
                holder.residency().host_pinned_bytes > 0,
                "dst {dst}: payloads must arrive slab-backed"
            );
            let mut got = Vec::new();
            while let Some(db) = holder.pop_device().unwrap() {
                got.push(db.batch.clone());
            }
            assert_eq!(collected_rows(&got), reference[dst], "dst {dst}");
        }
        for e in &exes {
            e.stop();
        }
    }
}
