//! Adaptive Exchange (§3.2).
//!
//! "An Adaptive Exchange operator exists as a pair, one for each side
//! of a join. ... First, it waits to accumulate enough input batches to
//! estimate the total bytes it will receive, and broadcasts that
//! information to paired Adaptive Exchange operators in all workers.
//! These operators are adaptive because based on the estimates, they
//! decide whether to hash partition or broadcast the data in the second
//! phase. ... The algorithm using an estimate of the data sizes to
//! arrive instead of waiting for all the data to arrive minimizes
//! interruption of data flow through the DAG by allowing phase two
//! tasks to be scheduled sooner."
//!
//! Phases: `Accumulate` (stage the first K batches in a spillable
//! holder and count bytes) → `WaitEstimates` (estimate broadcast to all
//! peers, wait for theirs) → `Stream` (hash-partition or broadcast each
//! batch through the Network Executor) → `Done` (Finish sent to all
//! peers). The receiving side is the [`ChannelRx`] holder the worker
//! registered for this operator's channel; it finishes when every
//! peer's Finish arrives.
//!
//! ## Destination-coalesced shuffle (§3.4, §4.1)
//!
//! Hash-partitioning used to fragment every input batch into
//! per-destination slivers of a few hundred rows, each encoded, framed,
//! compressed, and sent as its own message — `batches × workers` tiny
//! frames, each paying header + codec + syscall overhead. The `Stream`
//! phase now scatters rows in a single pass
//! ([`kernels::partition_scatter`]: histogram → prefix sum → placement,
//! reusing the device `hash_partition` stage's histogram when
//! available) into per-destination [`ShuffleCoalescer`] buffers
//! (append-only [`crate::types::BatchBuilder`] column accumulators). A
//! destination flushes only when
//!
//! * its buffer crosses `exchange_flush_bytes` (default ~4 MiB —
//!   slab-friendly target frames),
//! * the upstream finishes (final drain before Finish), or
//! * the worker's memory-pressure epoch advances
//!   ([`crate::memory::PressureEvent::memory_raise_count`], installed
//!   by the Data-Movement executor) — buffered shuffle state drains
//!   *early* under pressure instead of deepening a spill cycle.
//!
//! Flushes are slab-native:
//! [`send_batch_pooled`](crate::executors::network::Outbox::send_batch_pooled)
//! encodes the coalesced batch straight into a
//! `SlabWriter` from the worker's bounce pool (heap fallback when dry,
//! counted), so the old `StagedBytes::Heap(batch.encode())` bounce is
//! gone from the shuffle path. Metrics: `exchange.flush_total`,
//! `exchange.coalesced_bytes`, `exchange.pressure_flush_total`, plus
//! the live `exchange.buffered_bytes` gauge (coalescer memory is plain
//! heap outside the governor's accounting; the gauge keeps it visible,
//! and the flush threshold bounds it at `flush_bytes × destinations`
//! per exchange).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::exec::operators::kernels::ScatterPlan;
use crate::exec::operators::{kernels, OpCommon, Operator};
use crate::exec::plan::ExchangeRole;
use crate::exec::task::{Prefetch, Task};
use crate::exec::WorkerCtx;
use crate::executors::network::ChannelRx;
use crate::memory::{BatchHolder, PressureEvent};
use crate::metrics::Metrics;
use crate::types::{BatchBuilder, RecordBatch};
use crate::Result;

/// Phase-two routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Rows routed to `hash(key) % workers`.
    HashPartition,
    /// Every batch goes to every worker (small join build side).
    Broadcast,
    /// Rows stay on this worker (probe side of a broadcast join).
    PassThrough,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Accumulate,
    WaitEstimates,
    Stream,
    Done,
}

/// Growth factor applied to early-seen bytes when the input hasn't
/// finished (the paper estimates from a prefix; upstream totals are
/// unknown at this point in the DAG).
const EST_GROWTH: f64 = 4.0;

/// Per-destination shuffle coalescing buffers (see the module doc).
///
/// One instance per hash-partitioning exchange, shared by its stream
/// tasks under a mutex: appends are scatter placements into
/// [`BatchBuilder`]s, and the three flush triggers (size threshold,
/// final drain, memory-pressure epoch advance) hand back whole
/// coalesced `RecordBatch`es for the caller to send. The pressure check
/// is a single atomic read against the epoch observed last time — no
/// subscription, no callback plumbing.
///
/// The gather-append runs under one mutex for the whole exchange, so
/// concurrent stream tasks serialize on the append memcpy (they still
/// hash, decode, encode, and compress in parallel — the lock covers
/// only the builder fill). Sharding to per-destination locks is a
/// known follow-up if profiles show contention here (ROADMAP).
pub struct ShuffleCoalescer {
    builders: Vec<BatchBuilder>,
    flush_bytes: usize,
    pressure: Option<Arc<PressureEvent>>,
    /// Memory-pressure epoch at the last check; an advance flushes.
    seen_epoch: u64,
    metrics: Arc<Metrics>,
}

impl ShuffleCoalescer {
    pub fn new(
        dests: usize,
        flush_bytes: usize,
        pressure: Option<Arc<PressureEvent>>,
        metrics: Arc<Metrics>,
    ) -> ShuffleCoalescer {
        let seen_epoch = pressure.as_ref().map_or(0, |e| e.memory_raise_count());
        ShuffleCoalescer {
            builders: (0..dests.max(1)).map(|_| BatchBuilder::new()).collect(),
            flush_bytes: flush_bytes.max(1),
            pressure,
            seen_epoch,
            metrics,
        }
    }

    pub fn buffered_rows(&self) -> usize {
        self.builders.iter().map(|b| b.rows()).sum()
    }

    /// Keep the worker-level `exchange.buffered_bytes` gauge in step
    /// with the builders. Coalescer memory is plain heap the governor
    /// does not account, so the gauge is how an operator sees shuffle
    /// buffering from the outside (the flush threshold bounds it at
    /// `flush_bytes × destinations` per exchange).
    fn note_buffered(&self, delta: i64) {
        if delta != 0 {
            self.metrics.gauge("exchange.buffered_bytes").add(delta);
        }
    }

    fn flush(&mut self, dst: usize) -> RecordBatch {
        let batch = self.builders[dst].finish();
        self.metrics.counter("exchange.flush_total").inc();
        self.metrics
            .counter("exchange.coalesced_bytes")
            .add(batch.byte_size() as u64);
        self.note_buffered(-(batch.byte_size() as i64));
        batch
    }

    /// Scatter `batch`'s rows into the destination buffers per `plan`,
    /// returning every `(dst, coalesced_batch)` that must go out now:
    /// pressure-stale buffers first, then destinations whose fill
    /// crossed `flush_bytes`.
    pub fn append(
        &mut self,
        batch: &RecordBatch,
        plan: &ScatterPlan,
    ) -> Result<Vec<(usize, RecordBatch)>> {
        let mut out = self.take_pressure_flushes();
        for dst in 0..self.builders.len() {
            let rows = plan.rows_for(dst);
            if rows.is_empty() {
                continue;
            }
            let before = self.builders[dst].byte_size();
            self.builders[dst].append_gather(batch, rows)?;
            self.note_buffered((self.builders[dst].byte_size() - before) as i64);
            if self.builders[dst].byte_size() >= self.flush_bytes {
                let flushed = self.flush(dst);
                out.push((dst, flushed));
            }
        }
        Ok(out)
    }

    /// Flush everything buffered when the memory-pressure epoch moved
    /// since the last look (also polled between appends, so buffers
    /// drain under pressure even while the upstream is quiet).
    pub fn take_pressure_flushes(&mut self) -> Vec<(usize, RecordBatch)> {
        let Some(event) = &self.pressure else {
            return Vec::new();
        };
        let epoch = event.memory_raise_count();
        if epoch == self.seen_epoch {
            return Vec::new();
        }
        self.seen_epoch = epoch;
        let mut out = Vec::new();
        for dst in 0..self.builders.len() {
            if !self.builders[dst].is_empty() {
                self.metrics.counter("exchange.pressure_flush_total").inc();
                let flushed = self.flush(dst);
                out.push((dst, flushed));
            }
        }
        out
    }

    /// Final drain: every non-empty destination buffer, regardless of
    /// size (the upstream finished).
    pub fn flush_all(&mut self) -> Vec<(usize, RecordBatch)> {
        let mut out = Vec::new();
        for dst in 0..self.builders.len() {
            if !self.builders[dst].is_empty() {
                let flushed = self.flush(dst);
                out.push((dst, flushed));
            }
        }
        out
    }
}

impl Drop for ShuffleCoalescer {
    fn drop(&mut self) {
        // an aborted query drops buffered rows without flushing: settle
        // the gauge so it keeps meaning "bytes currently buffered"
        let left: usize = self.builders.iter().map(|b| b.byte_size()).sum();
        self.note_buffered(-(left as i64));
    }
}

pub struct ExchangeOp {
    common: Arc<OpCommon>,
    input: BatchHolder,
    /// Batches staged during estimation (spillable, like any holder).
    pending: BatchHolder,
    /// This exchange's receive side.
    rx: Arc<ChannelRx>,
    /// Wire channel id (shared by the operator pair across workers).
    channel: u32,
    key: Arc<String>,
    role: ExchangeRole,
    /// For `Probe` role: the paired Build exchange's receive side,
    /// whose estimates drive the broadcast/partition decision.
    partner_rx: Option<Arc<ChannelRx>>,
    /// LIP (§5): once the downstream join publishes its build bloom
    /// here, probe batches are pre-filtered *before* crossing the wire.
    lip_filter: Option<crate::exec::operators::join::LipShare>,
    lip_cut_rows: Arc<AtomicU64>,
    state: Mutex<Phase>,
    mode: Mutex<Option<ExchangeMode>>,
    seen_bytes: Arc<AtomicU64>,
    seen_batches: Arc<AtomicU64>,
    sent_batches: Arc<AtomicU64>,
    /// Per-destination coalescing buffers (HashPartition mode only;
    /// built lazily on the first routed batch, shared by stream tasks).
    coalescer: Arc<Mutex<Option<ShuffleCoalescer>>>,
}

impl ExchangeOp {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        base_priority: i64,
        max_inflight: usize,
        input: BatchHolder,
        pending: BatchHolder,
        rx: Arc<ChannelRx>,
        channel: u32,
        key: String,
        role: ExchangeRole,
        partner_rx: Option<Arc<ChannelRx>>,
        lip_filter: Option<crate::exec::operators::join::LipShare>,
    ) -> ExchangeOp {
        ExchangeOp {
            common: Arc::new(OpCommon::new(id, base_priority, max_inflight)),
            input,
            pending,
            rx,
            channel,
            key: Arc::new(key),
            role,
            partner_rx,
            lip_filter,
            lip_cut_rows: Arc::new(AtomicU64::new(0)),
            state: Mutex::new(Phase::Accumulate),
            mode: Mutex::new(None),
            seen_bytes: Arc::new(AtomicU64::new(0)),
            seen_batches: Arc::new(AtomicU64::new(0)),
            sent_batches: Arc::new(AtomicU64::new(0)),
            coalescer: Arc::new(Mutex::new(None)),
        }
    }

    /// The decided mode, once known (bench assertions).
    pub fn mode(&self) -> Option<ExchangeMode> {
        *self.mode.lock().unwrap()
    }

    pub fn sent_batches(&self) -> u64 {
        self.sent_batches.load(Ordering::Relaxed)
    }

    /// Probe rows eliminated before the wire by LIP (§5 metric).
    pub fn lip_cut_rows(&self) -> u64 {
        self.lip_cut_rows.load(Ordering::Relaxed)
    }

    /// Rows currently buffered in the shuffle coalescing builders
    /// (bench/test observability).
    pub fn buffered_shuffle_rows(&self) -> usize {
        self.coalescer
            .lock()
            .unwrap()
            .as_ref()
            .map_or(0, |c| c.buffered_rows())
    }

    /// Send one coalesced flush slab-native (heap fallback when the
    /// pool is dry or absent — counted by the pool gauge).
    ///
    /// A flush can overshoot `exchange_flush_bytes` by the *last
    /// appended batch's* per-destination share, which nothing bounds
    /// (an upstream operator may emit one huge batch skewed to one
    /// destination). The config validation's 2× headroom covers the
    /// common overshoot; the hard guarantee that no frame trips the
    /// receiver's `max_frame_bytes` guard is this split.
    fn send_flushed(
        ctx: &WorkerCtx,
        channel: u32,
        dst: usize,
        batch: RecordBatch,
        sent: &AtomicU64,
    ) -> Result<()> {
        let cap = (ctx.config.max_frame_bytes / 2).max(1);
        let chunks = if batch.byte_size() > cap {
            let per = ((batch.rows() * cap) / batch.byte_size()).max(1);
            let chunks = batch.split(per);
            ctx.metrics
                .counter("exchange.oversize_split_total")
                .add((chunks.len() - 1) as u64);
            chunks
        } else {
            vec![batch]
        };
        for b in chunks {
            ctx.outbox
                .send_batch_pooled(dst, channel, &b, ctx.env.pinned.as_ref())?;
            sent.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Package a set of coalescer flushes as one tracked compute task
    /// — shared by the Stream pressure sweep and the final drain.
    /// `poll` runs on the worker's single driver thread, and
    /// `Outbox::push` blocks when the queue is full: sending inline
    /// would park *every* operator on this worker behind a slow peer,
    /// exactly during the pressure episodes the sweep exists for. As a
    /// tracked task the send blocks only one compute thread, and the
    /// held `inflight` keeps the completion branch from racing a
    /// Finish past a still-draining flush.
    fn spawn_drain(&self, flushes: Vec<(usize, RecordBatch)>, tasks: &mut Vec<Task>) {
        if flushes.is_empty() {
            return;
        }
        self.common.issue();
        let payload = Arc::new(Mutex::new(Some(flushes)));
        let channel = self.channel;
        let sent = self.sent_batches.clone();
        let run = self.common.track(move |ctx: &WorkerCtx| {
            if let Some(flushes) = payload.lock().unwrap().take() {
                for (dst, coalesced) in flushes {
                    Self::send_flushed(ctx, channel, dst, coalesced, &sent)?;
                }
            }
            Ok(())
        });
        tasks.push(Task::new(self.common.id, self.common.base_priority, run));
    }

    /// Route one batch according to `mode`.
    fn route(
        ctx: &WorkerCtx,
        mode: ExchangeMode,
        channel: u32,
        key: &str,
        batch: &RecordBatch,
        sent: &AtomicU64,
        coalescer: &Mutex<Option<ShuffleCoalescer>>,
    ) -> Result<()> {
        let workers = ctx.num_workers();
        match mode {
            ExchangeMode::Broadcast => {
                for dst in 0..workers {
                    ctx.outbox.send_batch(dst, channel, batch)?;
                    sent.fetch_add(1, Ordering::Relaxed);
                }
            }
            ExchangeMode::PassThrough => {
                ctx.outbox.send_batch(ctx.worker_id, channel, batch)?;
                sent.fetch_add(1, Ordering::Relaxed);
            }
            ExchangeMode::HashPartition => {
                let keys = kernels::key_column(batch, key)?;
                let parts = ctx
                    .registry
                    .as_ref()
                    .map(|r| r.manifest().num_parts as u32)
                    .unwrap_or(16);
                // single-pass scatter: rows for partition p belong to
                // worker p % workers, laid out per destination
                let plan = kernels::partition_scatter(ctx, keys, parts, workers)?;
                let flushes = {
                    let mut guard = coalescer.lock().unwrap();
                    let co = guard.get_or_insert_with(|| {
                        ShuffleCoalescer::new(
                            workers,
                            ctx.config.exchange_flush_bytes,
                            ctx.env.arena.pressure_event(),
                            ctx.metrics.clone(),
                        )
                    });
                    co.append(batch, &plan)?
                };
                // send outside the buffer lock: outbox backpressure must
                // pace this task without also parking its siblings
                for (dst, coalesced) in flushes {
                    Self::send_flushed(ctx, channel, dst, coalesced, sent)?;
                }
            }
        }
        Ok(())
    }
}

impl Operator for ExchangeOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "exchange"
    }

    fn poll(&self, ctx: &WorkerCtx) -> Result<Vec<Task>> {
        let phase = *self.state.lock().unwrap();
        let mut tasks = Vec::new();
        match phase {
            Phase::Accumulate => {
                // stage arrivals; count bytes
                let mut budget = self.input.len().min(
                    self.common
                        .max_inflight
                        .saturating_sub(self.common.inflight()),
                );
                while budget > 0 {
                    budget -= 1;
                    self.common.issue();
                    let input = self.input.clone();
                    let pending = self.pending.clone();
                    let seen_bytes = self.seen_bytes.clone();
                    let seen_batches = self.seen_batches.clone();
                    let run = self.common.track(move |_ctx: &WorkerCtx| {
                        if let Some(enc) = input.pop_encoded()? {
                            seen_bytes.fetch_add(enc.len() as u64, Ordering::Relaxed);
                            seen_batches.fetch_add(1, Ordering::Relaxed);
                            // slab-backed bytes move holder-to-holder
                            // without a copy
                            pending.push_host_bytes(enc)?;
                        }
                        Ok(())
                    });
                    tasks.push(
                        Task::new(self.common.id, self.common.base_priority, run)
                            .with_input(self.input.clone()),
                    );
                }
                // transition?
                let enough = self.seen_batches.load(Ordering::Relaxed)
                    >= ctx.config.exchange_estimate_batches as u64;
                if (enough || self.input.is_exhausted()) && self.common.inflight() == 0 {
                    let seen = self.seen_bytes.load(Ordering::Relaxed);
                    let estimate = if self.input.is_exhausted() {
                        seen
                    } else {
                        (seen as f64 * EST_GROWTH) as u64
                    };
                    for dst in 0..ctx.num_workers() {
                        ctx.outbox.send_estimate(dst, self.channel, estimate)?;
                    }
                    *self.state.lock().unwrap() = Phase::WaitEstimates;
                }
            }
            Phase::WaitEstimates => {
                // Which channel's estimates decide? Build/Shuffle: our
                // own; Probe: the paired build exchange's (all workers
                // see identical estimate sets, so every worker reaches
                // the same decision independently).
                let decider = self.partner_rx.as_ref().unwrap_or(&self.rx);
                let (count, total) = decider.estimates();
                if count >= ctx.num_workers() {
                    let small = total as usize <= ctx.config.broadcast_threshold;
                    let mode = match self.role {
                        ExchangeRole::Shuffle => ExchangeMode::HashPartition,
                        ExchangeRole::Build if small => ExchangeMode::Broadcast,
                        ExchangeRole::Build => ExchangeMode::HashPartition,
                        ExchangeRole::Probe { .. } if small => ExchangeMode::PassThrough,
                        ExchangeRole::Probe { .. } => ExchangeMode::HashPartition,
                    };
                    *self.mode.lock().unwrap() = Some(mode);
                    ctx.metrics
                        .counter(match mode {
                            ExchangeMode::Broadcast => "exchange.broadcast",
                            ExchangeMode::HashPartition => "exchange.partition",
                            ExchangeMode::PassThrough => "exchange.passthrough",
                        })
                        .inc();
                    *self.state.lock().unwrap() = Phase::Stream;
                }
            }
            Phase::Stream => {
                let mode = self.mode.lock().unwrap().expect("mode decided");
                // LIP hold-off (§5): in PassThrough mode the rows stay
                // local and the build side (broadcast, small) completes
                // quickly — waiting for its bloom costs little and lets
                // every probe row be pre-filtered. The join always
                // publishes once its build input is exhausted, so this
                // cannot stall indefinitely.
                if mode == ExchangeMode::PassThrough {
                    if let Some(share) = &self.lip_filter {
                        if share.read().unwrap().is_none() {
                            return Ok(tasks);
                        }
                    }
                }
                // Pressure sweep (driver frequency): when the worker's
                // memory-pressure epoch advanced, drain the coalescing
                // buffers even if no new input arrives — buffered
                // shuffle rows must never sit on a worker that is busy
                // spilling.
                if mode == ExchangeMode::HashPartition {
                    let flushes = match self.coalescer.lock().unwrap().as_mut() {
                        Some(co) => co.take_pressure_flushes(),
                        None => Vec::new(),
                    };
                    self.spawn_drain(flushes, &mut tasks);
                }
                let avail = self.pending.len() + self.input.len();
                let mut budget = avail.min(
                    self.common
                        .max_inflight
                        .saturating_sub(self.common.inflight()),
                );
                while budget > 0 {
                    budget -= 1;
                    self.common.issue();
                    let pending = self.pending.clone();
                    let input = self.input.clone();
                    let channel = self.channel;
                    let key = self.key.clone();
                    let sent = self.sent_batches.clone();
                    let lip = self.lip_filter.clone();
                    let lip_cut = self.lip_cut_rows.clone();
                    let coalescer = self.coalescer.clone();
                    let run = self.common.track(move |ctx: &WorkerCtx| {
                        // Bytes-level fast path: Broadcast and
                        // un-filtered PassThrough never look at rows, so
                        // the encoded batch — often a pinned slab —
                        // moves holder → outbox → wire with no device
                        // promotion, no decode, no re-encode. Slab
                        // clones are Arc-shared views, so a broadcast
                        // stages one payload, not one per peer.
                        let needs_rows = mode == ExchangeMode::HashPartition
                            || (mode == ExchangeMode::PassThrough && lip.is_some());
                        if !needs_rows {
                            let enc = match pending.pop_encoded()? {
                                Some(e) => Some(e),
                                None => input.pop_encoded()?,
                            };
                            if let Some(enc) = enc {
                                if mode == ExchangeMode::Broadcast {
                                    // clone for all peers but the last
                                    // (slab clones are Arc-shared)
                                    let n = ctx.num_workers();
                                    for dst in 0..n - 1 {
                                        ctx.outbox.send_encoded(dst, channel, enc.clone())?;
                                        sent.fetch_add(1, Ordering::Relaxed);
                                    }
                                    ctx.outbox.send_encoded(n - 1, channel, enc)?;
                                } else {
                                    ctx.outbox.send_encoded(ctx.worker_id, channel, enc)?;
                                }
                                sent.fetch_add(1, Ordering::Relaxed);
                            }
                            return Ok(());
                        }
                        // row-level path: partitioning and LIP need
                        // decoded rows on device
                        let db = match pending.pop_device()? {
                            Some(db) => Some(db),
                            None => input.pop_device()?,
                        };
                        if let Some(db) = db {
                            // LIP pre-filter: drop rows that cannot join
                            // before they cost wire bytes (§5). Only
                            // sound in PassThrough mode: the build side
                            // was broadcast, so the local join's bloom
                            // covers the *entire* build relation. In
                            // HashPartition mode each worker's bloom
                            // covers only its partition and would drop
                            // joinable rows.
                            let mut batch = db.batch.clone();
                            drop(db);
                            if let (Some(share), ExchangeMode::PassThrough) = (&lip, mode) {
                                let cells = share.read().unwrap().clone();
                                if let Some(cells) = cells {
                                    let keys = kernels::key_column(&batch, &key)?;
                                    let mask = kernels::bloom_probe(ctx, keys, &cells)?;
                                    let before = batch.rows();
                                    batch = batch.compact(&mask)?;
                                    lip_cut.fetch_add(
                                        (before - batch.rows()) as u64,
                                        Ordering::Relaxed,
                                    );
                                }
                            }
                            if !batch.is_empty() {
                                Self::route(
                                    ctx, mode, channel, &key, &batch, &sent, &coalescer,
                                )?;
                            }
                        }
                        Ok(())
                    });
                    tasks.push(
                        Task::new(self.common.id, self.common.base_priority, run)
                            // stream tasks pop from both holders
                            .with_input(self.pending.clone())
                            .with_input(self.input.clone())
                            .with_prefetch(Prefetch::Promote {
                                holder: self.pending.clone(),
                            }),
                    );
                }
                if self.input.is_exhausted()
                    && self.pending.is_empty()
                    && self.common.inflight() == 0
                {
                    // final drain: every buffered destination goes out
                    // before any peer sees our Finish. Non-empty
                    // buffers become one more tracked task (its held
                    // inflight defers this branch); Finish goes out
                    // only once the coalescer has fully drained.
                    let flushes = match self.coalescer.lock().unwrap().as_mut() {
                        Some(co) => co.flush_all(),
                        None => Vec::new(),
                    };
                    if !flushes.is_empty() {
                        self.spawn_drain(flushes, &mut tasks);
                    } else {
                        for dst in 0..ctx.num_workers() {
                            ctx.outbox.send_finish(dst, self.channel)?;
                        }
                        *self.state.lock().unwrap() = Phase::Done;
                        self.common.mark_done();
                    }
                }
            }
            Phase::Done => {}
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::config::{TransportKind, WorkerConfig};
    use crate::executors::network::{NetworkExecutor, Outbox, Router};
    use crate::memory::batch_holder::MemEnv;
    use crate::network::InprocHub;
    use crate::sim::SimContext;
    use crate::types::Column;
    use crate::util::hash;

    #[test]
    fn mode_constants() {
        assert_ne!(ExchangeMode::Broadcast, ExchangeMode::HashPartition);
    }

    fn keyed_batch(rows: usize, salt: i64) -> RecordBatch {
        RecordBatch::new(vec![
            Column::i64("k", (0..rows as i64).map(|i| i * 31 + salt).collect()),
            Column::i64("w", (0..rows as i64).map(|i| i + salt * 1000).collect()),
        ])
        .unwrap()
    }

    /// Reference routing: the seed's per-batch per-destination take
    /// lists, as a sorted row multiset per destination.
    fn reference_rows(batches: &[RecordBatch], workers: usize) -> Vec<Vec<(i64, i64)>> {
        let mut by_dst = vec![Vec::new(); workers];
        for b in batches {
            let k = b.column("k").unwrap().data.as_i64().unwrap();
            let w = b.column("w").unwrap().data.as_i64().unwrap();
            for i in 0..b.rows() {
                let dst = hash::partition_id(k[i], 16) as usize % workers;
                by_dst[dst].push((k[i], w[i]));
            }
        }
        for d in &mut by_dst {
            d.sort_unstable();
        }
        by_dst
    }

    fn collected_rows(batches: &[RecordBatch]) -> Vec<(i64, i64)> {
        let mut rows = Vec::new();
        for b in batches {
            let k = b.column("k").unwrap().data.as_i64().unwrap();
            let w = b.column("w").unwrap().data.as_i64().unwrap();
            rows.extend(k.iter().copied().zip(w.iter().copied()));
        }
        rows.sort_unstable();
        rows
    }

    #[test]
    fn coalescer_flushes_on_threshold_and_preserves_routing() {
        let ctx = crate::exec::WorkerCtx::test();
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let workers = 3;
        // 2 i64 columns -> 16 bytes/row; flush after ~32 rows/dst
        let mut co = ShuffleCoalescer::new(workers, 512, None, metrics.clone());
        let batches: Vec<RecordBatch> = (0..5).map(|s| keyed_batch(100, s)).collect();
        let mut got: Vec<Vec<RecordBatch>> = vec![Vec::new(); workers];
        for b in &batches {
            let keys = b.column("k").unwrap().data.as_i64().unwrap();
            let plan = kernels::partition_scatter(&ctx, keys, 16, workers).unwrap();
            for (dst, flushed) in co.append(b, &plan).unwrap() {
                assert!(flushed.byte_size() >= 512, "flush crossed the threshold");
                got[dst].push(flushed);
            }
        }
        for (dst, flushed) in co.flush_all() {
            got[dst].push(flushed);
        }
        assert_eq!(co.buffered_rows(), 0, "flush_all drains everything");
        let reference = reference_rows(&batches, workers);
        let mut total_flushes = 0;
        for dst in 0..workers {
            assert_eq!(collected_rows(&got[dst]), reference[dst], "dst {dst}");
            total_flushes += got[dst].len();
        }
        assert_eq!(metrics.counter_value("exchange.flush_total"), total_flushes as u64);
        assert_eq!(
            metrics.counter_value("exchange.coalesced_bytes"),
            batches.iter().map(|b| b.byte_size() as u64).sum::<u64>()
        );
        assert_eq!(metrics.counter_value("exchange.pressure_flush_total"), 0);
    }

    #[test]
    fn pressure_epoch_advance_flushes_buffers_early() {
        let ctx = crate::exec::WorkerCtx::test();
        let metrics = Arc::new(crate::metrics::Metrics::default());
        let event = PressureEvent::new();
        // threshold far above anything appended here
        let mut co = ShuffleCoalescer::new(2, 1 << 30, Some(event.clone()), metrics.clone());
        let b = keyed_batch(64, 7);
        let keys = b.column("k").unwrap().data.as_i64().unwrap();
        let plan = kernels::partition_scatter(&ctx, keys, 16, 2).unwrap();
        assert!(co.append(&b, &plan).unwrap().is_empty(), "below threshold");
        assert_eq!(co.buffered_rows(), 64);
        assert_eq!(
            metrics.gauge_value("exchange.buffered_bytes"),
            b.byte_size() as i64,
            "buffered heap must be visible on the gauge"
        );
        assert!(co.take_pressure_flushes().is_empty(), "no pressure yet");

        event.raise_host(1);
        let flushed = co.take_pressure_flushes();
        assert!(!flushed.is_empty(), "epoch advance must flush");
        assert_eq!(flushed.iter().map(|(_, b)| b.rows()).sum::<usize>(), 64);
        assert_eq!(co.buffered_rows(), 0);
        assert_eq!(
            metrics.counter_value("exchange.pressure_flush_total"),
            flushed.len() as u64
        );
        assert_eq!(metrics.gauge_value("exchange.buffered_bytes"), 0);
        // the epoch was consumed: quiet again until the next raise
        assert!(co.take_pressure_flushes().is_empty());
        event.raise_device(1);
        assert!(co.take_pressure_flushes().is_empty(), "nothing buffered");

        // dropping a part-filled coalescer settles the gauge
        let plan = kernels::partition_scatter(&ctx, keys, 16, 2).unwrap();
        assert!(co.append(&b, &plan).unwrap().is_empty());
        assert!(metrics.gauge_value("exchange.buffered_bytes") > 0);
        drop(co);
        assert_eq!(metrics.gauge_value("exchange.buffered_bytes"), 0);
    }

    /// Acceptance: a multi-batch hash-partition shuffle emits at most
    /// ⌈total_bytes / exchange_flush_bytes⌉ + workers frames (the seed
    /// emitted batches × workers), every payload slab-backed, and the
    /// per-destination row multiset identical to the seed routing.
    #[test]
    fn coalesced_shuffle_bounds_frames_and_stays_pinned() {
        const WORKERS: usize = 2;
        const BATCHES: usize = 8;
        const ROWS: usize = 512;
        const FLUSH: usize = 16 << 10;

        let cfg = WorkerConfig {
            num_workers: WORKERS,
            exchange_estimate_batches: 1,
            exchange_flush_bytes: FLUSH,
            ..WorkerConfig::test()
        };
        let mut ctx = crate::exec::WorkerCtx::test_with(Arc::new(cfg));
        let pool = ctx.env.pinned.clone().unwrap();

        let hub = InprocHub::new(WORKERS, &SimContext::test(), TransportKind::Tcp);
        let mut exes = Vec::new();
        let mut routers = Vec::new();
        for ep in hub.endpoints() {
            let router = Arc::new(Router::new());
            let outbox = Arc::new(Outbox::new(64));
            routers.push(router.clone());
            exes.push(NetworkExecutor::start(
                Arc::new(ep),
                outbox,
                router,
                None,
                Some(pool.clone()),
                1,
            ));
        }
        ctx.outbox = exes[0].outbox().clone();

        let rx_env = MemEnv { pinned: Some(pool.clone()), ..ctx.env.clone() };
        let rx_holders: Vec<BatchHolder> = (0..WORKERS)
            .map(|w| BatchHolder::new(format!("rx{w}"), rx_env.clone()))
            .collect();
        let rx0 = Arc::new(ChannelRx::new(rx_holders[0].clone(), 1));
        routers[0].register(7, rx0.clone());
        routers[1].register(7, Arc::new(ChannelRx::new(rx_holders[1].clone(), 1)));

        let input = BatchHolder::new("in", ctx.env.clone());
        let pending = BatchHolder::new("pending", ctx.env.clone());
        let batches: Vec<RecordBatch> =
            (0..BATCHES as i64).map(|s| keyed_batch(ROWS, s)).collect();
        for b in &batches {
            input.push_batch_host(b.clone()).unwrap();
        }
        input.finish();

        let op = ExchangeOp::new(
            0,
            1000,
            2,
            input,
            pending,
            rx0,
            7,
            "k".into(),
            ExchangeRole::Shuffle,
            None,
            None,
        );
        // the missing peer's estimate (worker 1 runs no exchange here)
        exes[1].outbox().send_estimate(0, 7, 0).unwrap();

        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !op.is_done() {
            assert!(std::time::Instant::now() < deadline, "exchange stalled");
            for t in op.poll(&ctx).unwrap() {
                (t.run)(&ctx).unwrap();
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(exes[0].flush(Duration::from_secs(2)));
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !rx_holders.iter().all(|h| h.is_finished()) {
            assert!(std::time::Instant::now() < deadline, "finish lost");
            std::thread::sleep(Duration::from_millis(2));
        }

        // frame bound: ⌈total/flush⌉ + workers, far below batches×workers
        let total_bytes: usize = batches.iter().map(|b| b.byte_size()).sum();
        let bound = total_bytes.div_ceil(FLUSH) + WORKERS;
        let frames = op.sent_batches();
        assert!(
            frames as usize <= bound,
            "{frames} frames > bound {bound} (seed: {})",
            BATCHES * WORKERS
        );
        assert!(frames >= 1);
        assert_eq!(
            ctx.metrics.counter_value("exchange.flush_total"),
            frames,
            "every sent frame is one coalesced flush"
        );
        assert_eq!(
            ctx.metrics.counter_value("exchange.coalesced_bytes"),
            total_bytes as u64
        );
        assert_eq!(ctx.metrics.counter_value("exchange.pressure_flush_total"), 0);
        assert_eq!(op.buffered_shuffle_rows(), 0, "final drain left nothing behind");
        // zero heap on the shuffle path: no pooled-send fallback fired
        assert_eq!(pool.codec_heap_fallback_bytes(), 0);

        // routing identity vs the seed per-batch take path
        let reference = reference_rows(&batches, WORKERS);
        for (dst, holder) in rx_holders.iter().enumerate() {
            assert!(
                holder.residency().host_pinned_bytes > 0,
                "dst {dst}: payloads must arrive slab-backed"
            );
            let mut got = Vec::new();
            while let Some(db) = holder.pop_device().unwrap() {
                got.push(db.batch.clone());
            }
            assert_eq!(collected_rows(&got), reference[dst], "dst {dst}");
        }
        for e in &exes {
            e.stop();
        }
    }
}
