//! Adaptive Exchange (§3.2).
//!
//! "An Adaptive Exchange operator exists as a pair, one for each side
//! of a join. ... First, it waits to accumulate enough input batches to
//! estimate the total bytes it will receive, and broadcasts that
//! information to paired Adaptive Exchange operators in all workers.
//! These operators are adaptive because based on the estimates, they
//! decide whether to hash partition or broadcast the data in the second
//! phase. ... The algorithm using an estimate of the data sizes to
//! arrive instead of waiting for all the data to arrive minimizes
//! interruption of data flow through the DAG by allowing phase two
//! tasks to be scheduled sooner."
//!
//! Phases: `Accumulate` (stage the first K batches in a spillable
//! holder and count bytes) → `WaitEstimates` (estimate broadcast to all
//! peers, wait for theirs) → `Stream` (hash-partition or broadcast each
//! batch through the Network Executor) → `Done` (Finish sent to all
//! peers). The receiving side is the [`ChannelRx`] holder the worker
//! registered for this operator's channel; it finishes when every
//! peer's Finish arrives.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::exec::operators::{kernels, OpCommon, Operator};
use crate::exec::plan::ExchangeRole;
use crate::exec::task::{Prefetch, Task};
use crate::exec::WorkerCtx;
use crate::executors::network::ChannelRx;
use crate::memory::BatchHolder;
use crate::types::RecordBatch;
use crate::Result;

/// Phase-two routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Rows routed to `hash(key) % workers`.
    HashPartition,
    /// Every batch goes to every worker (small join build side).
    Broadcast,
    /// Rows stay on this worker (probe side of a broadcast join).
    PassThrough,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Accumulate,
    WaitEstimates,
    Stream,
    Done,
}

/// Growth factor applied to early-seen bytes when the input hasn't
/// finished (the paper estimates from a prefix; upstream totals are
/// unknown at this point in the DAG).
const EST_GROWTH: f64 = 4.0;

pub struct ExchangeOp {
    common: Arc<OpCommon>,
    input: BatchHolder,
    /// Batches staged during estimation (spillable, like any holder).
    pending: BatchHolder,
    /// This exchange's receive side.
    rx: Arc<ChannelRx>,
    /// Wire channel id (shared by the operator pair across workers).
    channel: u32,
    key: Arc<String>,
    role: ExchangeRole,
    /// For `Probe` role: the paired Build exchange's receive side,
    /// whose estimates drive the broadcast/partition decision.
    partner_rx: Option<Arc<ChannelRx>>,
    /// LIP (§5): once the downstream join publishes its build bloom
    /// here, probe batches are pre-filtered *before* crossing the wire.
    lip_filter: Option<crate::exec::operators::join::LipShare>,
    lip_cut_rows: Arc<AtomicU64>,
    state: Mutex<Phase>,
    mode: Mutex<Option<ExchangeMode>>,
    seen_bytes: Arc<AtomicU64>,
    seen_batches: Arc<AtomicU64>,
    sent_batches: Arc<AtomicU64>,
}

impl ExchangeOp {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        base_priority: i64,
        max_inflight: usize,
        input: BatchHolder,
        pending: BatchHolder,
        rx: Arc<ChannelRx>,
        channel: u32,
        key: String,
        role: ExchangeRole,
        partner_rx: Option<Arc<ChannelRx>>,
        lip_filter: Option<crate::exec::operators::join::LipShare>,
    ) -> ExchangeOp {
        ExchangeOp {
            common: Arc::new(OpCommon::new(id, base_priority, max_inflight)),
            input,
            pending,
            rx,
            channel,
            key: Arc::new(key),
            role,
            partner_rx,
            lip_filter,
            lip_cut_rows: Arc::new(AtomicU64::new(0)),
            state: Mutex::new(Phase::Accumulate),
            mode: Mutex::new(None),
            seen_bytes: Arc::new(AtomicU64::new(0)),
            seen_batches: Arc::new(AtomicU64::new(0)),
            sent_batches: Arc::new(AtomicU64::new(0)),
        }
    }

    /// The decided mode, once known (bench assertions).
    pub fn mode(&self) -> Option<ExchangeMode> {
        *self.mode.lock().unwrap()
    }

    pub fn sent_batches(&self) -> u64 {
        self.sent_batches.load(Ordering::Relaxed)
    }

    /// Probe rows eliminated before the wire by LIP (§5 metric).
    pub fn lip_cut_rows(&self) -> u64 {
        self.lip_cut_rows.load(Ordering::Relaxed)
    }

    /// Route one batch according to `mode`.
    fn route(
        ctx: &WorkerCtx,
        mode: ExchangeMode,
        channel: u32,
        key: &str,
        batch: &RecordBatch,
        sent: &AtomicU64,
    ) -> Result<()> {
        let workers = ctx.num_workers();
        match mode {
            ExchangeMode::Broadcast => {
                for dst in 0..workers {
                    ctx.outbox.send_batch(dst, channel, batch)?;
                    sent.fetch_add(1, Ordering::Relaxed);
                }
            }
            ExchangeMode::PassThrough => {
                ctx.outbox.send_batch(ctx.worker_id, channel, batch)?;
                sent.fetch_add(1, Ordering::Relaxed);
            }
            ExchangeMode::HashPartition => {
                let keys = kernels::key_column(batch, key)?;
                let parts = ctx
                    .registry
                    .as_ref()
                    .map(|r| r.manifest().num_parts as u32)
                    .unwrap_or(16);
                let ids = kernels::partition_ids(ctx, keys, parts)?;
                // rows for partition p go to worker p % workers
                let mut by_dst: Vec<Vec<u32>> = vec![Vec::new(); workers];
                for (row, &p) in ids.iter().enumerate() {
                    by_dst[p as usize % workers].push(row as u32);
                }
                for (dst, idx) in by_dst.into_iter().enumerate() {
                    if idx.is_empty() {
                        continue;
                    }
                    let sub = batch.take(&idx)?;
                    ctx.outbox.send_batch(dst, channel, &sub)?;
                    sent.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Ok(())
    }
}

impl Operator for ExchangeOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "exchange"
    }

    fn poll(&self, ctx: &WorkerCtx) -> Result<Vec<Task>> {
        let phase = *self.state.lock().unwrap();
        let mut tasks = Vec::new();
        match phase {
            Phase::Accumulate => {
                // stage arrivals; count bytes
                let mut budget = self.input.len().min(
                    self.common
                        .max_inflight
                        .saturating_sub(self.common.inflight()),
                );
                while budget > 0 {
                    budget -= 1;
                    self.common.issue();
                    let input = self.input.clone();
                    let pending = self.pending.clone();
                    let seen_bytes = self.seen_bytes.clone();
                    let seen_batches = self.seen_batches.clone();
                    let run = self.common.track(move |_ctx: &WorkerCtx| {
                        if let Some(enc) = input.pop_encoded()? {
                            seen_bytes.fetch_add(enc.len() as u64, Ordering::Relaxed);
                            seen_batches.fetch_add(1, Ordering::Relaxed);
                            // slab-backed bytes move holder-to-holder
                            // without a copy
                            pending.push_host_bytes(enc)?;
                        }
                        Ok(())
                    });
                    tasks.push(
                        Task::new(self.common.id, self.common.base_priority, run)
                            .with_input(self.input.clone()),
                    );
                }
                // transition?
                let enough = self.seen_batches.load(Ordering::Relaxed)
                    >= ctx.config.exchange_estimate_batches as u64;
                if (enough || self.input.is_exhausted()) && self.common.inflight() == 0 {
                    let seen = self.seen_bytes.load(Ordering::Relaxed);
                    let estimate = if self.input.is_exhausted() {
                        seen
                    } else {
                        (seen as f64 * EST_GROWTH) as u64
                    };
                    for dst in 0..ctx.num_workers() {
                        ctx.outbox.send_estimate(dst, self.channel, estimate)?;
                    }
                    *self.state.lock().unwrap() = Phase::WaitEstimates;
                }
            }
            Phase::WaitEstimates => {
                // Which channel's estimates decide? Build/Shuffle: our
                // own; Probe: the paired build exchange's (all workers
                // see identical estimate sets, so every worker reaches
                // the same decision independently).
                let decider = self.partner_rx.as_ref().unwrap_or(&self.rx);
                let (count, total) = decider.estimates();
                if count >= ctx.num_workers() {
                    let small = total as usize <= ctx.config.broadcast_threshold;
                    let mode = match self.role {
                        ExchangeRole::Shuffle => ExchangeMode::HashPartition,
                        ExchangeRole::Build if small => ExchangeMode::Broadcast,
                        ExchangeRole::Build => ExchangeMode::HashPartition,
                        ExchangeRole::Probe { .. } if small => ExchangeMode::PassThrough,
                        ExchangeRole::Probe { .. } => ExchangeMode::HashPartition,
                    };
                    *self.mode.lock().unwrap() = Some(mode);
                    ctx.metrics
                        .counter(match mode {
                            ExchangeMode::Broadcast => "exchange.broadcast",
                            ExchangeMode::HashPartition => "exchange.partition",
                            ExchangeMode::PassThrough => "exchange.passthrough",
                        })
                        .inc();
                    *self.state.lock().unwrap() = Phase::Stream;
                }
            }
            Phase::Stream => {
                let mode = self.mode.lock().unwrap().expect("mode decided");
                // LIP hold-off (§5): in PassThrough mode the rows stay
                // local and the build side (broadcast, small) completes
                // quickly — waiting for its bloom costs little and lets
                // every probe row be pre-filtered. The join always
                // publishes once its build input is exhausted, so this
                // cannot stall indefinitely.
                if mode == ExchangeMode::PassThrough {
                    if let Some(share) = &self.lip_filter {
                        if share.read().unwrap().is_none() {
                            return Ok(tasks);
                        }
                    }
                }
                let avail = self.pending.len() + self.input.len();
                let mut budget = avail.min(
                    self.common
                        .max_inflight
                        .saturating_sub(self.common.inflight()),
                );
                while budget > 0 {
                    budget -= 1;
                    self.common.issue();
                    let pending = self.pending.clone();
                    let input = self.input.clone();
                    let channel = self.channel;
                    let key = self.key.clone();
                    let sent = self.sent_batches.clone();
                    let lip = self.lip_filter.clone();
                    let lip_cut = self.lip_cut_rows.clone();
                    let run = self.common.track(move |ctx: &WorkerCtx| {
                        // Bytes-level fast path: Broadcast and
                        // un-filtered PassThrough never look at rows, so
                        // the encoded batch — often a pinned slab —
                        // moves holder → outbox → wire with no device
                        // promotion, no decode, no re-encode. Slab
                        // clones are Arc-shared views, so a broadcast
                        // stages one payload, not one per peer.
                        let needs_rows = mode == ExchangeMode::HashPartition
                            || (mode == ExchangeMode::PassThrough && lip.is_some());
                        if !needs_rows {
                            let enc = match pending.pop_encoded()? {
                                Some(e) => Some(e),
                                None => input.pop_encoded()?,
                            };
                            if let Some(enc) = enc {
                                if mode == ExchangeMode::Broadcast {
                                    // clone for all peers but the last
                                    // (slab clones are Arc-shared)
                                    let n = ctx.num_workers();
                                    for dst in 0..n - 1 {
                                        ctx.outbox.send_encoded(dst, channel, enc.clone())?;
                                        sent.fetch_add(1, Ordering::Relaxed);
                                    }
                                    ctx.outbox.send_encoded(n - 1, channel, enc)?;
                                } else {
                                    ctx.outbox.send_encoded(ctx.worker_id, channel, enc)?;
                                }
                                sent.fetch_add(1, Ordering::Relaxed);
                            }
                            return Ok(());
                        }
                        // row-level path: partitioning and LIP need
                        // decoded rows on device
                        let db = match pending.pop_device()? {
                            Some(db) => Some(db),
                            None => input.pop_device()?,
                        };
                        if let Some(db) = db {
                            // LIP pre-filter: drop rows that cannot join
                            // before they cost wire bytes (§5). Only
                            // sound in PassThrough mode: the build side
                            // was broadcast, so the local join's bloom
                            // covers the *entire* build relation. In
                            // HashPartition mode each worker's bloom
                            // covers only its partition and would drop
                            // joinable rows.
                            let mut batch = db.batch.clone();
                            drop(db);
                            if let (Some(share), ExchangeMode::PassThrough) = (&lip, mode) {
                                let cells = share.read().unwrap().clone();
                                if let Some(cells) = cells {
                                    let keys = kernels::key_column(&batch, &key)?;
                                    let mask = kernels::bloom_probe(ctx, keys, &cells)?;
                                    let before = batch.rows();
                                    batch = batch.compact(&mask)?;
                                    lip_cut.fetch_add(
                                        (before - batch.rows()) as u64,
                                        Ordering::Relaxed,
                                    );
                                }
                            }
                            if !batch.is_empty() {
                                Self::route(ctx, mode, channel, &key, &batch, &sent)?;
                            }
                        }
                        Ok(())
                    });
                    tasks.push(
                        Task::new(self.common.id, self.common.base_priority, run)
                            // stream tasks pop from both holders
                            .with_input(self.pending.clone())
                            .with_input(self.input.clone())
                            .with_prefetch(Prefetch::Promote {
                                holder: self.pending.clone(),
                            }),
                    );
                }
                if self.input.is_exhausted()
                    && self.pending.is_empty()
                    && self.common.inflight() == 0
                {
                    for dst in 0..ctx.num_workers() {
                        ctx.outbox.send_finish(dst, self.channel)?;
                    }
                    *self.state.lock().unwrap() = Phase::Done;
                    self.common.mark_done();
                }
            }
            Phase::Done => {}
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_constants() {
        assert_ne!(ExchangeMode::Broadcast, ExchangeMode::HashPartition);
    }
}
