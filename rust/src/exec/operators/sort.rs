//! Sort and Limit operators (host-side: these run on small post-
//! aggregation results in the query shapes we reproduce, as in the
//! paper's TPC-H plans where ORDER BY follows GROUP BY).

use std::sync::{Arc, Mutex};

use crate::exec::operators::{OpCommon, Operator};
use crate::exec::task::Task;
use crate::exec::WorkerCtx;
use crate::memory::BatchHolder;
use crate::types::{ColumnData, RecordBatch};
use crate::{Error, Result};

pub struct SortOp {
    common: Arc<OpCommon>,
    input: BatchHolder,
    output: BatchHolder,
    by: Arc<String>,
    desc: bool,
    staged: Arc<Mutex<Vec<RecordBatch>>>,
}

impl SortOp {
    pub fn new(
        id: usize,
        base_priority: i64,
        max_inflight: usize,
        input: BatchHolder,
        output: BatchHolder,
        by: String,
        desc: bool,
    ) -> SortOp {
        SortOp {
            common: Arc::new(OpCommon::new(id, base_priority, max_inflight)),
            input,
            output,
            by: Arc::new(by),
            desc,
            staged: Arc::new(Mutex::new(Vec::new())),
        }
    }
}

impl Operator for SortOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "sort"
    }

    fn poll(&self, _ctx: &WorkerCtx) -> Result<Vec<Task>> {
        if self.common.is_done() {
            return Ok(Vec::new());
        }
        let mut tasks = Vec::new();
        let mut budget = self.input.len().min(
            self.common
                .max_inflight
                .saturating_sub(self.common.inflight()),
        );
        while budget > 0 {
            budget -= 1;
            self.common.issue();
            let input = self.input.clone();
            let staged = self.staged.clone();
            let run = self.common.track(move |_ctx| {
                if let Some(db) = input.pop_device()? {
                    staged.lock().unwrap().push(db.batch.clone());
                }
                Ok(())
            });
            tasks.push(
                Task::new(self.common.id, self.common.base_priority, run)
                    .with_input(self.input.clone()),
            );
        }
        if self.input.is_exhausted() && self.common.inflight() == 0 {
            let staged = std::mem::take(&mut *self.staged.lock().unwrap());
            let all = RecordBatch::concat(&staged)?;
            if !all.is_empty() {
                let sorted = sort_batch(&all, &self.by, self.desc)?;
                self.output.push_batch(sorted)?;
            }
            self.output.finish();
            self.common.mark_done();
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

/// Stable sort of a batch by one column.
pub fn sort_batch(batch: &RecordBatch, by: &str, desc: bool) -> Result<RecordBatch> {
    let col = batch.column(by)?;
    let mut idx: Vec<u32> = (0..batch.rows() as u32).collect();
    match &col.data {
        ColumnData::I64(v) => idx.sort_by_key(|&i| v[i as usize]),
        ColumnData::F32(v) => idx.sort_by(|&a, &b| {
            v[a as usize]
                .partial_cmp(&v[b as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        }),
        ColumnData::F64(v) => idx.sort_by(|&a, &b| {
            v[a as usize]
                .partial_cmp(&v[b as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        }),
    }
    if desc {
        idx.reverse();
    }
    batch.take(&idx)
}

pub struct LimitOp {
    common: Arc<OpCommon>,
    input: BatchHolder,
    output: BatchHolder,
    n: u64,
    emitted: Arc<Mutex<u64>>,
}

impl LimitOp {
    pub fn new(
        id: usize,
        base_priority: i64,
        input: BatchHolder,
        output: BatchHolder,
        n: u64,
    ) -> LimitOp {
        LimitOp {
            common: Arc::new(OpCommon::new(id, base_priority, 1)), // ordered
            input,
            output,
            n,
            emitted: Arc::new(Mutex::new(0)),
        }
    }
}

impl Operator for LimitOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "limit"
    }

    fn poll(&self, _ctx: &WorkerCtx) -> Result<Vec<Task>> {
        if self.common.is_done() {
            return Ok(Vec::new());
        }
        let mut tasks = Vec::new();
        if self.input.len() > 0 && self.common.can_issue() {
            self.common.issue();
            let input = self.input.clone();
            let output = self.output.clone();
            let emitted = self.emitted.clone();
            let n = self.n;
            let run = self.common.track(move |_ctx| {
                // single-task op: drain what's available, stop at n
                while let Some(db) = input.pop_device()? {
                    let mut e = emitted.lock().unwrap();
                    if *e >= n {
                        break; // drop the rest
                    }
                    let take = ((n - *e) as usize).min(db.rows());
                    let out = if take == db.rows() {
                        db.batch.clone()
                    } else {
                        db.batch.slice(0, take)?
                    };
                    *e += take as u64;
                    drop(e);
                    output.push_batch(out)?;
                }
                Ok(())
            });
            tasks.push(
                Task::new(self.common.id, self.common.base_priority, run)
                    .with_input(self.input.clone()),
            );
        }
        let done_early = *self.emitted.lock().unwrap() >= self.n;
        if (self.input.is_exhausted() || done_early) && self.common.inflight() == 0 {
            self.output.finish();
            self.common.mark_done();
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

/// Validate that a sort/limit column exists in a schema-shaped batch —
/// a cheap plan-time check used by the DAG builder.
pub fn check_column(batch: &RecordBatch, name: &str) -> Result<()> {
    batch
        .column(name)
        .map(|_| ())
        .map_err(|_| Error::Plan(format!("sort column '{name}' missing")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::batch_holder::MemEnv;
    use crate::types::Column;

    fn drive(op: &dyn Operator, ctx: &WorkerCtx) {
        for _ in 0..100 {
            for t in op.poll(ctx).unwrap() {
                (t.run)(ctx).unwrap();
            }
            if op.is_done() {
                break;
            }
        }
    }

    #[test]
    fn sort_orders_across_batches() {
        let ctx = WorkerCtx::test();
        let env = MemEnv::test(8 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        input
            .push_batch(
                RecordBatch::new(vec![Column::i64("k", vec![5, 1, 9])]).unwrap(),
            )
            .unwrap();
        input
            .push_batch(
                RecordBatch::new(vec![Column::i64("k", vec![3, 7])]).unwrap(),
            )
            .unwrap();
        input.finish();
        let op = SortOp::new(1, 0, 2, input, output.clone(), "k".into(), false);
        drive(&op, &ctx);
        let out = output.pop_device().unwrap().unwrap();
        assert_eq!(out.batch.column("k").unwrap().data.as_i64().unwrap(), &[1, 3, 5, 7, 9]);
    }

    #[test]
    fn sort_desc_f64() {
        let b = RecordBatch::new(vec![
            Column::f64("v", vec![1.5, -2.0, 3.25]),
            Column::i64("id", vec![1, 2, 3]),
        ])
        .unwrap();
        let s = sort_batch(&b, "v", true).unwrap();
        assert_eq!(s.column("id").unwrap().data.as_i64().unwrap(), &[3, 1, 2]);
    }

    #[test]
    fn limit_truncates_and_finishes_early() {
        let ctx = WorkerCtx::test();
        let env = MemEnv::test(8 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        for lo in [0i64, 10, 20] {
            input
                .push_batch(
                    RecordBatch::new(vec![Column::i64("k", (lo..lo + 10).collect())])
                        .unwrap(),
                )
                .unwrap();
        }
        input.finish();
        let op = LimitOp::new(1, 0, input, output.clone(), 15);
        drive(&op, &ctx);
        assert!(op.is_done());
        let mut rows = 0;
        let mut keys = Vec::new();
        while let Some(db) = output.pop_device().unwrap() {
            rows += db.rows();
            keys.extend_from_slice(db.batch.column("k").unwrap().data.as_i64().unwrap());
        }
        assert_eq!(rows, 15);
        assert_eq!(keys, (0..15).collect::<Vec<_>>());
    }

    #[test]
    fn limit_zero_emits_nothing() {
        let ctx = WorkerCtx::test();
        let env = MemEnv::test(8 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        input
            .push_batch(RecordBatch::new(vec![Column::i64("k", vec![1])]).unwrap())
            .unwrap();
        input.finish();
        let op = LimitOp::new(1, 0, input, output.clone(), 0);
        drive(&op, &ctx);
        assert!(output.is_exhausted());
    }
}
