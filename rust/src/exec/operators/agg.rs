//! Hash aggregation: device pre-aggregation + exact host finalize.
//!
//! Per batch, the device `bucket_preagg` stage hashes the group key
//! into `num_buckets` buckets and reduces sum/count/min/max per bucket
//! in one launch. The host then checks *bucket injectivity* for the
//! batch (each touched bucket maps to exactly one distinct key): when
//! injective — the common case for the low-to-medium-cardinality group
//! keys OLAP aggregates see — the per-bucket partials merge directly
//! into the global table; a collision falls back to exact host
//! aggregation for that batch, so results are always exact.
//!
//! Sums accumulate in f64 on the host regardless of the device's f32
//! partials? No — when the device path is taken the partials are f32;
//! columns needing exact decimal totals take the host path (i64/f64
//! values). This mirrors the paper's precision note (§4: 128-bit
//! decimals) scaled to our dtype set; see DESIGN.md §Substitutions.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::exec::operators::{kernels, OpCommon, Operator};
use crate::exec::plan::{AggFn, AggSpec};
use crate::exec::task::{Prefetch, Task};
use crate::exec::WorkerCtx;
use crate::memory::BatchHolder;
use crate::types::{Column, ColumnData, DType, RecordBatch};
use crate::Result;

/// Running state of one (key, agg-column) pair.
#[derive(Clone, Copy, Debug, Default)]
struct AggState {
    sum: f64,
    count: i64,
    min: f64,
    max: f64,
    init: bool,
}

impl AggState {
    fn absorb(&mut self, v: f64, n: i64) {
        self.sum += v;
        self.count += n;
        if !self.init {
            self.min = f64::INFINITY;
            self.max = f64::NEG_INFINITY;
            self.init = true;
        }
    }

    fn observe_min_max(&mut self, mn: f64, mx: f64) {
        if !self.init {
            self.min = f64::INFINITY;
            self.max = f64::NEG_INFINITY;
            self.init = true;
        }
        self.min = self.min.min(mn);
        self.max = self.max.max(mx);
    }
}

type GroupTable = HashMap<i64, Vec<AggState>>;

pub struct HashAggOp {
    common: Arc<OpCommon>,
    input: BatchHolder,
    output: BatchHolder,
    group_by: Arc<String>,
    aggs: Arc<Vec<AggSpec>>,
    groups: Arc<Mutex<GroupTable>>,
    device_batches: Arc<AtomicU64>,
    host_batches: Arc<AtomicU64>,
}

impl HashAggOp {
    pub fn new(
        id: usize,
        base_priority: i64,
        max_inflight: usize,
        input: BatchHolder,
        output: BatchHolder,
        group_by: String,
        aggs: Vec<AggSpec>,
    ) -> HashAggOp {
        HashAggOp {
            common: Arc::new(OpCommon::new(id, base_priority, max_inflight)),
            input,
            output,
            group_by: Arc::new(group_by),
            aggs: Arc::new(aggs),
            groups: Arc::new(Mutex::new(HashMap::new())),
            device_batches: Arc::new(AtomicU64::new(0)),
            host_batches: Arc::new(AtomicU64::new(0)),
        }
    }

    /// (device-preagg batches, host-fallback batches) — ablation metric.
    pub fn path_counts(&self) -> (u64, u64) {
        (
            self.device_batches.load(Ordering::Relaxed),
            self.host_batches.load(Ordering::Relaxed),
        )
    }
}

impl Operator for HashAggOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "hash_agg"
    }

    fn poll(&self, _ctx: &WorkerCtx) -> Result<Vec<Task>> {
        if self.common.is_done() {
            return Ok(Vec::new());
        }
        let mut tasks = Vec::new();
        let mut budget = self.input.len().min(
            self.common
                .max_inflight
                .saturating_sub(self.common.inflight()),
        );
        while budget > 0 {
            budget -= 1;
            self.common.issue();
            let input = self.input.clone();
            let group_by = self.group_by.clone();
            let aggs = self.aggs.clone();
            let groups = self.groups.clone();
            let dev = self.device_batches.clone();
            let host = self.host_batches.clone();
            let run = self.common.track(move |ctx: &WorkerCtx| {
                let db = match input.pop_device()? {
                    Some(db) => db,
                    None => return Ok(()),
                };
                absorb_batch(ctx, &db.batch, &group_by, &aggs, &groups, &dev, &host)?;
                Ok(())
            });
            tasks.push(
                Task::new(self.common.id, self.common.base_priority, run)
                    .with_input(self.input.clone())
                    .with_prefetch(Prefetch::Promote { holder: self.input.clone() }),
            );
        }
        // finalize
        if self.input.is_exhausted() && self.common.inflight() == 0 {
            let groups = std::mem::take(&mut *self.groups.lock().unwrap());
            let out = finalize(&self.group_by, &self.aggs, groups)?;
            if !out.is_empty() {
                self.output.push_batch(out)?;
            }
            self.output.finish();
            self.common.mark_done();
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

fn absorb_batch(
    ctx: &WorkerCtx,
    batch: &RecordBatch,
    group_by: &str,
    aggs: &[AggSpec],
    groups: &Arc<Mutex<GroupTable>>,
    dev_ctr: &AtomicU64,
    host_ctr: &AtomicU64,
) -> Result<()> {
    let keys = kernels::key_column(batch, group_by)?;

    // Try the device pre-agg path: single f32 agg column, registry
    // available, batch injective into buckets.
    if aggs.len() == 1 {
        if let Some(vals) = kernels::f32_column(batch, &aggs[0].col) {
            if let Some(chunks) = kernels::bucket_preagg(ctx, keys, &vals)? {
                let n = kernels::batch_rows(ctx);
                let mut merged_all = true;
                for (ci, pre) in chunks.iter().enumerate() {
                    let base = ci * n;
                    let len = pre.bucket_of_row.len();
                    // bucket -> unique key check for this chunk
                    let mut bucket_key: HashMap<i32, i64> = HashMap::new();
                    let mut injective = true;
                    for (i, &b) in pre.bucket_of_row.iter().enumerate() {
                        match bucket_key.entry(b) {
                            std::collections::hash_map::Entry::Vacant(e) => {
                                e.insert(keys[base + i]);
                            }
                            std::collections::hash_map::Entry::Occupied(e) => {
                                if *e.get() != keys[base + i] {
                                    injective = false;
                                    break;
                                }
                            }
                        }
                    }
                    if injective {
                        let mut g = groups.lock().unwrap();
                        for (&bucket, &key) in &bucket_key {
                            let st = &mut g
                                .entry(key)
                                .or_insert_with(|| vec![AggState::default(); 1])[0];
                            let b = bucket as usize;
                            st.absorb(pre.sums[b] as f64, pre.counts[b] as i64);
                            if pre.counts[b] > 0 {
                                st.observe_min_max(pre.mins[b] as f64, pre.maxs[b] as f64);
                            }
                        }
                        dev_ctr.fetch_add(1, Ordering::Relaxed);
                    } else {
                        // exact host fallback for this chunk
                        let full = agg_values(batch, &aggs[0])?;
                        host_chunk(
                            &keys[base..base + len],
                            &[(0, full[base..base + len].to_vec())],
                            1,
                            groups,
                        );
                        host_ctr.fetch_add(1, Ordering::Relaxed);
                        merged_all = false;
                    }
                }
                let _ = merged_all;
                return Ok(());
            }
        }
    }

    // Host path: exact aggregation over all agg columns.
    let cols: Vec<(usize, Vec<f64>)> = aggs
        .iter()
        .enumerate()
        .map(|(i, a)| Ok((i, agg_values(batch, a)?)))
        .collect::<Result<_>>()?;
    host_chunk(keys, &cols, aggs.len(), groups);
    host_ctr.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// Numeric view of an agg column (counts ignore the values anyway).
fn agg_values(batch: &RecordBatch, spec: &AggSpec) -> Result<Vec<f64>> {
    let c = batch.column(&spec.col)?;
    Ok(match &c.data {
        ColumnData::I64(v) => v.iter().map(|&x| x as f64).collect(),
        ColumnData::F32(v) => v.iter().map(|&x| x as f64).collect(),
        ColumnData::F64(v) => v.clone(),
    })
}

/// Exact host aggregation: `cols` values are aligned 1:1 with `keys`.
fn host_chunk(
    keys: &[i64],
    cols: &[(usize, Vec<f64>)],
    n_aggs: usize,
    groups: &Arc<Mutex<GroupTable>>,
) {
    let mut g = groups.lock().unwrap();
    for (row, &k) in keys.iter().enumerate() {
        let states = g
            .entry(k)
            .or_insert_with(|| vec![AggState::default(); n_aggs]);
        for (ai, vals) in cols {
            let v = vals[row];
            let st = &mut states[*ai];
            st.absorb(v, 1);
            st.observe_min_max(v, v);
        }
    }
}

/// Build the output batch: group key + one column per agg.
fn finalize(group_by: &str, aggs: &[AggSpec], groups: GroupTable) -> Result<RecordBatch> {
    let mut keys: Vec<i64> = groups.keys().copied().collect();
    keys.sort_unstable(); // deterministic output
    let mut columns = vec![Column::new(
        group_by.to_string(),
        DType::Int64,
        ColumnData::I64(keys.clone()),
    )];
    for (ai, spec) in aggs.iter().enumerate() {
        let data: Vec<f64> = keys
            .iter()
            .map(|k| {
                let st = groups[k][ai];
                match spec.func {
                    AggFn::Sum => st.sum,
                    AggFn::Count => st.count as f64,
                    AggFn::Min => st.min,
                    AggFn::Max => st.max,
                }
            })
            .collect();
        columns.push(Column::new(
            spec.name.clone(),
            DType::Float64,
            ColumnData::F64(data),
        ));
    }
    RecordBatch::new(columns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::batch_holder::MemEnv;

    fn drive(op: &HashAggOp, ctx: &WorkerCtx) {
        for _ in 0..200 {
            for t in op.poll(ctx).unwrap() {
                (t.run)(ctx).unwrap();
            }
            if op.is_done() {
                break;
            }
        }
    }

    fn setup(aggs: Vec<AggSpec>) -> (WorkerCtx, BatchHolder, HashAggOp) {
        let ctx = WorkerCtx::test();
        let env = MemEnv::test(16 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        let op = HashAggOp::new(1, 0, 2, input.clone(), output, "g".into(), aggs);
        (ctx, input, op)
    }

    fn result(op: &HashAggOp) -> RecordBatch {
        op.output.pop_device().unwrap().unwrap().batch.clone()
    }

    #[test]
    fn sum_count_min_max_exact() {
        let (ctx, input, op) = setup(vec![
            AggSpec::new(AggFn::Sum, "v"),
            AggSpec::new(AggFn::Count, "v"),
            AggSpec::new(AggFn::Min, "v"),
            AggSpec::new(AggFn::Max, "v"),
        ]);
        // two batches, groups 0..4, v = row index
        for lo in [0i64, 100] {
            input
                .push_batch(
                    RecordBatch::new(vec![
                        Column::i64("g", (lo..lo + 100).map(|i| i % 4).collect()),
                        Column::f64("v", (lo..lo + 100).map(|i| i as f64).collect()),
                    ])
                    .unwrap(),
                )
                .unwrap();
        }
        input.finish();
        drive(&op, &ctx);
        let out = result(&op);
        assert_eq!(out.rows(), 4);
        let g = out.column("g").unwrap().data.as_i64().unwrap().to_vec();
        assert_eq!(g, vec![0, 1, 2, 3]);
        let sums = out.column("sum_v").unwrap().data.as_f64().unwrap();
        let counts = out.column("count_v").unwrap().data.as_f64().unwrap();
        let mins = out.column("min_v").unwrap().data.as_f64().unwrap();
        let maxs = out.column("max_v").unwrap().data.as_f64().unwrap();
        // group 0: rows 0,4,..,96 and 100,104,...,196
        let expect_sum: f64 = (0..200).filter(|i| i % 4 == 0).map(|i| i as f64).sum();
        assert_eq!(sums[0], expect_sum);
        assert_eq!(counts[0], 50.0);
        assert_eq!(mins[0], 0.0);
        assert_eq!(maxs[0], 196.0);
    }

    #[test]
    fn empty_input_yields_empty_finished_output() {
        let (ctx, input, op) = setup(vec![AggSpec::new(AggFn::Sum, "v")]);
        input.finish();
        drive(&op, &ctx);
        assert!(op.is_done());
        assert!(op.output.is_exhausted());
    }

    #[test]
    fn device_path_used_with_registry() {
        let Ok(ctx) = WorkerCtx::test_with_registry() else {
            return;
        };
        let env = MemEnv::test(64 << 20);
        let input = BatchHolder::new("in", env.clone());
        let output = BatchHolder::new("out", env);
        let op = HashAggOp::new(
            1,
            0,
            2,
            input.clone(),
            output,
            "g".into(),
            vec![AggSpec::new(AggFn::Sum, "v")],
        );
        // low-cardinality keys: injective bucketing is near-certain
        input
            .push_batch(
                RecordBatch::new(vec![
                    Column::i64("g", (0..1000).map(|i| i % 3).collect()),
                    Column::f32("v", (0..1000).map(|i| i as f32).collect()),
                ])
                .unwrap(),
            )
            .unwrap();
        input.finish();
        drive(&op, &ctx);
        let (dev, host) = op.path_counts();
        assert!(dev > 0, "device preagg unused (dev={dev}, host={host})");
        let out = op.output.pop_device().unwrap().unwrap();
        let sums = out.batch.column("sum_v").unwrap().data.as_f64().unwrap();
        let want: f64 = (0..1000).filter(|i| i % 3 == 0).map(|i| i as f64).sum();
        assert!((sums[0] - want).abs() < 1.0, "{} vs {want}", sums[0]);
    }
}
