//! Adaptive Join (§3.2) with optional Lookahead Information Passing
//! (§5).
//!
//! Inner equi-join. Input 0 is the build side, input 1 the probe side
//! (both normally fed by the paired Adaptive Exchanges). The operator
//! "must wait until some data has arrived from both" inputs — here the
//! build phase consumes the entire build side (classic hash join), then
//! probe tasks stream.
//!
//! With `lip` enabled, the build phase also constructs a bloom filter
//! over the build keys (device `bloom_build` stage) and every probe
//! batch is pre-filtered with `bloom_probe` before the hash-table
//! lookups — the paper reports ~50% runtime cuts on join-heavy queries
//! from passing this lookahead information down.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::exec::operators::{kernels, OpCommon, Operator};
use crate::exec::task::{Prefetch, Task};
use crate::exec::WorkerCtx;
use crate::memory::BatchHolder;
use crate::types::{Column, RecordBatch};
use crate::{Error, Result};

/// Shared LIP slot: the join publishes its build-side bloom filter
/// here; the probe-side exchange (§5 Lookahead Information Passing)
/// applies it *before* rows cross the wire. Empty until the build
/// completes — rows exchanged earlier simply go unfiltered.
pub type LipShare = Arc<RwLock<Option<Arc<Vec<u32>>>>>;

/// Immutable build-side table after the build phase.
struct BuildTable {
    /// All build rows, concatenated.
    batch: RecordBatch,
    /// key -> row indices.
    index: std::collections::HashMap<i64, Vec<u32>>,
    /// LIP bloom cells (empty when lip disabled).
    bloom: Vec<u32>,
}

pub struct HashJoinOp {
    common: Arc<OpCommon>,
    build_input: BatchHolder,
    probe_input: BatchHolder,
    output: BatchHolder,
    left_on: Arc<String>,
    right_on: Arc<String>,
    lip: bool,
    /// Where to publish the build bloom for the probe exchange.
    lip_share: Option<LipShare>,
    /// Build batches accumulated so far.
    staged: Arc<Mutex<Vec<RecordBatch>>>,
    built: Arc<RwLock<Option<Arc<BuildTable>>>>,
    probed_rows: Arc<AtomicU64>,
    bloom_filtered: Arc<AtomicU64>,
}

impl HashJoinOp {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        base_priority: i64,
        max_inflight: usize,
        build_input: BatchHolder,
        probe_input: BatchHolder,
        output: BatchHolder,
        left_on: String,
        right_on: String,
        lip: bool,
        lip_share: Option<LipShare>,
    ) -> HashJoinOp {
        HashJoinOp {
            common: Arc::new(OpCommon::new(id, base_priority, max_inflight)),
            build_input,
            probe_input,
            output,
            left_on: Arc::new(left_on),
            right_on: Arc::new(right_on),
            lip,
            lip_share,
            staged: Arc::new(Mutex::new(Vec::new())),
            built: Arc::new(RwLock::new(None)),
            probed_rows: Arc::new(AtomicU64::new(0)),
            bloom_filtered: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Probe rows eliminated by the bloom pre-filter (LIP ablation
    /// metric).
    pub fn bloom_filtered_rows(&self) -> u64 {
        self.bloom_filtered.load(Ordering::Relaxed)
    }

    pub fn probed_rows(&self) -> u64 {
        self.probed_rows.load(Ordering::Relaxed)
    }

    fn build_ready(&self) -> bool {
        self.built.read().unwrap().is_some()
    }
}

impl Operator for HashJoinOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "hash_join"
    }

    fn poll(&self, ctx: &WorkerCtx) -> Result<Vec<Task>> {
        if self.common.is_done() {
            return Ok(Vec::new());
        }
        let mut tasks = Vec::new();

        if !self.build_ready() {
            // ---- build phase: drain build input into `staged`
            let mut budget = self.build_input.len().min(
                self.common
                    .max_inflight
                    .saturating_sub(self.common.inflight()),
            );
            while budget > 0 {
                budget -= 1;
                self.common.issue();
                let input = self.build_input.clone();
                let staged = self.staged.clone();
                let run = self.common.track(move |_ctx: &WorkerCtx| {
                    if let Some(db) = input.pop_device()? {
                        staged.lock().unwrap().push(db.batch.clone());
                    }
                    Ok(())
                });
                tasks.push(
                    Task::new(self.common.id, self.common.base_priority + 100, run)
                        .with_input(self.build_input.clone())
                        .with_prefetch(Prefetch::Promote {
                            holder: self.build_input.clone(),
                        }),
                );
            }
            // transition: build side fully consumed -> construct table
            if self.build_input.is_exhausted() && self.common.inflight() == 0 {
                let staged = std::mem::take(&mut *self.staged.lock().unwrap());
                let batch = RecordBatch::concat(&staged)?;
                let keys: Vec<i64> = if batch.is_empty() {
                    Vec::new()
                } else {
                    kernels::key_column(&batch, &self.left_on)?.to_vec()
                };
                let mut index: std::collections::HashMap<i64, Vec<u32>> =
                    std::collections::HashMap::with_capacity(keys.len());
                for (i, &k) in keys.iter().enumerate() {
                    index.entry(k).or_default().push(i as u32);
                }
                let bloom = if self.lip {
                    let bits = ctx
                        .registry
                        .as_ref()
                        .map(|r| r.manifest().bloom_bits)
                        .unwrap_or(16384);
                    // an empty build side yields all-zero cells: the
                    // correct lookahead info (inner join -> empty)
                    kernels::bloom_build(ctx, &keys, bits)?
                } else {
                    Vec::new()
                };
                // publish the lookahead information for the probe-side
                // exchange (§5) — always once built, so a waiting probe
                // exchange is never stranded. When the exchange applies
                // the filter, re-probing here would be redundant work:
                // every arriving row already passed the bloom.
                let bloom = match &self.lip_share {
                    Some(share) => {
                        *share.write().unwrap() = Some(Arc::new(bloom));
                        Vec::new()
                    }
                    None => bloom,
                };
                *self.built.write().unwrap() =
                    Some(Arc::new(BuildTable { batch, index, bloom }));
            }
            return Ok(tasks);
        }

        // ---- probe phase
        let mut budget = self.probe_input.len().min(
            self.common
                .max_inflight
                .saturating_sub(self.common.inflight()),
        );
        while budget > 0 {
            budget -= 1;
            self.common.issue();
            let probe = self.probe_input.clone();
            let output = self.output.clone();
            let built = self.built.clone();
            let right_on = self.right_on.clone();
            let probed = self.probed_rows.clone();
            let bloomed = self.bloom_filtered.clone();
            let run = self.common.track(move |ctx: &WorkerCtx| {
                let db = match probe.pop_device()? {
                    Some(db) => db,
                    None => return Ok(()),
                };
                let table = built
                    .read()
                    .unwrap()
                    .clone()
                    .ok_or_else(|| Error::internal("probe before build"))?;
                let out = probe_batch(ctx, &table, &db.batch, &right_on, &probed, &bloomed)?;
                drop(db);
                if !out.is_empty() {
                    output.push_batch(out)?;
                }
                Ok(())
            });
            tasks.push(
                Task::new(self.common.id, self.common.base_priority, run)
                    .with_input(self.probe_input.clone())
                    .with_prefetch(Prefetch::Promote { holder: self.probe_input.clone() }),
            );
        }
        if self.probe_input.is_exhausted() && self.common.inflight() == 0 {
            self.output.finish();
            self.common.mark_done();
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

/// Join one probe batch against the build table.
fn probe_batch(
    ctx: &WorkerCtx,
    table: &BuildTable,
    probe: &RecordBatch,
    right_on: &str,
    probed: &AtomicU64,
    bloomed: &AtomicU64,
) -> Result<RecordBatch> {
    let keys = kernels::key_column(probe, right_on)?;
    probed.fetch_add(keys.len() as u64, Ordering::Relaxed);

    // LIP pre-filter
    let candidate: Vec<u32> = if !table.bloom.is_empty() {
        let mask = kernels::bloom_probe(ctx, keys, &table.bloom)?;
        let kept: Vec<u32> = (0..keys.len() as u32)
            .filter(|&i| mask[i as usize] != 0)
            .collect();
        bloomed.fetch_add((keys.len() - kept.len()) as u64, Ordering::Relaxed);
        kept
    } else {
        (0..keys.len() as u32).collect()
    };

    // hash lookups
    let mut probe_idx = Vec::new();
    let mut build_idx = Vec::new();
    for &i in &candidate {
        if let Some(rows) = table.index.get(&keys[i as usize]) {
            for &b in rows {
                probe_idx.push(i);
                build_idx.push(b);
            }
        }
    }
    if probe_idx.is_empty() {
        return Ok(RecordBatch::empty());
    }
    ctx.device_compute
        .acquire(probe_idx.len() * (probe.schema_shape().row_width() + 8));

    // gather: probe columns + build columns (probe-side key kept;
    // build-side duplicate key column dropped)
    let probe_side = probe.take(&probe_idx)?;
    let build_side = table.batch.take(&build_idx)?;
    let mut columns: Vec<Column> = probe_side.columns;
    for c in build_side.columns {
        if columns.iter().any(|e| e.name == c.name) {
            continue; // drop duplicate (the equi-key and any same-named col)
        }
        columns.push(c);
    }
    RecordBatch::new(columns)
}
