//! Physical operators (§3.1–§3.2).
//!
//! Operators are polled state machines: the worker driver calls
//! [`Operator::poll`] repeatedly; ready work is returned as [`Task`]s
//! for the Compute Executor, and phase transitions (exchange estimation,
//! join build→probe, aggregation finalize) happen inside `poll` when
//! their conditions are met. Tasks communicate back through the shared
//! operator state; all pops from batch holders are restartable, so a
//! task failing with a retryable OOM re-runs safely (§3.3.2).

pub mod agg;
pub mod exchange;
pub mod filter;
pub mod fragment;
pub mod join;
pub mod kernels;
pub mod scan;
pub mod sort;

pub use agg::HashAggOp;
pub use exchange::{ExchangeOp, ShuffleCoalescer};
pub use filter::{FilterOp, ProjectOp};
pub use fragment::FragmentOp;
pub use join::HashJoinOp;
pub use scan::ScanOp;
pub use sort::{LimitOp, SortOp};

use crate::exec::{Task, WorkerCtx};
use crate::Result;

/// The driver-facing operator interface.
pub trait Operator: Send + Sync {
    /// Plan-node id.
    fn id(&self) -> usize;

    fn name(&self) -> &'static str;

    /// Generate ready tasks. Must be cheap; called at driver frequency.
    fn poll(&self, ctx: &WorkerCtx) -> Result<Vec<Task>>;

    /// All work done and output finished.
    fn is_done(&self) -> bool;
}

/// Bookkeeping every operator shares: concurrency-limited task issue.
pub(crate) struct OpCommon {
    pub id: usize,
    /// Compute priority base (depth * 1000).
    pub base_priority: i64,
    /// Tasks issued but not completed.
    pub inflight: std::sync::atomic::AtomicUsize,
    /// Max concurrent tasks for this operator.
    pub max_inflight: usize,
    pub done: std::sync::atomic::AtomicBool,
}

impl OpCommon {
    pub fn new(id: usize, base_priority: i64, max_inflight: usize) -> Self {
        OpCommon {
            id,
            base_priority,
            inflight: Default::default(),
            max_inflight: max_inflight.max(1),
            done: Default::default(),
        }
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(std::sync::atomic::Ordering::Acquire)
    }

    pub fn can_issue(&self) -> bool {
        self.inflight() < self.max_inflight
    }

    pub fn issue(&self) {
        self.inflight.fetch_add(1, std::sync::atomic::Ordering::AcqRel);
    }

    /// Returns a guard that decrements inflight when the task finishes
    /// (success or failure — a retried task re-runs the same closure,
    /// which re-increments via this wrapper running again? No: retries
    /// re-run the closure only, so the guard lives inside the closure).
    pub fn track<F>(self: &std::sync::Arc<Self>, f: F) -> crate::exec::task::TaskFn
    where
        F: Fn(&WorkerCtx) -> Result<()> + Send + Sync + 'static,
    {
        let me = self.clone();
        std::sync::Arc::new(move |ctx: &WorkerCtx| {
            let r = f(ctx);
            if r.is_ok() {
                me.inflight.fetch_sub(1, std::sync::atomic::Ordering::AcqRel);
            }
            // on Err the compute executor re-queues the same closure;
            // inflight stays held so poll doesn't over-issue.
            r
        })
    }

    pub fn mark_done(&self) {
        self.done.store(true, std::sync::atomic::Ordering::Release);
    }

    pub fn is_done(&self) -> bool {
        self.done.load(std::sync::atomic::Ordering::Acquire)
    }
}

/// Drop-guard variant used when a task may legitimately fail forever:
/// decrements on drop. (Unused for now; kept private.)
#[allow(dead_code)]
pub(crate) struct InflightGuard<'a>(pub &'a OpCommon);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, std::sync::atomic::Ordering::AcqRel);
    }
}
