//! Table scan (§3.2): each task reads one row group's projected column
//! chunks ("each task processing fractional or multiple Parquet files,
//! depending on their size" — our unit is the row group), decompresses
//! and decodes on the device path, and pushes sized batches downstream.
//!
//! Scan tasks advertise their byte ranges to the Pre-load Executor via
//! the task's staging cell; if the pre-loader got the bytes first the
//! task only decodes, otherwise it fetches itself (Insight B).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::exec::operators::{kernels, OpCommon, Operator};
use crate::exec::plan::Pred;
use crate::exec::task::{take_staged, Prefetch, Staging, StagingState, Task};
use crate::exec::WorkerCtx;
use crate::memory::BatchHolder;
use crate::storage::datasource::{plan_ranges, ByteRange};
use crate::storage::format::{FileFooter, FileReader};
use crate::Result;

/// One schedulable scan unit: (file, row group).
pub struct ScanUnit {
    pub key: String,
    pub footer: Arc<FileFooter>,
    pub group: usize,
}

pub struct ScanOp {
    common: Arc<OpCommon>,
    output: BatchHolder,
    units: Mutex<VecDeque<Arc<ScanUnit>>>,
    total_units: usize,
    units_done: Arc<AtomicUsize>,
    /// Projected column indices (same for every unit: one table).
    cols: Arc<Vec<usize>>,
}

impl ScanOp {
    /// `units` are this worker's assignment (the DAG builder applies
    /// round-robin assignment and row-group pruning).
    pub fn new(
        id: usize,
        base_priority: i64,
        max_inflight: usize,
        output: BatchHolder,
        units: Vec<ScanUnit>,
        cols: Vec<usize>,
    ) -> ScanOp {
        let total_units = units.len();
        ScanOp {
            common: Arc::new(OpCommon::new(id, base_priority, max_inflight)),
            output,
            units: Mutex::new(units.into_iter().map(Arc::new).collect()),
            total_units,
            units_done: Arc::new(AtomicUsize::new(0)),
            cols: Arc::new(cols),
        }
    }

    /// Enumerate (prune, assign) scan units for one worker.
    pub fn plan_units(
        footers: &[(String, Arc<FileFooter>)],
        pred: Option<&Pred>,
        worker_id: usize,
        num_workers: usize,
    ) -> Vec<ScanUnit> {
        let mut units = Vec::new();
        let mut idx = 0usize;
        for (key, footer) in footers {
            for g in 0..footer.row_groups.len() {
                let mine = idx % num_workers == worker_id;
                idx += 1;
                if !mine {
                    continue;
                }
                // row-group pruning from footer stats (§ format docs)
                if let Some(p) = pred {
                    if prune_group(footer, g, p) {
                        continue;
                    }
                }
                units.push(ScanUnit { key: key.clone(), footer: footer.clone(), group: g });
            }
        }
        units
    }

    pub fn units_remaining(&self) -> usize {
        self.units.lock().unwrap().len()
    }

    pub fn units_done(&self) -> usize {
        self.units_done.load(Ordering::Relaxed)
    }

    pub fn total_units(&self) -> usize {
        self.total_units
    }
}

/// Can this row group be skipped entirely for `pred`? (All conjuncts
/// are ANDed: any disjoint conjunct prunes.)
fn prune_group(footer: &FileFooter, group: usize, pred: &Pred) -> bool {
    pred.conjuncts().iter().any(|c| match c {
        Pred::RangeI64 { col, lo, hi } => footer
            .schema
            .index_of(col)
            .map(|ci| footer.prune_i64(group, ci, *lo, *hi))
            .unwrap_or(false),
        Pred::EqI64 { col, val } => footer
            .schema
            .index_of(col)
            .map(|ci| footer.prune_i64(group, ci, *val, *val + 1))
            .unwrap_or(false),
        Pred::RangeF32 { col, lo, hi } => footer
            .schema
            .index_of(col)
            .map(|ci| {
                let ch = &footer.row_groups[group].chunks[ci];
                ch.max_f64 < *lo as f64 || ch.min_f64 >= *hi as f64
            })
            .unwrap_or(false),
        Pred::And(..) => false, // conjuncts() already flattened
    })
}

impl Operator for ScanOp {
    fn id(&self) -> usize {
        self.common.id
    }

    fn name(&self) -> &'static str {
        "scan"
    }

    fn poll(&self, _ctx: &WorkerCtx) -> Result<Vec<Task>> {
        if self.common.is_done() {
            return Ok(Vec::new());
        }
        let mut tasks = Vec::new();
        while self.common.can_issue() {
            let unit = match self.units.lock().unwrap().pop_front() {
                Some(u) => u,
                None => break,
            };
            self.common.issue();
            let ranges: Vec<ByteRange> =
                plan_ranges(&unit.footer.row_groups[unit.group], &self.cols);
            let staging: Staging = Arc::new(Mutex::new(StagingState::Empty));
            let output = self.output.clone();
            let cols = self.cols.clone();
            let done_ctr = self.units_done.clone();
            let unit2 = unit.clone();
            let staging2 = staging.clone(); // shared with the prefetch spec
            let run = self.common.track(move |ctx: &WorkerCtx| {
                scan_task(ctx, &unit2, &cols, &staging2, &output)?;
                done_ctr.fetch_add(1, Ordering::AcqRel);
                Ok(())
            });
            let task = Task {
                op: self.common.id,
                priority: self.common.base_priority,
                attempts: 0,
                prefetch: Some(Prefetch::ByteRanges {
                    key: unit.key.clone(),
                    ranges,
                    staging,
                }),
                // no holder inputs: scans read the object store
                inputs: Vec::new(),
                run,
            };
            tasks.push(task);
        }
        // completion
        if self.units.lock().unwrap().is_empty()
            && self.common.inflight() == 0
            && !self.common.is_done()
        {
            self.output.finish();
            self.common.mark_done();
        }
        Ok(tasks)
    }

    fn is_done(&self) -> bool {
        self.common.is_done()
    }
}

/// The actual scan work: fetch (or take staged) pages, decode, size,
/// push.
fn scan_task(
    ctx: &WorkerCtx,
    unit: &ScanUnit,
    cols: &[usize],
    staging: &Staging,
    output: &BatchHolder,
) -> Result<()> {
    let pages = match take_staged(staging) {
        Some(p) => p,
        None => ctx
            .datasource
            .fetch_group(&unit.key, &unit.footer, unit.group, cols)?,
    };
    // decompress + decode (device work: parquet decode runs on GPU in
    // the paper; charge the modeled device). Slab-backed pages decode
    // straight out of the bounce pool — this is the device-upload hop,
    // the one place the slab is allowed to materialize (a page spanning
    // pool buffers borrows contiguously when it fits one buffer).
    let total: usize = pages.iter().map(|p| p.len()).sum();
    ctx.device_compute.acquire(total);
    let reader = FileReader { footer: unit.footer.as_ref().clone() };
    let cows: Vec<std::borrow::Cow<[u8]>> = pages.iter().map(|p| p.contiguous()).collect();
    let refs: Vec<&[u8]> = cows.iter().map(|c| c.as_ref()).collect();
    let batch = reader.decode_group(unit.group, cols, &refs)?;
    let rows = kernels::batch_rows(ctx);
    for chunk in batch.split(rows) {
        output.push_batch(chunk)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::batch_holder::MemEnv;
    use crate::storage::compression::Codec;
    use crate::storage::datasource::Datasource;
    use crate::storage::format::FileWriter;
    use crate::storage::object_store::ObjectStore;
    use crate::types::{Column, DType, Field, RecordBatch, Schema};

    fn make_ctx_with_table(rows: usize, rg: usize, files: usize) -> WorkerCtx {
        let ctx = WorkerCtx::test();
        let schema = Schema::new(vec![
            Field::new("k", DType::Int64),
            Field::new("v", DType::Float32),
        ]);
        for f in 0..files {
            let base = (f * rows) as i64;
            let batch = RecordBatch::new(vec![
                Column::i64("k", (base..base + rows as i64).collect()),
                Column::f32("v", (0..rows).map(|i| i as f32).collect()),
            ])
            .unwrap();
            let mut w = FileWriter::new(schema.clone(), Codec::Zstd { level: 1 }, rg);
            w.write(batch).unwrap();
            ctx.store
                .put(&format!("t/part-{f}.ths"), &w.finish().unwrap())
                .unwrap();
        }
        ctx
    }

    fn footers(ctx: &WorkerCtx, prefix: &str) -> Vec<(String, Arc<FileFooter>)> {
        ctx.store
            .list(prefix)
            .unwrap()
            .into_iter()
            .map(|k| {
                let f = ctx.datasource.footer(&k).unwrap();
                (k, f)
            })
            .collect()
    }

    fn drain(op: &ScanOp, ctx: &WorkerCtx) -> usize {
        // single-threaded driver: poll + run inline
        let mut rows = 0;
        for _ in 0..1000 {
            let tasks = op.poll(ctx).unwrap();
            for t in tasks {
                (t.run)(ctx).unwrap();
            }
            while let Some(db) = op.output_holder().pop_device().unwrap() {
                rows += db.rows();
            }
            if op.is_done() && op.output_holder().is_exhausted() {
                break;
            }
        }
        rows
    }

    impl ScanOp {
        fn output_holder(&self) -> &BatchHolder {
            &self.output
        }
    }

    #[test]
    fn scans_all_rows_across_files_and_groups() {
        let ctx = make_ctx_with_table(1000, 256, 3);
        let fs = footers(&ctx, "t/");
        let units = ScanOp::plan_units(&fs, None, 0, 1);
        assert_eq!(units.len(), 3 * 4); // 1000/256 -> 4 groups per file
        let out = BatchHolder::new("scan-out", MemEnv::test(8 << 20));
        let op = ScanOp::new(0, 5000, 2, out, units, vec![0, 1]);
        let rows = drain(&op, &ctx);
        assert_eq!(rows, 3000);
        assert!(op.is_done());
        assert_eq!(op.units_done(), 12);
    }

    #[test]
    fn worker_assignment_partitions_units() {
        let ctx = make_ctx_with_table(1000, 250, 2);
        let fs = footers(&ctx, "t/");
        let u0 = ScanOp::plan_units(&fs, None, 0, 2);
        let u1 = ScanOp::plan_units(&fs, None, 1, 2);
        assert_eq!(u0.len() + u1.len(), 8);
        assert!((u0.len() as i64 - u1.len() as i64).abs() <= 1);
    }

    #[test]
    fn pruning_skips_disjoint_groups() {
        // k ascends across the file: predicate on low k prunes later
        // groups.
        let ctx = make_ctx_with_table(1024, 256, 1);
        let fs = footers(&ctx, "t/");
        let pred = Pred::RangeI64 { col: "k".into(), lo: 0, hi: 100 };
        let units = ScanOp::plan_units(&fs, Some(&pred), 0, 1);
        assert_eq!(units.len(), 1, "only the first group overlaps [0,100)");
    }

    #[test]
    fn projection_reads_requested_columns_only() {
        let ctx = make_ctx_with_table(500, 500, 1);
        let fs = footers(&ctx, "t/");
        let units = ScanOp::plan_units(&fs, None, 0, 1);
        let out = BatchHolder::new("o", MemEnv::test(8 << 20));
        let op = ScanOp::new(0, 0, 1, out.clone(), units, vec![1]);
        let tasks = op.poll(&ctx).unwrap();
        for t in tasks {
            (t.run)(&ctx).unwrap();
        }
        let db = out.pop_device().unwrap().unwrap();
        assert_eq!(db.batch.num_columns(), 1);
        assert_eq!(db.batch.columns[0].name, "v");
    }

    #[test]
    fn batches_are_sized_to_batch_rows() {
        let ctx = make_ctx_with_table(1000, 1000, 1);
        let fs = footers(&ctx, "t/");
        let units = ScanOp::plan_units(&fs, None, 0, 1);
        let out = BatchHolder::new("o", MemEnv::test(8 << 20));
        // config batch_rows is 8192 in tests; use a small op-level chunk
        // by shrinking config
        let mut cfg = crate::config::WorkerConfig::test();
        cfg.batch_rows = 300;
        let ctx = WorkerCtx { config: Arc::new(cfg), ..ctx };
        let op = ScanOp::new(0, 0, 1, out.clone(), units, vec![0, 1]);
        for t in op.poll(&ctx).unwrap() {
            (t.run)(&ctx).unwrap();
        }
        let mut sizes = Vec::new();
        while let Some(db) = out.pop_device().unwrap() {
            sizes.push(db.rows());
        }
        assert_eq!(sizes, vec![300, 300, 300, 100]);
    }
}
