//! The metric-name registry: every counter, gauge, and histogram the
//! engine emits, in one table.
//!
//! `cargo xtask lint` enforces it both ways: a literal name passed to
//! `.counter()`/`.gauge()`/`.histogram()` anywhere in `src/` must
//! appear here exactly once, and every entry here must appear as a
//! string literal somewhere in `src/` (names that reach the sink
//! through variables — eviction tuple tables, exchange-mode match
//! arms — satisfy that weaker direction). Entries containing `*` are
//! wildcards for `format!`-built per-instance names and are exempt
//! from the usage check.
//!
//! Dashboards and tests should treat this slice as the complete metric
//! surface; renaming a metric means editing it here in the same change
//! or CI fails.

pub const METRIC_NAMES: &[&str] = &[
    // serving-layer caches (src/cache)
    "cache.fragment_bytes",
    "cache.fragment_evict",
    "cache.fragment_hit",
    "cache.fragment_miss",
    "cache.fragment_refused",
    "cache.invalidated",
    "cache.plan_memo_hit",
    "cache.plan_memo_miss",
    "cache.result_bytes",
    "cache.result_evict",
    "cache.result_hit",
    "cache.result_miss",
    "cache.result_refused",
    "cache.stale_insert_dropped",
    // codec fallbacks (src/codec)
    "codec.heap_fallback_bytes",
    // coalescing shuffle (src/exec/operators/exchange.rs)
    "exchange.broadcast",
    "exchange.buffered_bytes",
    "exchange.coalesced_bytes",
    "exchange.credit_stall_total",
    "exchange.flush_bytes_current{dst=*}",
    "exchange.flush_total",
    "exchange.oversize_split_total",
    "exchange.partition",
    "exchange.passthrough",
    "exchange.pressure_flush_total",
    // fault injection (src/fault)
    "fault.injected_total",
    "fault.injected_total.net_recv",
    "fault.injected_total.net_send",
    "fault.injected_total.spill_read",
    "fault.injected_total.spill_write",
    "fault.injected_total.storage_get",
    "fault.injected_total.storage_put",
    // gateway admission + sessions (src/cluster)
    "gateway.admission_peak_bytes",
    "gateway.admission_wait_ms",
    "gateway.admitted",
    "gateway.query_retry_total",
    "gateway.queued",
    "gateway.worker_panic_total",
    // data-movement executor (src/executors/movement.rs)
    "movement.demote_bytes",
    "movement.plans",
    "movement.promotions",
    "movement.queue_depth",
    // network executor (src/executors/network.rs)
    "net.close_unsent_total",
    "net.credits_granted_total",
    "net.peer_down_total",
    "net.send_retry_total",
    // pinned host pool (src/memory/pinned.rs)
    "pinned.acquires",
    "pinned.bounce_bytes",
    "pinned.exhaustions",
    "pinned.free_buffers",
    "pinned.waste_bytes",
    // bounded-retry ladders (src/fault, src/cluster)
    "retry.attempts_total",
    "retry.exhausted_total",
    // compute scheduler (src/executors/compute.rs)
    "sched.residency_rerank_total",
    "sched.spill_stall_avoided",
    // spill files (src/memory/spill.rs)
    "spill.compacted_bytes",
    "spill.write_failover_total",
    // ordered-lock poison recovery (src/sync/ordered.rs)
    "sync.poison_recovered_total",
];

#[cfg(test)]
mod tests {
    use super::METRIC_NAMES;

    #[test]
    fn sorted_and_unique() {
        for pair in METRIC_NAMES.windows(2) {
            assert!(
                pair[0] < pair[1],
                "METRIC_NAMES must stay sorted and duplicate-free: {} >= {}",
                pair[0],
                pair[1]
            );
        }
    }
}
