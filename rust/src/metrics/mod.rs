//! Lightweight metrics: atomic counters + duration histograms, grouped
//! per worker. The paper's workers expose per-executor utilization; the
//! benches print these to explain *why* a configuration wins (e.g.
//! network busy-time dropping when RDMA is enabled).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

pub mod registry;

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down instantaneous value (queue depths, in-flight movement
/// tasks).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn set(&self, n: i64) {
        self.0.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log2 latency histogram (1us .. ~1hour).
pub struct Histogram {
    buckets: [AtomicU64; 32],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
        }
    }

    /// Approximate quantile from the log2 buckets (upper bound of the
    /// bucket containing quantile q).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << 32
    }
}

/// Per-worker metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<&'static str, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<&'static str, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<&'static str, std::sync::Arc<Histogram>>>,
}

impl Metrics {
    pub fn counter(&self, name: &'static str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name)
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &'static str) -> std::sync::Arc<Gauge> {
        self.gauges.lock().unwrap().entry(name).or_default().clone()
    }

    pub fn histogram(&self, name: &'static str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name)
            .or_default()
            .clone()
    }

    /// Render a sorted snapshot (for logs / bench reports).
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name}: {}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name}: n={} mean={:?} total={:?}\n",
                h.count(),
                h.mean(),
                h.total()
            ));
        }
        out
    }

    /// Fetch a counter value by name (0 if never touched).
    pub fn counter_value(&self, name: &'static str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.get())
            .unwrap_or(0)
    }

    /// Fetch a gauge value by name (0 if never touched). The §3.4 pool
    /// gauges (`pinned.bounce_bytes`, `pinned.waste_bytes`,
    /// `pinned.acquires`, `pinned.exhaustions`, `pinned.free_buffers`)
    /// are published here by the Data-Movement executor via
    /// [`crate::memory::PinnedPool::publish_metrics`].
    pub fn gauge_value(&self, name: &'static str) -> i64 {
        self.gauges
            .lock()
            .unwrap()
            .get(name)
            .map(|g| g.get())
            .unwrap_or(0)
    }
}

/// Scope timer: records into a histogram on drop.
pub struct Timer<'a> {
    hist: &'a Histogram,
    start: std::time::Instant,
}

impl<'a> Timer<'a> {
    pub fn new(hist: &'a Histogram) -> Self {
        Timer { hist, start: std::time::Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let m = Metrics::default();
        m.counter("x").inc();
        m.counter("x").add(4);
        assert_eq!(m.counter_value("x"), 5);
        assert_eq!(m.counter_value("y"), 0);
    }

    #[test]
    fn histogram_mean_and_quantile() {
        let h = Histogram::default();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.quantile_us(1.0) >= 100_000);
        assert!(h.quantile_us(0.2) <= 4_096);
    }

    #[test]
    fn timer_records_on_drop() {
        let h = Histogram::default();
        {
            let _t = Timer::new(&h);
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(h.count(), 1);
        assert!(h.total() >= Duration::from_millis(2));
    }

    #[test]
    fn snapshot_lists_everything() {
        let m = Metrics::default();
        m.counter("a.b").inc();
        m.gauge("q.depth").add(3);
        m.histogram("c.d").record(Duration::from_micros(5));
        let s = m.snapshot();
        assert!(s.contains("a.b: 1") && s.contains("c.d"));
        assert!(s.contains("q.depth: 3"));
    }

    #[test]
    fn pinned_pool_counters_export() {
        let m = Metrics::default();
        let pool = crate::memory::PinnedPool::new(64, 2).unwrap();
        let slab = crate::memory::PinnedSlab::write(&pool, &[7u8; 100]).unwrap();
        let _held = pool.try_acquire(); // exhaust, err counted below
        let _ = pool.try_acquire();
        pool.publish_metrics(&m);
        assert_eq!(m.gauge_value("pinned.bounce_bytes"), 100);
        assert_eq!(m.gauge_value("pinned.waste_bytes"), 28, "2x64 - 100");
        assert!(m.gauge_value("pinned.exhaustions") >= 1);
        assert!(m.gauge_value("pinned.acquires") >= 2);
        let s = m.snapshot();
        assert!(s.contains("pinned.bounce_bytes"));
        drop(slab);
    }

    #[test]
    fn gauge_goes_up_and_down() {
        let m = Metrics::default();
        let g = m.gauge("g");
        g.add(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
        g.set(0);
        assert_eq!(m.gauge("g").get(), 0);
    }
}
