//! [`KernelRegistry`] — compile-once cache of PJRT executables, one per
//! AOT stage.
//!
//! Loading and compiling HLO takes milliseconds-to-seconds; executing
//! takes microseconds-to-milliseconds. The registry therefore compiles
//! each stage lazily on first use and caches the loaded executable for
//! the life of the process, mirroring how the paper compiles libcudf
//! kernels once and launches them per task.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::metrics::Histogram;
use crate::runtime::manifest::{Manifest, ShapeSpec, SpecDType, StageSpec};
use crate::runtime::pjrt_shim as xla;
use crate::runtime::stage::Value;
use crate::{Error, Result};

/// Thread-safety wrapper. The `xla` crate's wrappers are raw-pointer
/// newtypes without `Send`/`Sync` impls, but the underlying PJRT C API
/// is documented thread-safe (the CPU client dispatches executions onto
/// its own thread pool, and `PJRT_LoadedExecutable_Execute` may be
/// called concurrently). Compilation is serialized by our own mutex.
struct ShareablePjrt {
    client: xla::PjRtClient,
    exes: Mutex<HashMap<String, Arc<Exe>>>,
}

struct Exe(xla::PjRtLoadedExecutable);

unsafe impl Send for ShareablePjrt {}
unsafe impl Sync for ShareablePjrt {}
unsafe impl Send for Exe {}
unsafe impl Sync for Exe {}

/// Compile-once registry over one PJRT CPU client.
///
/// Cheap to clone; all clones share the executable cache.
#[derive(Clone)]
pub struct KernelRegistry {
    manifest: Arc<Manifest>,
    pjrt: Arc<ShareablePjrt>,
    /// Per-stage execution latency (perf pass input).
    exec_hist: Arc<Histogram>,
    compiles: Arc<std::sync::atomic::AtomicU64>,
    executions: Arc<std::sync::atomic::AtomicU64>,
}

impl KernelRegistry {
    /// Create a registry over `manifest` (one PJRT CPU client).
    pub fn new(manifest: Manifest) -> Result<KernelRegistry> {
        let client = xla::PjRtClient::cpu()?;
        Ok(KernelRegistry {
            manifest: Arc::new(manifest),
            pjrt: Arc::new(ShareablePjrt { client, exes: Mutex::new(HashMap::new()) }),
            exec_hist: Arc::new(Histogram::default()),
            compiles: Arc::new(Default::default()),
            executions: Arc::new(Default::default()),
        })
    }

    /// Process-wide shared registry over the discovered artifacts
    /// (workers in one process share the PJRT client, as GPUs would be
    /// shared by worker processes on one node).
    pub fn shared() -> Result<KernelRegistry> {
        static SHARED: OnceLock<std::result::Result<KernelRegistry, String>> =
            OnceLock::new();
        SHARED
            .get_or_init(|| {
                Manifest::discover()
                    .and_then(KernelRegistry::new)
                    .map_err(|e| e.to_string())
            })
            .clone()
            .map_err(Error::Xla)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn compile_count(&self) -> u64 {
        self.compiles.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn execution_count(&self) -> u64 {
        self.executions.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn exec_histogram(&self) -> &Histogram {
        &self.exec_hist
    }

    fn executable(&self, name: &str) -> Result<Arc<Exe>> {
        // fast path
        if let Some(e) = self.pjrt.exes.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.stage(name)?;
        let path = spec.hlo_path(&self.manifest.dir);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Config("non-utf8 artifacts path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.pjrt.client.compile(&comp)?;
        self.compiles.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let exe = Arc::new(Exe(exe));
        let mut exes = self.pjrt.exes.lock().unwrap();
        Ok(exes.entry(name.to_string()).or_insert(exe).clone())
    }

    /// Warm the cache for a set of stages (worker startup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Compile every stage in the manifest (cluster startup — keeps
    /// multi-hundred-ms PJRT compiles out of query time, like the
    /// paper's engine initializing its kernels once).
    pub fn warmup_all(&self) -> Result<()> {
        let names: Vec<String> = self.manifest.stages.keys().cloned().collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Execute a stage: conform inputs to the manifest spec (padding
    /// short batches), run on PJRT, return one [`Value`] per output.
    pub fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.stage(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Plan(format!(
                "stage {name}: {} args given, {} expected",
                inputs.len(),
                spec.inputs.len()
            )));
        }
        let exe = self.executable(name)?;
        let literals = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(v, s)| to_literal(&v.conform(s)?, s))
            .collect::<Result<Vec<_>>>()?;

        let start = std::time::Instant::now();
        let out = exe.0.execute::<xla::Literal>(&literals)?;
        let root = out
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::Xla(format!("stage {name}: empty result")))?
            .to_literal_sync()?;
        self.exec_hist.record(start.elapsed());
        self.executions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);

        // aot.py lowers with return_tuple=True: always a tuple result.
        let parts = root.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            return Err(Error::Xla(format!(
                "stage {name}: {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            )));
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, s)| from_literal(&lit, s))
            .collect()
    }

    /// The spec of one stage (operators size their I/O from this).
    pub fn stage_spec(&self, name: &str) -> Result<&StageSpec> {
        self.manifest.stage(name)
    }
}

fn to_literal(v: &Value, spec: &ShapeSpec) -> Result<xla::Literal> {
    let lit = match v {
        Value::F32(x) => xla::Literal::vec1(x.as_slice()),
        Value::F64(x) => xla::Literal::vec1(x.as_slice()),
        Value::I32(x) => xla::Literal::vec1(x.as_slice()),
        Value::I64(x) => xla::Literal::vec1(x.as_slice()),
        Value::U32(x) => xla::Literal::vec1(x.as_slice()),
        Value::U64(x) => xla::Literal::vec1(x.as_slice()),
    };
    if spec.dims.len() > 1 {
        let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    } else {
        Ok(lit)
    }
}

fn from_literal(lit: &xla::Literal, spec: &ShapeSpec) -> Result<Value> {
    Ok(match spec.dtype {
        SpecDType::F32 => Value::F32(lit.to_vec::<f32>()?),
        SpecDType::F64 => Value::F64(lit.to_vec::<f64>()?),
        SpecDType::I32 => Value::I32(lit.to_vec::<i32>()?),
        SpecDType::I64 => Value::I64(lit.to_vec::<i64>()?),
        SpecDType::U32 => Value::U32(lit.to_vec::<u32>()?),
        SpecDType::U64 => Value::U64(lit.to_vec::<u64>()?),
    })
}

#[cfg(test)]
mod tests {
    //! These tests require built artifacts (`make artifacts`); they are
    //! the L3-side correctness re-check of the L1 kernels against the
    //! Rust reimplementation of the same hash constants.
    use super::*;
    use crate::util::hash;

    /// `None` (skip) when the `pjrt` feature is off or artifacts are
    /// not built (`make artifacts`) — these tests verify the L1 kernels
    /// against the Rust reimplementation and need the real runtime.
    fn registry() -> Option<KernelRegistry> {
        match KernelRegistry::shared() {
            Ok(r) => Some(r),
            Err(e) => {
                eprintln!("skipping PJRT test: {e}");
                None
            }
        }
    }

    #[test]
    fn filter_range_f32_matches_scalar_math() {
        let Some(r) = registry() else { return };
        let n = r.manifest().batch_rows;
        let col: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mask: Vec<i32> = vec![1; 64];
        let out = r
            .execute(
                "filter_range_f32",
                &[
                    Value::F32(col.clone()),
                    Value::scalar_f32(10.0),
                    Value::scalar_f32(20.0),
                    Value::I32(mask),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let m = out[0].clone().truncate(64);
        let m = m.as_i32().unwrap();
        for (i, &v) in col.iter().enumerate() {
            let want = (v >= 10.0 && v < 20.0) as i32;
            assert_eq!(m[i], want, "row {i}");
        }
        // padded rows must be masked out
        assert_eq!(out[0].len(), n);
        assert!(out[0].as_i32().unwrap()[64..].iter().all(|&x| x == 0));
    }

    #[test]
    fn hash_partition_matches_rust_splitmix() {
        let Some(r) = registry() else { return };
        let parts = r.manifest().num_parts as u32;
        let keys: Vec<i64> = (0..100).map(|i| i * 7919 - 50).collect();
        let mask = vec![1i32; 100];
        let out = r
            .execute("hash_partition", &[Value::I64(keys.clone()), Value::I32(mask)])
            .unwrap();
        let ids = out[0].as_i32().unwrap();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(ids[i] as u32, hash::partition_id(k, parts), "key {k}");
        }
        // histogram sums to the unmasked count... plus padded zeros
        let hist = out[1].as_i32().unwrap();
        let total: i32 = hist.iter().sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn bloom_build_probe_roundtrip() {
        let Some(r) = registry() else { return };
        let keys: Vec<i64> = (0..50).map(|i| i * 31 + 1).collect();
        let mask = vec![1i32; 50];
        let cells = r
            .execute("bloom_build", &[Value::I64(keys.clone()), Value::I32(mask.clone())])
            .unwrap()
            .remove(0);
        // all inserted keys must probe positive
        let hits = r
            .execute(
                "bloom_probe",
                &[Value::I64(keys), Value::I32(mask), cells.clone()],
            )
            .unwrap();
        let h = hits[0].as_i32().unwrap();
        assert!(h[..50].iter().all(|&x| x == 1), "false negative in bloom");
        // disjoint keys mostly probe negative
        let other: Vec<i64> = (0..50).map(|i| 1_000_000 + i * 37).collect();
        let hits = r
            .execute(
                "bloom_probe",
                &[Value::I64(other), Value::I32(vec![1; 50]), cells],
            )
            .unwrap();
        let fp: i32 = hits[0].as_i32().unwrap()[..50].iter().sum();
        assert!(fp < 10, "false positive rate too high: {fp}/50");
    }

    #[test]
    fn bucket_preagg_sums_match_host() {
        let Some(r) = registry() else { return };
        let g = r.manifest().num_buckets as u32;
        let keys: Vec<i64> = (0..200).map(|i| i % 10).collect();
        let vals: Vec<f32> = (0..200).map(|i| i as f32).collect();
        let mask = vec![1i32; 200];
        let out = r
            .execute(
                "bucket_preagg",
                &[Value::I64(keys.clone()), Value::F32(vals.clone()), Value::I32(mask)],
            )
            .unwrap();
        let sums = out[1].as_f32().unwrap();
        let cnts = out[2].as_i32().unwrap();
        // host-side recomputation
        let mut want_sum = vec![0f32; g as usize];
        let mut want_cnt = vec![0i32; g as usize];
        for (i, &k) in keys.iter().enumerate() {
            let b = hash::bucket_id(k, g) as usize;
            want_sum[b] += vals[i];
            want_cnt[b] += 1;
        }
        // bucket 0 absorbs padding contributions of masked rows for
        // count? No: mask=0 rows contribute 0 to both sums and counts.
        for b in 0..g as usize {
            assert!((sums[b] - want_sum[b]).abs() < 1e-3, "bucket {b}");
            assert_eq!(cnts[b], want_cnt[b], "bucket {b}");
        }
    }

    #[test]
    fn executables_are_cached() {
        let Some(r) = registry() else { return };
        let before = r.compile_count();
        for _ in 0..3 {
            r.execute(
                "filter_eq_i64",
                &[Value::I64(vec![1, 2, 3]), Value::scalar_i64(2), Value::I32(vec![1; 3])],
            )
            .unwrap();
        }
        // at most one new compile for this stage
        assert!(r.compile_count() <= before + 1);
        assert!(r.execution_count() >= 3);
    }

    #[test]
    fn wrong_arity_and_dtype_rejected() {
        let Some(r) = registry() else { return };
        assert!(r.execute("filter_eq_i64", &[Value::I64(vec![1])]).is_err());
        assert!(r
            .execute(
                "filter_eq_i64",
                &[
                    Value::F32(vec![1.0]),
                    Value::scalar_i64(2),
                    Value::I32(vec![1])
                ],
            )
            .is_err());
    }

    #[test]
    fn concurrent_executions_are_safe() {
        let Some(r) = registry() else { return };
        r.warmup(&["hash_partition"]).unwrap();
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let r = r.clone();
                std::thread::spawn(move || {
                    let keys: Vec<i64> = (0..64).map(|i| i + t * 1000).collect();
                    let out = r
                        .execute(
                            "hash_partition",
                            &[Value::I64(keys.clone()), Value::I32(vec![1; 64])],
                        )
                        .unwrap();
                    let ids = out[0].as_i32().unwrap().to_vec();
                    (keys, ids)
                })
            })
            .collect();
        for h in hs {
            let (keys, ids) = h.join().unwrap();
            for (i, &k) in keys.iter().enumerate() {
                assert_eq!(ids[i] as u32, hash::partition_id(k, 16));
            }
        }
    }
}
