//! Typed values crossing the L3 → PJRT boundary.
//!
//! [`Value`] is the host-side mirror of a stage argument/result. The
//! registry turns it into an `xla::Literal` (padding to the stage's
//! static shape — HLO is fixed-shape, so the coordinator pads every
//! batch to `batch_rows` and carries the true row count in the mask,
//! §3.1) and back.

use crate::runtime::manifest::{ShapeSpec, SpecDType};
use crate::types::ColumnData;
use crate::{Error, Result};

/// A typed host buffer.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    U32(Vec<u32>),
    U64(Vec<u64>),
}

impl Value {
    pub fn dtype(&self) -> SpecDType {
        match self {
            Value::F32(_) => SpecDType::F32,
            Value::F64(_) => SpecDType::F64,
            Value::I32(_) => SpecDType::I32,
            Value::I64(_) => SpecDType::I64,
            Value::U32(_) => SpecDType::U32,
            Value::U64(_) => SpecDType::U64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::F64(v) => v.len(),
            Value::I32(v) => v.len(),
            Value::I64(v) => v.len(),
            Value::U32(v) => v.len(),
            Value::U64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len() * self.dtype().width()
    }

    /// Scalar constructors (stage parameters like filter bounds travel
    /// as 1-element arrays — see model.py's `_f32(1)` specs).
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(vec![v])
    }

    pub fn scalar_i64(v: i64) -> Value {
        Value::I64(vec![v])
    }

    /// Pad (with zeros) or reject to match `spec` exactly.
    pub fn conform(&self, spec: &ShapeSpec) -> Result<Value> {
        if self.dtype() != spec.dtype {
            return Err(Error::Plan(format!(
                "stage arg dtype mismatch: have {}, want {}",
                self.dtype().name(),
                spec.dtype.name()
            )));
        }
        let want = spec.elems();
        let have = self.len();
        if have == want {
            return Ok(self.clone());
        }
        if have > want {
            return Err(Error::Plan(format!(
                "stage arg too long: have {have}, want {want} (split the batch)"
            )));
        }
        macro_rules! pad {
            ($v:expr, $variant:ident) => {{
                let mut v = $v.clone();
                v.resize(want, Default::default());
                Value::$variant(v)
            }};
        }
        Ok(match self {
            Value::F32(v) => pad!(v, F32),
            Value::F64(v) => pad!(v, F64),
            Value::I32(v) => pad!(v, I32),
            Value::I64(v) => pad!(v, I64),
            Value::U32(v) => pad!(v, U32),
            Value::U64(v) => pad!(v, U64),
        })
    }

    /// Truncate to `n` leading elements (drop batch padding on output).
    pub fn truncate(self, n: usize) -> Value {
        macro_rules! trunc {
            ($v:expr, $variant:ident) => {{
                let mut v = $v;
                v.truncate(n);
                Value::$variant(v)
            }};
        }
        match self {
            Value::F32(v) => trunc!(v, F32),
            Value::F64(v) => trunc!(v, F64),
            Value::I32(v) => trunc!(v, I32),
            Value::I64(v) => trunc!(v, I64),
            Value::U32(v) => trunc!(v, U32),
            Value::U64(v) => trunc!(v, U64),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(v) => Ok(v),
            _ => Err(Error::internal("value is not i32")),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(v) => Ok(v),
            _ => Err(Error::internal("value is not f32")),
        }
    }

    pub fn as_i64(&self) -> Result<&[i64]> {
        match self {
            Value::I64(v) => Ok(v),
            _ => Err(Error::internal("value is not i64")),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            Value::U32(v) => Ok(v),
            _ => Err(Error::internal("value is not u32")),
        }
    }
}

/// Column → stage argument (device batches feed kernels directly).
impl From<&ColumnData> for Value {
    fn from(c: &ColumnData) -> Value {
        match c {
            ColumnData::I64(v) => Value::I64(v.clone()),
            ColumnData::F32(v) => Value::F32(v.clone()),
            ColumnData::F64(v) => Value::F64(v.clone()),
        }
    }
}

impl From<Value> for ColumnData {
    fn from(v: Value) -> ColumnData {
        match v {
            Value::I64(v) => ColumnData::I64(v),
            Value::F32(v) => ColumnData::F32(v),
            Value::F64(v) => ColumnData::F64(v),
            Value::I32(v) => ColumnData::I64(v.into_iter().map(i64::from).collect()),
            Value::U32(v) => ColumnData::I64(v.into_iter().map(i64::from).collect()),
            Value::U64(v) => ColumnData::I64(v.into_iter().map(|x| x as i64).collect()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(d: SpecDType, n: usize) -> ShapeSpec {
        ShapeSpec { dtype: d, dims: vec![n] }
    }

    #[test]
    fn conform_pads_with_zeros() {
        let v = Value::F32(vec![1.0, 2.0]);
        let c = v.conform(&spec(SpecDType::F32, 4)).unwrap();
        assert_eq!(c, Value::F32(vec![1.0, 2.0, 0.0, 0.0]));
    }

    #[test]
    fn conform_rejects_dtype_and_overflow() {
        let v = Value::I64(vec![1, 2, 3]);
        assert!(v.conform(&spec(SpecDType::F32, 4)).is_err());
        assert!(v.conform(&spec(SpecDType::I64, 2)).is_err());
        assert_eq!(v.conform(&spec(SpecDType::I64, 3)).unwrap(), v);
    }

    #[test]
    fn truncate_drops_padding() {
        let v = Value::I32(vec![1, 2, 3, 0, 0]);
        assert_eq!(v.truncate(3), Value::I32(vec![1, 2, 3]));
    }

    #[test]
    fn column_roundtrip() {
        let c = ColumnData::F32(vec![1.5, 2.5]);
        let v = Value::from(&c);
        assert_eq!(v, Value::F32(vec![1.5, 2.5]));
        assert_eq!(ColumnData::from(v), c);
    }

    #[test]
    fn i32_value_widens_to_i64_column() {
        let v = Value::I32(vec![1, -2]);
        assert_eq!(ColumnData::from(v), ColumnData::I64(vec![1, -2]));
    }

    #[test]
    fn byte_len_tracks_width() {
        assert_eq!(Value::F32(vec![0.0; 8]).byte_len(), 32);
        assert_eq!(Value::I64(vec![0; 8]).byte_len(), 64);
    }
}
