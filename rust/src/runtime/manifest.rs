//! Parser for `artifacts/manifest.tsv` — the L2→L3 contract.
//!
//! Grammar (tab-separated):
//! ```text
//! # theseus AOT manifest\tbatch_rows=8192\t... (header params)
//! <stage>\t<in>;<in>;...\t<out>;<out>;...
//! ```
//! where each I/O spec is `dtype[d0,d1,...]`, e.g. `f32[8192]`,
//! `i32[16]`, `u32[16384]`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Element dtype of a stage argument/result.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpecDType {
    F32,
    F64,
    I32,
    I64,
    U32,
    U64,
}

impl SpecDType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => SpecDType::F32,
            "f64" => SpecDType::F64,
            "i32" => SpecDType::I32,
            "i64" => SpecDType::I64,
            "u32" => SpecDType::U32,
            "u64" => SpecDType::U64,
            _ => return Err(Error::Format(format!("bad spec dtype '{s}'"))),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SpecDType::F32 => "f32",
            SpecDType::F64 => "f64",
            SpecDType::I32 => "i32",
            SpecDType::I64 => "i64",
            SpecDType::U32 => "u32",
            SpecDType::U64 => "u64",
        }
    }

    pub fn width(self) -> usize {
        match self {
            SpecDType::F32 | SpecDType::I32 | SpecDType::U32 => 4,
            _ => 8,
        }
    }
}

/// `dtype[dims]` — one argument or result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeSpec {
    pub dtype: SpecDType,
    pub dims: Vec<usize>,
}

impl ShapeSpec {
    pub fn parse(s: &str) -> Result<Self> {
        let open = s
            .find('[')
            .ok_or_else(|| Error::Format(format!("bad shape spec '{s}'")))?;
        if !s.ends_with(']') {
            return Err(Error::Format(format!("bad shape spec '{s}'")));
        }
        let dtype = SpecDType::parse(&s[..open])?;
        let dims_str = &s[open + 1..s.len() - 1];
        let dims = if dims_str.is_empty() {
            Vec::new()
        } else {
            dims_str
                .split(',')
                .map(|d| {
                    d.trim()
                        .parse::<usize>()
                        .map_err(|e| Error::Format(format!("bad dim '{d}': {e}")))
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(ShapeSpec { dtype, dims })
    }

    /// Total element count.
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elems() * self.dtype.width()
    }
}

impl std::fmt::Display for ShapeSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        write!(f, "{}[{}]", self.dtype.name(), dims.join(","))
    }
}

/// One stage's signature.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageSpec {
    pub name: String,
    pub inputs: Vec<ShapeSpec>,
    pub outputs: Vec<ShapeSpec>,
}

impl StageSpec {
    /// Path of this stage's HLO artifact under `dir`.
    pub fn hlo_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.hlo.txt", self.name))
    }
}

/// The parsed manifest: header constants + stage table.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch_rows: usize,
    pub block_rows: usize,
    pub num_parts: usize,
    pub num_buckets: usize,
    pub bloom_bits: usize,
    pub stages: BTreeMap<String, StageSpec>,
}

impl Manifest {
    /// Load `dir/manifest.tsv`.
    pub fn load(dir: impl Into<PathBuf>) -> Result<Manifest> {
        let dir = dir.into();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Config(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Locate the artifacts dir: `$THESEUS_ARTIFACTS` or `./artifacts`
    /// (walking up from cwd so tests and benches work from any subdir).
    pub fn discover() -> Result<Manifest> {
        if let Ok(d) = std::env::var("THESEUS_ARTIFACTS") {
            return Self::load(d);
        }
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.tsv").exists() {
                return Self::load(cand);
            }
            match dir.parent() {
                Some(p) => dir = p.to_path_buf(),
                None => {
                    return Err(Error::Config(
                        "no artifacts/manifest.tsv found (run `make artifacts`)".into(),
                    ))
                }
            }
        }
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut lines = text.lines();
        let header = lines
            .next()
            .ok_or_else(|| Error::Format("empty manifest".into()))?;
        if !header.starts_with('#') {
            return Err(Error::Format("manifest missing header line".into()));
        }
        let mut params: BTreeMap<&str, usize> = BTreeMap::new();
        for tok in header.split('\t').skip(1) {
            if let Some((k, v)) = tok.split_once('=') {
                let v = v
                    .trim()
                    .parse::<usize>()
                    .map_err(|e| Error::Format(format!("header param {k}: {e}")))?;
                params.insert(k, v);
            }
        }
        let need = |k: &str| -> Result<usize> {
            params
                .get(k)
                .copied()
                .ok_or_else(|| Error::Format(format!("manifest header missing {k}")))
        };

        let mut stages = BTreeMap::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() || line.starts_with('#') {
                continue;
            }
            let mut cols = line.split('\t');
            let (name, ins, outs) = match (cols.next(), cols.next(), cols.next()) {
                (Some(a), Some(b), Some(c)) => (a, b, c),
                _ => {
                    return Err(Error::Format(format!(
                        "manifest line {} malformed: '{line}'",
                        i + 2
                    )))
                }
            };
            let parse_list = |s: &str| -> Result<Vec<ShapeSpec>> {
                s.split(';')
                    .filter(|t| !t.is_empty())
                    .map(ShapeSpec::parse)
                    .collect()
            };
            stages.insert(
                name.to_string(),
                StageSpec {
                    name: name.to_string(),
                    inputs: parse_list(ins)?,
                    outputs: parse_list(outs)?,
                },
            );
        }
        Ok(Manifest {
            dir,
            batch_rows: need("batch_rows")?,
            block_rows: need("block_rows")?,
            num_parts: need("num_parts")?,
            num_buckets: need("num_buckets")?,
            bloom_bits: need("bloom_bits")?,
            stages,
        })
    }

    pub fn stage(&self, name: &str) -> Result<&StageSpec> {
        self.stages
            .get(name)
            .ok_or_else(|| Error::Plan(format!("no AOT stage named '{name}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# theseus AOT manifest\tbatch_rows=8192\tblock_rows=1024\tnum_parts=16\tnum_buckets=1024\tbloom_bits=16384\n\
        filter_range_f32\tf32[8192];f32[1];f32[1];i32[8192]\ti32[8192]\n\
        hash_partition\ti64[8192];i32[8192]\ti32[8192];i32[16]\n";

    #[test]
    fn parses_header_and_stages() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.batch_rows, 8192);
        assert_eq!(m.num_parts, 16);
        assert_eq!(m.stages.len(), 2);
        let s = m.stage("hash_partition").unwrap();
        assert_eq!(s.inputs.len(), 2);
        assert_eq!(s.outputs[1].dims, vec![16]);
        assert_eq!(s.outputs[1].dtype, SpecDType::I32);
    }

    #[test]
    fn shape_spec_grammar() {
        let s = ShapeSpec::parse("f32[8192]").unwrap();
        assert_eq!(s.elems(), 8192);
        assert_eq!(s.byte_len(), 8192 * 4);
        let s = ShapeSpec::parse("i64[4,8]").unwrap();
        assert_eq!(s.dims, vec![4, 8]);
        assert_eq!(s.elems(), 32);
        let s = ShapeSpec::parse("u32[]").unwrap();
        assert_eq!(s.elems(), 1); // scalar: empty product = 1
        assert!(ShapeSpec::parse("f32").is_err());
        assert!(ShapeSpec::parse("q8[4]").is_err());
        assert!(ShapeSpec::parse("f32[x]").is_err());
    }

    #[test]
    fn display_roundtrips() {
        for s in ["f32[8192]", "i32[16]", "i64[4,8]"] {
            assert_eq!(ShapeSpec::parse(s).unwrap().to_string(), s);
        }
    }

    #[test]
    fn missing_stage_is_plan_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m.stage("nope").is_err());
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(Manifest::parse("", PathBuf::new()).is_err());
        assert!(Manifest::parse("no header\n", PathBuf::new()).is_err());
        let bad = "# m\tbatch_rows=1\tblock_rows=1\tnum_parts=1\tnum_buckets=1\tbloom_bits=1\nonly_name\n";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn real_manifest_loads() {
        // Only runs when artifacts exist (after `make artifacts`).
        if let Ok(m) = Manifest::discover() {
            assert!(m.stages.contains_key("filter_range_f32"));
            assert!(m.stages.contains_key("bucket_preagg"));
            for s in m.stages.values() {
                assert!(s.hlo_path(&m.dir).exists(), "{} artifact missing", s.name);
            }
        }
    }
}
