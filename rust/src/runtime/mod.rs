//! PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! This is the L3 ↔ L2 bridge. Python never runs at query time: the
//! artifacts directory is the *only* interface between the layers —
//! `manifest.tsv` describes every stage's I/O signature and the global
//! shape constants (batch rows, partition fanout, ...), and each
//! `<stage>.hlo.txt` is an HLO-text module compiled once per process by
//! [`KernelRegistry`] on the PJRT CPU client (`xla` crate).
//!
//! HLO *text* — not a serialized `HloModuleProto` — is the interchange
//! format because jax ≥ 0.5 emits protos with 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod manifest;
pub mod pjrt_shim;
pub mod registry;
pub mod stage;

pub use manifest::{Manifest, ShapeSpec, SpecDType, StageSpec};
pub use registry::KernelRegistry;
pub use stage::Value;
