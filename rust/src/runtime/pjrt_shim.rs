//! Seam between the engine and the optional `xla` crate.
//!
//! With the `pjrt` cargo feature enabled this module re-exports the
//! real `xla` types and the registry runs the AOT HLO artifacts on the
//! PJRT CPU client. Without it (the default — the xla_extension shared
//! library is a heavyweight native build), the same names resolve to
//! the stubs below: [`PjRtClient::cpu`] fails with a descriptive error,
//! [`crate::runtime::KernelRegistry::shared`] surfaces that as
//! `Error::Xla`, and every operator takes its host fallback path —
//! exactly what `registry: None` callers (the whole test suite) do.

#[cfg(feature = "pjrt")]
pub use xla::*;

#[cfg(not(feature = "pjrt"))]
pub use stub::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    /// Mirror of `xla::Error` (message-only).
    #[derive(Debug)]
    pub struct Error(pub String);

    impl std::fmt::Display for Error {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str(&self.0)
        }
    }

    impl std::error::Error for Error {}

    fn unavailable() -> Error {
        Error(
            "PJRT support not compiled in: rebuild with `--features pjrt` \
             (requires the xla_extension library)"
                .into(),
        )
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            Err(unavailable())
        }

        pub fn compile(
            &self,
            _computation: &XlaComputation,
        ) -> Result<PjRtLoadedExecutable, Error> {
            Err(unavailable())
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            Err(unavailable())
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            Err(unavailable())
        }
    }

    #[derive(Clone)]
    pub struct Literal;

    impl Literal {
        pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            Err(unavailable())
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            Err(unavailable())
        }

        pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
            Err(unavailable())
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            Err(unavailable())
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }
}
