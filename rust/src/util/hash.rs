//! SplitMix64 — bit-for-bit identical to the L1 Pallas kernel
//! (`python/compile/kernels/hashing.py`) and the numpy oracle, so every
//! layer agrees on partition/bucket/bloom decisions.

pub const SPLITMIX_C0: u64 = 0x9E37_79B9_7F4A_7C15;
pub const SPLITMIX_C1: u64 = 0xBF58_476D_1CE4_E5B9;
pub const SPLITMIX_C2: u64 = 0x94D0_49BB_1331_11EB;
pub const SECOND_HASH_SEED: u64 = 0xA24B_AED4_963E_E407;

/// SplitMix64 finalizer.
#[inline(always)]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(SPLITMIX_C0);
    z = (z ^ (z >> 30)).wrapping_mul(SPLITMIX_C1);
    z = (z ^ (z >> 27)).wrapping_mul(SPLITMIX_C2);
    z ^ (z >> 31)
}

/// Exchange partition id (low hash bits); `parts` must be a power of two.
#[inline(always)]
pub fn partition_id(key: i64, parts: u32) -> u32 {
    debug_assert!(parts.is_power_of_two());
    (splitmix64(key as u64) & (parts as u64 - 1)) as u32
}

/// Aggregation/join bucket id (high hash bits; independent of partition
/// bits — see kernels/hashing.py).
#[inline(always)]
pub fn bucket_id(key: i64, buckets: u32) -> u32 {
    debug_assert!(buckets.is_power_of_two());
    ((splitmix64(key as u64) >> 32) & (buckets as u64 - 1)) as u32
}

/// Double-hash lanes for the bloom filter.
#[inline(always)]
pub fn bloom_lanes(key: i64, bits: u64) -> (usize, usize) {
    let h1 = splitmix64(key as u64);
    let h2 = splitmix64(key as u64 ^ SECOND_HASH_SEED);
    ((h1 % bits) as usize, (h2 % bits) as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden vectors cross-checked against the numpy oracle:
    /// `ref.splitmix64(np.uint64([0,1,2**63]))`.
    #[test]
    fn splitmix64_golden() {
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(1), 0x910A_2DEC_8902_5CC1);
    }

    #[test]
    fn partition_in_range_and_balanced() {
        let parts = 16u32;
        let mut counts = vec![0usize; parts as usize];
        let n = 1 << 14;
        for k in 0..n {
            let p = partition_id(k, parts);
            assert!(p < parts);
            counts[p as usize] += 1;
        }
        let ideal = n as usize / parts as usize;
        for &c in &counts {
            assert!(c > ideal * 8 / 10 && c < ideal * 12 / 10, "skew: {c} vs {ideal}");
        }
    }

    #[test]
    fn bucket_independent_of_partition() {
        // keys that collide on partition must not systematically collide
        // on bucket.
        let parts = 16;
        let buckets = 1024;
        let same_part: Vec<i64> =
            (0..100_000).filter(|&k| partition_id(k, parts) == 3).collect();
        let mut seen = std::collections::HashSet::new();
        for &k in same_part.iter().take(500) {
            seen.insert(bucket_id(k, buckets));
        }
        assert!(seen.len() > 300, "bucket ids collapsed: {}", seen.len());
    }

    #[test]
    fn bloom_lanes_in_range() {
        for k in -1000..1000 {
            let (a, b) = bloom_lanes(k, 16384);
            assert!(a < 16384 && b < 16384);
        }
    }
}
