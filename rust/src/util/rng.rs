//! Deterministic PRNG for data generation and simulation jitter
//! (xoshiro256** seeded via SplitMix64 — no external `rand` needed).

use super::hash::splitmix64;

/// xoshiro256** — fast, high-quality, reproducible across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion, per Vigna's recommendation.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            splitmix64(sm.wrapping_sub(0x9E37_79B9_7F4A_7C15))
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive, for i64 workload keys.
    #[inline]
    pub fn gen_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.gen_range((hi - lo) as u64 + 1) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn gen_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.gen_f64() as f32) * (hi - lo)
    }

    /// True with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Zipf-like skewed index in `[0, n)` with exponent `theta` in (0,1);
    /// used for skewed join keys (TPC-DS-lite) and adversarial tests.
    pub fn gen_zipf(&mut self, n: u64, theta: f64) -> u64 {
        // Approximate inverse-CDF sampling: rank ~ u^(1/(1-theta)).
        let u = self.gen_f64().max(1e-12);
        let r = (u.powf(1.0 / (1.0 - theta)) * n as f64) as u64;
        r.min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.gen_range(7);
            assert!(v < 7);
            let x = r.gen_i64(-5, 5);
            assert!((-5..=5).contains(&x));
            let f = r.gen_f32(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
        }
    }

    #[test]
    fn gen_f64_unit_interval_mean() {
        let mut r = Rng::new(9);
        let mean: f64 = (0..10_000).map(|_| r.gen_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn zipf_is_skewed_toward_zero() {
        let mut r = Rng::new(5);
        let lows = (0..10_000).filter(|_| r.gen_zipf(1000, 0.5) < 100).count();
        assert!(lows > 2_000, "zipf not skewed: {lows}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
