//! Little-endian binary encode/decode helpers — the hand-rolled wire
//! grammar shared by the columnar file format (`storage::format`), spill
//! files, and network frames (no serde available offline; a fixed
//! explicit wire format is also what the paper's IPC needs anyway).

use crate::{Error, Result};

/// Append-only encoder.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Writer { buf: Vec::with_capacity(n) }
    }

    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    /// Raw bytes, no prefix (caller knows the length).
    pub fn raw(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }
}

/// Cursor-based decoder with bounds checking.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

macro_rules! read_prim {
    ($name:ident, $ty:ty) => {
        #[inline]
        pub fn $name(&mut self) -> Result<$ty> {
            const N: usize = std::mem::size_of::<$ty>();
            let b = self.take(N)?;
            Ok(<$ty>::from_le_bytes(b.try_into().unwrap()))
        }
    };
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    #[inline]
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Format(format!(
                "truncated: need {} bytes at offset {}, have {}",
                n,
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    read_prim!(u16, u16);
    read_prim!(u32, u32);
    read_prim!(u64, u64);
    read_prim!(i64, i64);
    read_prim!(f32, f32);
    read_prim!(f64, f64);

    #[inline]
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|e| Error::Format(format!("bad utf8: {e}")))
    }

    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u64()? as usize;
        self.take(n)
    }

    pub fn raw(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn seek(&mut self, pos: usize) -> Result<()> {
        if pos > self.buf.len() {
            return Err(Error::Format(format!("seek {} past end {}", pos, self.buf.len())));
        }
        self.pos = pos;
        Ok(())
    }
}

/// Reinterpret a typed slice as raw little-endian bytes (native LE only;
/// we target x86-64/aarch64-LE, asserted at build time below).
pub fn as_bytes<T: Copy>(xs: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs))
    }
}

/// Reinterpret raw bytes back to a typed vec (copies; alignment-safe).
pub fn from_bytes<T: Copy>(b: &[u8]) -> Result<Vec<T>> {
    let sz = std::mem::size_of::<T>();
    if b.len() % sz != 0 {
        return Err(Error::Format(format!(
            "byte length {} not a multiple of element size {}",
            b.len(),
            sz
        )));
    }
    let n = b.len() / sz;
    let mut v = Vec::<T>::with_capacity(n);
    unsafe {
        std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr() as *mut u8, b.len());
        v.set_len(n);
    }
    Ok(v)
}

#[cfg(target_endian = "big")]
compile_error!("theseus assumes a little-endian target");

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut w = Writer::new();
        w.u8(7);
        w.u16(300);
        w.u32(70_000);
        w.u64(1 << 40);
        w.i64(-42);
        w.f32(1.5);
        w.f64(-2.25);
        w.str("theseus");
        w.bytes(&[1, 2, 3]);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 300);
        assert_eq!(r.u32().unwrap(), 70_000);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.i64().unwrap(), -42);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.str().unwrap(), "theseus");
        assert_eq!(r.bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let buf = [1u8, 2];
        let mut r = Reader::new(&buf);
        assert!(r.u64().is_err());
    }

    #[test]
    fn typed_slice_roundtrip() {
        let xs: Vec<i64> = vec![-1, 0, 1, i64::MAX];
        let b = as_bytes(&xs);
        assert_eq!(b.len(), 32);
        let back: Vec<i64> = from_bytes(b).unwrap();
        assert_eq!(back, xs);
        let f: Vec<f32> = vec![1.0, -2.5];
        assert_eq!(from_bytes::<f32>(as_bytes(&f)).unwrap(), f);
    }

    #[test]
    fn from_bytes_misaligned_length_rejected() {
        assert!(from_bytes::<i64>(&[0u8; 7]).is_err());
    }
}
