//! Small shared utilities: hashing, PRNG, byte encoding, human sizes.

pub mod bytes;
pub mod hash;
pub mod rng;

/// Format a byte count for logs ("1.50 GiB").
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Integer ceiling division.
pub fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Round `n` up to a multiple of `align`.
pub fn align_up(n: usize, align: usize) -> usize {
    div_ceil(n, align) * align
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn align_and_ceil() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(align_up(10, 8), 16);
        assert_eq!(align_up(16, 8), 16);
        assert_eq!(align_up(0, 8), 0);
    }
}
