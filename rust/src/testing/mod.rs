//! Minimal property-testing harness (DESIGN.md substitution #5: no
//! `proptest` offline): generate random cases from a seeded RNG, run
//! the property, and on failure *shrink* the case toward a minimal
//! reproduction before panicking with the seed.

use crate::util::rng::Rng;

/// A shrinkable case.
pub trait Shrink: Clone + std::fmt::Debug {
    /// Candidate smaller versions (tried in order; empty = atomic).
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<i64> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
        }
        if *self < 0 {
            out.push(-self);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        if *self == 0 {
            Vec::new()
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        // halves first, then drop-one, then element-wise shrink of slot 0
        // (every candidate must be strictly "smaller": shorter, or same
        // length with a shrunk element — never the original itself)
        out.push(self[..n / 2].to_vec());
        if n / 2 > 0 {
            out.push(self[n / 2..].to_vec());
        }
        if n > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        if let Some(first) = self.first() {
            for s in first.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[0] = s;
                out.push(v);
            }
        }
        out
    }
}

/// Run `prop` over `iters` random cases from `gen`; shrink failures.
///
/// Panics with the seed and the minimal failing case.
pub fn check<T, G, P>(seed: u64, iters: usize, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for i in 0..iters {
        let case = gen(&mut rng);
        if prop(&case) {
            continue;
        }
        // shrink loop
        let mut minimal = case;
        'outer: loop {
            for cand in minimal.shrink() {
                if !prop(&cand) {
                    minimal = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property failed (seed {seed}, iteration {i});\nminimal case: {minimal:?}"
        );
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng;

    pub fn i64_vec(rng: &mut Rng, max_len: usize, lo: i64, hi: i64) -> Vec<i64> {
        let n = rng.gen_range(max_len as u64 + 1) as usize;
        (0..n).map(|_| rng.gen_i64(lo, hi)).collect()
    }

    pub fn f32_vec(rng: &mut Rng, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = rng.gen_range(max_len as u64 + 1) as usize;
        (0..n).map(|_| rng.gen_f32(lo, hi)).collect()
    }

    pub fn bytes(rng: &mut Rng, max_len: usize) -> Vec<u8> {
        let n = rng.gen_range(max_len as u64 + 1) as usize;
        (0..n).map(|_| rng.next_u64() as u8).collect()
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl Shrink for u8 {
    fn shrink(&self) -> Vec<u8> {
        if *self == 0 {
            Vec::new()
        } else {
            vec![0, self / 2]
        }
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<f32> {
        if *self == 0.0 {
            Vec::new()
        } else {
            vec![0.0, self / 2.0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        check(1, 200, |rng| gen::i64_vec(rng, 32, -100, 100), |v| {
            v.iter().all(|&x| (-100..=100).contains(&x))
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let r = std::panic::catch_unwind(|| {
            check(
                2,
                200,
                |rng| gen::i64_vec(rng, 64, 0, 1000),
                // fails whenever the vec contains a value >= 500
                |v| v.iter().all(|&x| x < 500),
            );
        });
        let msg = match r {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // the minimal case is a single offending element
        assert!(msg.contains("minimal case"), "{msg}");
        let after = msg.split("minimal case:").nth(1).unwrap();
        let count = after.matches(',').count();
        assert!(count <= 1, "not shrunk enough: {after}");
    }

    #[test]
    fn shrink_pairs_shrinks_each_side() {
        let p = (6i64, vec![1u8, 2]);
        let cands = p.shrink();
        assert!(cands.iter().any(|(a, b)| *a != 6 && *b == vec![1, 2]));
        assert!(cands.iter().any(|(a, b)| *a == 6 && b.len() < 2));
    }

    #[test]
    fn shrink_vec_produces_smaller_candidates() {
        let v = vec![5i64, 6, 7, 8];
        for s in v.shrink() {
            assert!(s.len() < v.len() || s.iter().zip(&v).any(|(a, b)| a != b));
        }
    }
}
