//! Cluster runtime (§3): Client, Gateway (+Planner), and Workers.
//!
//! "A Theseus cluster has four core components: a Client, a Gateway, a
//! Planner (based on Apache Calcite), and Workers. ... When the client
//! submits a query, the planner creates the query plan, and then every
//! worker receives the same physical execution plan with a different
//! subset of files to scan."
//!
//! [`worker::Worker`] is the §3.3 worker process: four executors around
//! one device; [`client::Cluster`] launches N of them over a shared
//! fabric; [`client::Gateway`] plans and submits queries;
//! [`client::Client`] is the user-facing handle.

pub mod client;
pub mod session;
pub mod worker;

pub use client::{Client, Cluster, Gateway, QueryResult, WorkerStats};
pub use session::{
    AdmissionController, AdmissionGrant, AdmissionQueue, QuerySession, SessionOpts,
};
pub use worker::Worker;
