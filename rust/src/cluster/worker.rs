//! One Theseus worker: the four executors (§3.3) wired around a device
//! arena, a pinned pool, a spill store, a datasource, and a fabric
//! endpoint. The worker's driver loop polls the query DAG for ready
//! tasks and feeds the Compute Executor until the DAG completes.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{DatasourceKind, WorkerConfig};
use crate::exec::{PhysicalPlan, QueryDag, WorkerCtx};
use crate::executors::compute::{ComputeExecutor, ResidencyBonus, TaskQueue};
use crate::executors::movement::{DataMovementExecutor, HolderRegistry, MovementConfig};
use crate::executors::network::{NetworkExecutor, Outbox, Router};
use crate::executors::preload::PreloadExecutor;
use crate::memory::batch_holder::MemEnv;
use crate::memory::{DeviceArena, MemoryGovernor, PinnedPool, SpillStore};
use crate::network::Endpoint;
use crate::runtime::KernelRegistry;
use crate::sim::SimContext;
use crate::storage::datasource::{CustomObjectStoreDatasource, Datasource, GenericDatasource};
use crate::storage::object_store::ObjectStore;
use crate::types::RecordBatch;
use crate::{Error, Result};

use super::client::WorkerStats;

pub struct Worker {
    pub ctx: WorkerCtx,
    pub queue: Arc<TaskQueue>,
    pub compute: Arc<ComputeExecutor>,
    /// The unified spill + promotion plane (§3.3.2 + §3.3.3's
    /// Compute-Task Pre-loading).
    pub movement: Arc<DataMovementExecutor>,
    /// Byte-Range Pre-loading only (§3.3.3).
    pub preload: Arc<PreloadExecutor>,
    pub network: Arc<NetworkExecutor>,
    pub router: Arc<Router>,
    pub holders: Arc<HolderRegistry>,
    stopped: AtomicBool,
    /// Test hook: makes the next `run_query` panic, exercising the
    /// gateway's worker-panic containment path.
    inject_panic: AtomicBool,
}

impl Worker {
    /// Bring up a worker over `endpoint`. `registry = None` uses host
    /// fallbacks for device stages (tests); real deployments pass the
    /// shared AOT registry.
    pub fn start(
        worker_id: usize,
        config: Arc<WorkerConfig>,
        store: Arc<dyn ObjectStore>,
        endpoint: Arc<dyn Endpoint>,
        registry: Option<KernelRegistry>,
    ) -> Result<Arc<Worker>> {
        config.validate()?;
        let sim = SimContext::new(config.profile.clone(), config.time_scale);

        // ---- memory tiers
        let arena = DeviceArena::new(config.device_capacity);
        let pinned = if config.pinned_pool {
            Some(PinnedPool::new(config.pinned_buf_size, config.pinned_buffers)?)
        } else {
            None
        };
        let env = MemEnv {
            arena: arena.clone(),
            pinned: pinned.clone(),
            spill: Arc::new(SpillStore::temp_with(
                &format!("w{worker_id}"),
                config.spill_segment_bytes,
            )?),
            pcie: sim.throttle(&sim.profile.pcie),
            disk: sim.throttle(&crate::sim::LinkSpec::new(30, 2 * crate::sim::GIB)),
            pageable_penalty: sim.profile.pageable_penalty,
            spill_codec: config.spill_codec,
            demotions: Arc::new(std::sync::atomic::AtomicU64::new(0)),
        };
        let governor = MemoryGovernor::new(arena.clone());
        let metrics = Arc::new(crate::metrics::Metrics::default());

        // ---- datasource. The retry policy must be set before the
        // concrete value is Arc-shared (`set_retry_policy` needs `&mut`).
        let retry = crate::fault::RetryPolicy {
            limit: config.storage_retry_limit,
            base_ms: config.storage_backoff_base_ms,
        };
        let (datasource, custom): (Arc<dyn Datasource>, Option<Arc<CustomObjectStoreDatasource>>) =
            match config.datasource {
                DatasourceKind::Generic => {
                    let mut g = GenericDatasource::new(store.clone());
                    g.set_retry_policy(retry);
                    g.install_metrics(metrics.clone());
                    (Arc::new(g), None)
                }
                DatasourceKind::Custom => {
                    let mut c = CustomObjectStoreDatasource::new(
                        store.clone(),
                        config.coalesce_gap,
                        pinned.clone(),
                    );
                    c.set_retry_policy(retry);
                    c.install_metrics(metrics.clone());
                    let c = Arc::new(c);
                    (c.clone(), Some(c))
                }
            };

        // ---- network executor. The pinned pool doubles as the network
        // bounce buffer (§3.4): sends stage/pass (or compress into)
        // slabs for vectored writes, the endpoint's readers land
        // payloads in the pool, and the router decompresses compressed
        // payloads back into it.
        let outbox = Arc::new(Outbox::new(128));
        // credit-based backpressure (§3.3): senders start with the
        // configured per-destination window; receivers return credits
        // as consumers drain, so a slow peer throttles this worker's
        // lanes instead of ballooning the outbox
        outbox.enable_credits(config.exchange_initial_credits);
        outbox.install_metrics(metrics.clone());
        let router = Arc::new(Router::new());
        router.install_metrics(metrics.clone());
        if let Some(pool) = &pinned {
            endpoint.install_recv_pool(pool.clone());
            router.install_bounce_pool(pool.clone());
        }
        let network = NetworkExecutor::start(
            endpoint,
            outbox.clone(),
            router.clone(),
            config.net_compression,
            pinned.clone(),
            config.network_threads,
        );

        // ---- compute executor
        let ctx = WorkerCtx {
            worker_id,
            config: config.clone(),
            env,
            governor: governor.clone(),
            registry,
            datasource: datasource.clone(),
            store,
            outbox,
            device_compute: sim.throttle(&sim.profile.device_compute),
            metrics,
        };
        // Residency-aware ordering (§3.3.1): the queue scores tasks by
        // where their input holders' bytes live; the movement executor
        // below feeds it ResidencyChanged notifications. All-zero bonus
        // knobs (the default) make this a plain priority+FIFO queue.
        let queue = TaskQueue::with_residency(
            ResidencyBonus {
                device_bonus: config.residency_bonus_device,
                spilled_penalty: config.residency_penalty_spilled,
                rerank_batch: config.residency_rerank_batch,
            },
            ctx.metrics.clone(),
        );
        let compute = ComputeExecutor::start(ctx.clone(), queue.clone(), config.compute_threads);

        // ---- data-movement executor: installs the shared pressure
        // event into the arena, pinned pool, governor, and queue, so
        // spills and promotions are event-driven (§3.3.2/§3.3.3)
        let holders = HolderRegistry::new();
        let movement = DataMovementExecutor::start(
            holders.clone(),
            ctx.env.clone(),
            governor,
            queue.clone(),
            MovementConfig {
                threads: config.memory_threads,
                spill_watermark: config.spill_watermark,
                promote_watermark: config.promote_watermark,
                urgency_reservation: config.urgency_reservation,
                urgency_watermark: config.urgency_watermark,
                promote_enabled: config.task_preload,
            },
            ctx.metrics.clone(),
        );

        // ---- pre-load executor (byte-range staging only)
        let preload = PreloadExecutor::start(
            queue.clone(),
            custom,
            config.byte_range_preload,
            config.preload_threads,
        );

        Ok(Arc::new(Worker {
            ctx,
            queue,
            compute,
            movement,
            preload,
            network,
            router,
            holders,
            stopped: AtomicBool::new(false),
            inject_panic: AtomicBool::new(false),
        }))
    }

    /// Execute `plan`; returns this worker's share of the result plus
    /// this query's statistics. The driver loop is the paper's
    /// Operator-polling: ready tasks go to the Compute Executor's
    /// priority queue; the other three executors work the same queue
    /// from their own angles.
    ///
    /// Multi-query safe: every counter in the returned [`WorkerStats`]
    /// is scoped to `query_id` (the earlier snapshot/delta scheme read
    /// worker-lifetime totals, so two overlapping queries each counted
    /// the other's work), `weight` scales this query's residency bonus
    /// and promotion urgency, and cleanup removes only this query's
    /// holders and counters instead of resetting the whole worker.
    pub fn run_query(
        &self,
        plan: &PhysicalPlan,
        query_id: u64,
        weight: i64,
        timeout: Duration,
    ) -> Result<(RecordBatch, WorkerStats)> {
        if self.inject_panic.swap(false, Ordering::Relaxed) {
            panic!(
                "injected worker panic (worker {} query {query_id})",
                self.ctx.worker_id
            );
        }
        // Per-query environment: a fresh demotion counter, so spills
        // are attributed to the holders this query's DAG builds (the
        // only increment paths go through holder envs), not to the
        // worker lifetime.
        let mut qctx = self.ctx.clone();
        qctx.env.demotions = Arc::new(AtomicU64::new(0));
        let res = self.drive(plan, &qctx, query_id, weight, timeout);
        let stats = self.query_stats(&qctx, query_id);
        self.clear_query(query_id);
        res.map(|batch| (batch, stats))
    }

    fn drive(
        &self,
        plan: &PhysicalPlan,
        qctx: &WorkerCtx,
        query_id: u64,
        weight: i64,
        timeout: Duration,
    ) -> Result<RecordBatch> {
        let dag = QueryDag::build(plan, qctx, &self.router, &self.holders, query_id)?;
        let deadline = Instant::now() + timeout;
        loop {
            if self.stopped.load(Ordering::Relaxed) {
                return Err(Error::Shutdown);
            }
            if let Some(e) = self.compute.take_failure_for(query_id) {
                return Err(e);
            }
            let tasks = dag.poll(qctx)?;
            let had_tasks = !tasks.is_empty();
            for t in tasks {
                self.queue.submit(t.with_query(query_id, weight));
            }
            if dag.all_done() && self.queue.quiescent() {
                // drain the root holder into the result
                let mut parts = Vec::new();
                while let Some(db) = dag.output.pop_device()? {
                    parts.push(db.batch.clone());
                }
                return RecordBatch::concat(&parts);
            }
            if Instant::now() >= deadline {
                return Err(Error::internal(format!(
                    "query {query_id} timed out on worker {} (queue {} in-flight {})",
                    self.ctx.worker_id,
                    self.queue.len(),
                    self.queue.in_flight(),
                )));
            }
            if !had_tasks {
                std::thread::sleep(Duration::from_micros(500));
            }
        }
    }

    /// Assemble this query's statistics from the per-qid counter
    /// scopes. `device_peak_bytes` stays a worker-level gauge — the
    /// arena high-water mark is shared by design.
    fn query_stats(&self, qctx: &WorkerCtx, query_id: u64) -> WorkerStats {
        let (pre, wire, compress_time) = self.network.query_net((query_id % 65536) as u16);
        WorkerStats {
            worker_id: self.ctx.worker_id,
            tasks_executed: self.compute.executed_for(query_id),
            task_retries: self.compute.retries_for(query_id),
            spills: qctx.env.demotions(),
            spilled_bytes: self.movement.spilled_bytes_for(query_id),
            preload_byte_ranges: self.preload.loads_for(query_id),
            preload_promotions: self.movement.promotions_for(query_id),
            net_bytes_precompress: pre,
            net_bytes_wire: wire,
            compress_time,
            device_peak_bytes: self.ctx.env.arena.peak(),
        }
    }

    /// Drop one finished query's counter scopes and any holders its
    /// DAG left registered. Other in-flight queries are untouched —
    /// this replaces the old cluster-wide `reset()` that cleared every
    /// query's holders between runs. Idempotent: the gateway's
    /// `QueryScope` guard calls it again on every exit path (including
    /// worker panics, where `run_query` never reaches its own cleanup).
    pub(crate) fn clear_query(&self, query_id: u64) {
        self.compute.clear_query(query_id);
        self.movement.clear_query(query_id);
        self.preload.clear_query(query_id);
        self.network.clear_query((query_id % 65536) as u16);
        self.holders.clear_query(query_id);
    }

    /// Make the next `run_query` on this worker panic (regression
    /// harness for gateway panic containment).
    #[doc(hidden)]
    pub fn inject_panic_next(&self) {
        self.inject_panic.store(true, Ordering::Relaxed);
    }

    pub fn stop(&self) {
        if self.stopped.swap(true, Ordering::Relaxed) {
            return;
        }
        self.compute.stop();
        self.preload.stop();
        self.movement.stop();
        self.network.stop();
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.stop();
    }
}
