//! Cluster launcher, Gateway, and Client (§3).
//!
//! [`Cluster::launch`] brings up N workers in one process over the
//! configured fabric (in-proc channels or real loopback TCP, both
//! shaped by the profile's link specs). [`Gateway`] plans logical
//! queries and submits the physical plan to every worker — "every
//! worker receives the same physical execution plan with a different
//! subset of files to scan" — then gathers and merges worker outputs
//! for the [`Client`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cache::{canonicalize, CanonicalKey, ServingCache};
use crate::config::{TransportKind, WorkerConfig};
use crate::exec::operators::sort::sort_batch;
use crate::exec::plan::OpSpec;
use crate::exec::PhysicalPlan;
use crate::metrics::Metrics;
use crate::network::{Endpoint, InprocHub, TcpCluster};
use crate::planner::{gather_mode, GatherMode, Logical, Planner};
use crate::runtime::KernelRegistry;
use crate::sim::SimContext;
use crate::storage::object_store::ObjectStore;
use crate::types::RecordBatch;
use crate::{Error, Result};

use super::session::{AdmissionController, AdmissionGrant, SessionOpts};
use super::worker::Worker;

/// Per-worker post-query statistics (bench reporting).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub worker_id: usize,
    pub tasks_executed: u64,
    pub task_retries: u64,
    pub spills: u64,
    pub spilled_bytes: u64,
    pub preload_byte_ranges: u64,
    pub preload_promotions: u64,
    pub net_bytes_precompress: u64,
    pub net_bytes_wire: u64,
    pub compress_time: Duration,
    pub device_peak_bytes: usize,
}

/// A completed query.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub batch: RecordBatch,
    pub elapsed: Duration,
    pub worker_stats: Vec<WorkerStats>,
}

impl QueryResult {
    pub fn total_spills(&self) -> u64 {
        self.worker_stats.iter().map(|s| s.spills).sum()
    }

    pub fn total_wire_bytes(&self) -> u64 {
        self.worker_stats.iter().map(|s| s.net_bytes_wire).sum()
    }
}

/// N workers over one fabric.
pub struct Cluster {
    pub workers: Vec<Arc<Worker>>,
    query_seq: AtomicU64,
    pub config: Arc<WorkerConfig>,
    /// The store the cluster reads — the gateway's serving cache
    /// validates entries against its mutation clock.
    pub store: Arc<dyn ObjectStore>,
    /// Cluster-level metrics (admission, panic containment) — distinct
    /// from the per-worker registries inside each [`Worker`].
    pub metrics: Arc<Metrics>,
}

impl Cluster {
    /// Launch `config.num_workers` workers over `store`.
    ///
    /// `registry = None` uses host fallbacks (unit tests); pass
    /// `Some(KernelRegistry::shared()?)` for the AOT device path.
    pub fn launch(
        config: WorkerConfig,
        store: Arc<dyn ObjectStore>,
        registry: Option<KernelRegistry>,
    ) -> Result<Cluster> {
        config.validate()?;
        let config = Arc::new(config);
        let n = config.num_workers;
        // compile every AOT stage up front (engine-init time, not query
        // time — the paper's workers initialize kernels at startup)
        if let Some(r) = &registry {
            r.warmup_all()?;
        }
        let sim = SimContext::new(config.profile.clone(), config.time_scale);

        let endpoints: Vec<Arc<dyn Endpoint>> = match config.transport {
            TransportKind::Tcp => TcpCluster::listen_with_limit(
                n,
                &sim,
                TransportKind::Tcp,
                config.max_frame_bytes,
            )?
                .into_endpoints()
                .into_iter()
                .map(|e| Arc::new(e) as Arc<dyn Endpoint>)
                .collect(),
            kind => {
                let hub = InprocHub::new(n, &sim, kind);
                hub.endpoints()
                    .into_iter()
                    .map(|e| Arc::new(e) as Arc<dyn Endpoint>)
                    .collect()
            }
        };

        let workers = endpoints
            .into_iter()
            .enumerate()
            .map(|(id, ep)| {
                Worker::start(id, config.clone(), store.clone(), ep, registry.clone())
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Cluster {
            workers,
            query_seq: AtomicU64::new(1),
            config,
            store,
            metrics: Arc::new(Metrics::default()),
        })
    }

    /// Run one physical plan across all workers; gather per `mode`.
    pub fn run_plan(
        &self,
        plan: &PhysicalPlan,
        timeout: Duration,
    ) -> Result<QueryResult> {
        self.run_plan_weighted(plan, timeout, 1)
    }

    /// [`run_plan`](Cluster::run_plan) with a session weight that
    /// scales this query's residency bonus and promotion urgency on
    /// every worker. Safe to call concurrently: each invocation gets
    /// its own query id, and all statistics are per-qid on the
    /// workers, so overlapping queries never read each other's
    /// counters.
    pub fn run_plan_weighted(
        &self,
        plan: &PhysicalPlan,
        timeout: Duration,
        weight: i64,
    ) -> Result<QueryResult> {
        let qid = self.query_seq.fetch_add(1, Ordering::Relaxed);
        // Every exit from this function — success, worker error, worker
        // panic, or a panic in the gather below — runs the scope's Drop,
        // which clears per-qid state (scheduler stats, exchange channels,
        // governor reservations) on every worker. `clear_query` is
        // idempotent, so the double-clear on the success path (workers
        // already clear their own state) costs nothing.
        let _scope = QueryScope { workers: &self.workers, qid };
        let start = Instant::now();
        let plan = Arc::new(plan.clone());
        type Joined = std::thread::Result<Result<(RecordBatch, WorkerStats)>>;
        let joined: Vec<(usize, Joined)> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .workers
                .iter()
                .map(|w| {
                    let w = w.clone();
                    let plan = plan.clone();
                    s.spawn(move || w.run_query(&plan, qid, weight, timeout))
                })
                .collect();
            handles
                .into_iter()
                .enumerate()
                .map(|(i, h)| (i, h.join()))
                .collect()
        });
        let mut parts = Vec::new();
        let mut worker_stats = Vec::new();
        let mut first_err: Option<Error> = None;
        for (worker_id, r) in joined {
            match r {
                Ok(Ok((batch, stats))) => {
                    parts.push(batch);
                    worker_stats.push(stats);
                }
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(payload) => {
                    // A worker thread panicked. The seed's
                    // `h.join().unwrap()` re-panicked here, taking the
                    // whole gateway down with the query that tripped
                    // the bug; contain it as a query-scoped error so
                    // the cluster keeps serving.
                    self.metrics.counter("gateway.worker_panic_total").inc();
                    let detail = panic_detail(payload);
                    log::error!("worker {worker_id} panicked during query {qid}: {detail}");
                    first_err.get_or_insert(Error::WorkerPanic {
                        worker_id,
                        query_id: qid,
                        detail,
                    });
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let merged = gather(&plan, parts)?;
        Ok(QueryResult { batch: merged, elapsed: start.elapsed(), worker_stats })
    }

    pub fn stop(&self) {
        for w in &self.workers {
            w.stop();
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.stop();
    }
}

/// RAII guard: clears per-query state on all workers when a query
/// leaves [`Cluster::run_plan_weighted`] by *any* path. Without it, an
/// early-error return (or a panic unwinding through the gateway) would
/// strand per-qid scheduler entries and exchange channels until the
/// cluster shut down.
struct QueryScope<'a> {
    workers: &'a [Arc<Worker>],
    qid: u64,
}

impl Drop for QueryScope<'_> {
    fn drop(&mut self) {
        for w in self.workers {
            w.clear_query(self.qid);
        }
    }
}

/// Human-readable panic payload (panics carry `&str` or `String`).
fn panic_detail(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Client-side gather-merge of per-worker root outputs.
fn gather(plan: &PhysicalPlan, parts: Vec<RecordBatch>) -> Result<RecordBatch> {
    let all = RecordBatch::concat(&parts)?;
    Ok(match gather_mode(plan) {
        GatherMode::Concat => all,
        GatherMode::Sort { by, desc } => {
            if all.is_empty() {
                all
            } else {
                sort_batch(&all, &by, desc)?
            }
        }
        GatherMode::Limit { n } => {
            let take = (n as usize).min(all.rows());
            all.slice(0, take)?
        }
        GatherMode::SortLimit { by, desc, n } => {
            if all.is_empty() {
                all
            } else {
                let sorted = sort_batch(&all, &by, desc)?;
                let take = (n as usize).min(sorted.rows());
                sorted.slice(0, take)?
            }
        }
    })
}

/// Gateway: Planner + Cluster + serving cache (see [`crate::cache`])
/// + admission control (see [`crate::cluster::session`]).
pub struct Gateway {
    pub cluster: Cluster,
    pub planner: Planner,
    /// Per-query wall-clock timeout (`query_timeout_ms`; sessions can
    /// override per submission via [`SessionOpts::timeout`]).
    pub timeout: Duration,
    /// Two-level result/fragment cache; `None` when both budgets are 0
    /// (the default) — submit then always executes.
    pub cache: Option<ServingCache>,
    /// Gate on aggregate admitted scan footprint: concurrent submits
    /// beyond the budget queue here instead of thrashing the workers'
    /// governors mid-flight.
    pub admission: AdmissionController,
}

impl Gateway {
    pub fn new(cluster: Cluster) -> Gateway {
        let cfg = &cluster.config;
        let planner = Planner::new(cfg.num_workers);
        let (rb, fb) = (cfg.result_cache_bytes, cfg.fragment_cache_bytes);
        let cache = if rb + fb > 0 {
            Some(ServingCache::new(rb, fb, cluster.store.source_version()))
        } else {
            None
        };
        let timeout = Duration::from_millis(cfg.query_timeout_ms);
        let budget = if cfg.admission_capacity_bytes == 0 {
            cfg.device_capacity
        } else {
            cfg.admission_capacity_bytes
        };
        let admission =
            AdmissionController::new(budget, cfg.admission_bypass_limit, cluster.metrics.clone());
        Gateway { cluster, planner, timeout, cache, admission }
    }

    /// Plan + execute a logical query with default session options.
    pub fn submit(&self, q: &Logical) -> Result<QueryResult> {
        self.submit_with(q, &SessionOpts::default())
    }

    /// Plan + execute a logical query. With the serving cache enabled:
    /// canonicalize → memoized compile → exact-result lookup (a warm
    /// hit returns with zero cluster tasks) → fragment serve/fill →
    /// execute → fill the result cache. The *canonical* form is what
    /// executes, so cached bytes are byte-identical to a cache-off run
    /// of any query in the same equivalence class.
    ///
    /// Cache misses pass through admission before touching the
    /// cluster: the query holds a reservation sized at its per-worker
    /// scan footprint for its whole execution. Warm hits skip
    /// admission entirely — they cost the cluster nothing.
    pub fn submit_with(&self, q: &Logical, opts: &SessionOpts) -> Result<QueryResult> {
        let timeout = opts.timeout.unwrap_or(self.timeout);
        let weight = opts.weight.max(1);
        let Some(cache) = &self.cache else {
            let plan = self.planner.plan(q)?;
            let _grant = self.admit(&plan, opts, timeout)?;
            return self.run_with_retry(|| self.cluster.run_plan_weighted(&plan, timeout, weight));
        };
        let start = Instant::now();
        let canon = canonicalize(q);
        let plan = cache.plan_for(&self.planner, &canon)?;
        let key = CanonicalKey::of_plan(&plan);
        let versions = cache.version_snapshot(&canon.tables());
        if let Some(batch) = cache.lookup_result(&key, &versions) {
            // zero tasks executed: the cluster never sees the query
            return Ok(QueryResult {
                batch,
                elapsed: start.elapsed(),
                worker_stats: Vec::new(),
            });
        }
        let _grant = self.admit(&plan, opts, timeout)?;
        let res =
            self.run_with_retry(|| self.execute_with_fragments(cache, &canon, &plan, timeout, weight))?;
        cache.insert_result(key, &res.batch, versions);
        Ok(res)
    }

    /// Query-level recovery: re-run `run` after a *transient* failure
    /// (injected fault, dropped connection, timed-out read) up to
    /// `query_retry_limit` extra times. Each re-run mints a fresh qid —
    /// the failed attempt's per-query state was already torn down by
    /// its [`QueryScope`] — so attempts never see each other's debris.
    /// The admission grant is held by the caller across all attempts:
    /// a retrying query does not re-queue behind newly arrived work.
    /// Permanent errors (worker panics, plan bugs) pass through on the
    /// first attempt; exhausted retries return the last transient error
    /// as-is (still `is_retryable`, so the client may resubmit).
    fn run_with_retry<F>(&self, mut run: F) -> Result<QueryResult>
    where
        F: FnMut() -> Result<QueryResult>,
    {
        let limit = self.cluster.config.query_retry_limit;
        let mut reruns = 0usize;
        loop {
            match run() {
                Ok(r) => return Ok(r),
                Err(e) if e.is_transient() && reruns < limit => {
                    reruns += 1;
                    self.cluster.metrics.counter("gateway.query_retry_total").inc();
                    log::warn!("transient query failure ({e}); re-running ({reruns}/{limit})");
                }
                Err(e) => {
                    if e.is_transient() {
                        self.cluster.metrics.counter("retry.exhausted_total").inc();
                        log::error!(
                            "query failed after {reruns} re-runs (limit {limit}): {e}"
                        );
                    }
                    return Err(e);
                }
            }
        }
    }

    /// Serve cached fragments into the plan (filling missing ones) and
    /// run it. Fragment-hit plans execute strictly fewer cluster tasks
    /// than cold ones: the scan→filter→agg pipeline is replaced by a
    /// single fragment emit per worker.
    fn execute_with_fragments(
        &self,
        cache: &ServingCache,
        canon: &Logical,
        plan: &PhysicalPlan,
        timeout: Duration,
        weight: i64,
    ) -> Result<QueryResult> {
        if !cache.fragments_enabled() {
            return self.cluster.run_plan_weighted(plan, timeout, weight);
        }
        let mut rewritten = canon.clone();
        let mut rewrote = false;
        for frontier in canon.fragment_frontiers() {
            let fkey = CanonicalKey::of_logical(frontier);
            let fversions = cache.version_snapshot(&frontier.tables());
            let data = match cache.lookup_fragment(&fkey, &fversions) {
                Some(d) => d,
                None => {
                    // fill: run the frontier as its own query and keep
                    // the materialized batch for future drill-downs
                    let fplan = cache.plan_for(&self.planner, frontier)?;
                    let fres = self.cluster.run_plan_weighted(&fplan, timeout, weight)?;
                    let data = cache.insert_fragment(fkey, &fres.batch, fversions);
                    if frontier == canon {
                        // the whole query IS the frontier — done
                        return Ok(fres);
                    }
                    data
                }
            };
            rewritten = rewritten.substitute(frontier, &data);
            rewrote = true;
        }
        if rewrote {
            let plan = self.planner.plan(&rewritten)?;
            self.cluster.run_plan_weighted(&plan, timeout, weight)
        } else {
            self.cluster.run_plan_weighted(plan, timeout, weight)
        }
    }

    /// Execute a pre-built physical plan (bench harness path). Fronted
    /// by the exact-result cache only — fragments need the logical
    /// tree. Cache misses go through admission like `submit_with`.
    pub fn submit_plan(&self, plan: &PhysicalPlan) -> Result<QueryResult> {
        let opts = SessionOpts::default();
        let Some(cache) = &self.cache else {
            let _grant = self.admit(plan, &opts, self.timeout)?;
            return self.run_with_retry(|| self.cluster.run_plan(plan, self.timeout));
        };
        let start = Instant::now();
        let key = CanonicalKey::of_plan(plan);
        let versions = cache.version_snapshot(&plan_tables(plan));
        if let Some(batch) = cache.lookup_result(&key, &versions) {
            return Ok(QueryResult {
                batch,
                elapsed: start.elapsed(),
                worker_stats: Vec::new(),
            });
        }
        let _grant = self.admit(plan, &opts, self.timeout)?;
        let res = self.run_with_retry(|| self.cluster.run_plan(plan, self.timeout))?;
        cache.insert_result(key, &res.batch, versions);
        Ok(res)
    }

    /// Take an admission reservation sized at the plan's per-worker
    /// scan footprint. Blocks (FIFO within priority class, bounded
    /// bypassing across classes) while the aggregate admitted
    /// footprint would exceed the budget; times out with a retryable
    /// `ReservationTimeout { tier: "admission" }`.
    fn admit(
        &self,
        plan: &PhysicalPlan,
        opts: &SessionOpts,
        timeout: Duration,
    ) -> Result<AdmissionGrant> {
        self.admission
            .admit(opts.priority, self.scan_footprint(plan), timeout)
    }

    /// Per-worker share of the bytes `plan` scans — each worker reads
    /// ~1/N of every table's files, and the admission budget mirrors
    /// one worker's device capacity. Unsizable plans (no scans, or a
    /// store that can't list) admit at 1 byte: they still serialize
    /// behind starved waiters but don't consume budget.
    fn scan_footprint(&self, plan: &PhysicalPlan) -> usize {
        let mut total: u64 = 0;
        for table in plan_tables(plan) {
            let Ok(keys) = self.cluster.store.list(&format!("{table}/")) else {
                continue;
            };
            for key in keys {
                total += self.cluster.store.head(&key).unwrap_or(0);
            }
        }
        ((total / self.cluster.config.num_workers.max(1) as u64) as usize).max(1)
    }
}

/// Tables a physical plan scans (version-stamp dependencies).
fn plan_tables(plan: &PhysicalPlan) -> Vec<String> {
    let mut out: Vec<String> = plan
        .nodes
        .iter()
        .filter_map(|n| match &n.op {
            OpSpec::Scan { table, .. } => Some(table.clone()),
            _ => None,
        })
        .collect();
    out.sort_unstable();
    out.dedup();
    out
}

/// The user-facing handle.
pub struct Client {
    gateway: Arc<Gateway>,
}

impl Client {
    pub fn new(gateway: Arc<Gateway>) -> Client {
        Client { gateway }
    }

    pub fn query(&self, q: &Logical) -> Result<QueryResult> {
        self.gateway.submit(q)
    }

    /// Query with explicit session options (weight, admission
    /// priority, timeout override).
    pub fn query_with(&self, q: &Logical, opts: &SessionOpts) -> Result<QueryResult> {
        self.gateway.submit_with(q, opts)
    }

    pub fn gateway(&self) -> &Gateway {
        &self.gateway
    }
}

/// Convenience: launch a full stack (cluster + gateway + client) in one
/// call — the quickstart path.
pub fn connect(
    config: WorkerConfig,
    store: Arc<dyn ObjectStore>,
    registry: Option<KernelRegistry>,
) -> Result<Client> {
    let cluster = Cluster::launch(config, store, registry)?;
    Ok(Client::new(Arc::new(Gateway::new(cluster))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::plan::{AggFn, AggSpec, Pred};
    use crate::sim::SimContext;
    use crate::storage::compression::Codec;
    use crate::storage::format::FileWriter;
    use crate::storage::object_store::SimObjectStore;
    use crate::types::{Column, DType, Field, RecordBatch, Schema};
    use crate::util::rng::Rng;

    /// Two tables: fact(k, v) and dim(k, w) for join tests.
    fn store_with_tables(rows: usize) -> Arc<SimObjectStore> {
        let store = SimObjectStore::in_memory(&SimContext::test());
        let mut rng = Rng::new(7);
        let fact_schema = Schema::new(vec![
            Field::new("k", DType::Int64),
            Field::new("v", DType::Float32),
        ]);
        for f in 0..2 {
            let batch = RecordBatch::new(vec![
                Column::i64("k", (0..rows).map(|_| rng.gen_i64(0, 49)).collect()),
                Column::f32("v", (0..rows).map(|i| i as f32).collect()),
            ])
            .unwrap();
            let mut w = FileWriter::new(fact_schema.clone(), Codec::Zstd { level: 1 }, 256);
            w.write(batch).unwrap();
            store
                .put(&format!("fact/{f}.ths"), &w.finish().unwrap())
                .unwrap();
        }
        let dim_schema = Schema::new(vec![
            Field::new("dk", DType::Int64),
            Field::new("w", DType::Int64),
        ]);
        let batch = RecordBatch::new(vec![
            Column::i64("dk", (0..50).collect()),
            Column::i64("w", (0..50).map(|i| i * 100).collect()),
        ])
        .unwrap();
        let mut w = FileWriter::new(dim_schema, Codec::None, 64);
        w.write(batch).unwrap();
        store.put("dim/0.ths", &w.finish().unwrap()).unwrap();
        store
    }

    fn cfg(workers: usize) -> WorkerConfig {
        WorkerConfig {
            num_workers: workers,
            compute_threads: 2,
            ..WorkerConfig::test()
        }
    }

    #[test]
    fn single_worker_scan_agg() {
        let store = store_with_tables(500);
        let client = connect(cfg(1), store, None).unwrap();
        let q = Logical::scan("fact", &["k", "v"])
            .aggregate("k", vec![AggSpec::new(AggFn::Count, "v")]);
        let r = client.query(&q).unwrap();
        assert_eq!(r.batch.rows(), 50);
        let counts = r.batch.column("count_v").unwrap().data.as_f64().unwrap();
        let total: f64 = counts.iter().sum();
        assert_eq!(total, 1000.0);
    }

    #[test]
    fn two_workers_exchange_and_agg() {
        let store = store_with_tables(500);
        let client = connect(cfg(2), store, None).unwrap();
        let q = Logical::scan("fact", &["k", "v"])
            .aggregate("k", vec![AggSpec::new(AggFn::Count, "v")])
            .sort("k", false);
        let r = client.query(&q).unwrap();
        assert_eq!(r.batch.rows(), 50, "each key once after exchange");
        let counts = r.batch.column("count_v").unwrap().data.as_f64().unwrap();
        assert_eq!(counts.iter().sum::<f64>(), 1000.0);
        let keys = r.batch.column("k").unwrap().data.as_i64().unwrap();
        assert!(keys.windows(2).all(|w| w[0] < w[1]), "gather sort");
    }

    #[test]
    fn join_across_workers_with_lip() {
        let store = store_with_tables(400);
        let client = connect(cfg(2), store, None).unwrap();
        // build = dim, probe = fact; sum joined weights per key
        let q = Logical::scan("dim", &["dk", "w"])
            .join(Logical::scan("fact", &["k", "v"]), "dk", "k", true)
            .aggregate("dk", vec![AggSpec::new(AggFn::Count, "w"), AggSpec::new(AggFn::Max, "w")])
            .sort("dk", false);
        let r = client.query(&q).unwrap();
        assert_eq!(r.batch.rows(), 50);
        let counts = r.batch.column("count_w").unwrap().data.as_f64().unwrap();
        assert_eq!(counts.iter().sum::<f64>(), 800.0, "every fact row joins once");
        let maxs = r.batch.column("max_w").unwrap().data.as_f64().unwrap();
        let keys = r.batch.column("dk").unwrap().data.as_i64().unwrap();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(maxs[i], (k * 100) as f64);
        }
    }

    #[test]
    fn filter_pushdown_and_limit() {
        let store = store_with_tables(300);
        let client = connect(cfg(2), store, None).unwrap();
        let q = Logical::scan("fact", &["k", "v"])
            .filter(Pred::RangeI64 { col: "k".into(), lo: 0, hi: 10 })
            .aggregate("k", vec![AggSpec::new(AggFn::Count, "v")])
            .sort("k", false)
            .limit(5);
        let r = client.query(&q).unwrap();
        assert_eq!(r.batch.rows(), 5);
        let keys = r.batch.column("k").unwrap().data.as_i64().unwrap();
        assert_eq!(keys, &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn sequential_queries_reuse_cluster() {
        let store = store_with_tables(200);
        let client = connect(cfg(2), store, None).unwrap();
        for _ in 0..3 {
            let q = Logical::scan("fact", &["k", "v"])
                .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")]);
            let r = client.query(&q).unwrap();
            assert_eq!(r.batch.rows(), 50);
        }
    }

    /// Integer-valued fact table (exact f64 aggregation ⇒ cached bytes
    /// can be compared bit-for-bit across runs).
    fn int_store(rows: usize) -> Arc<SimObjectStore> {
        let store = SimObjectStore::in_memory(&SimContext::test());
        let mut rng = Rng::new(11);
        let schema = Schema::new(vec![
            Field::new("k", DType::Int64),
            Field::new("v", DType::Int64),
        ]);
        for f in 0..2 {
            let batch = RecordBatch::new(vec![
                Column::i64("k", (0..rows).map(|_| rng.gen_i64(0, 19)).collect()),
                Column::i64("v", (0..rows).map(|_| rng.gen_i64(0, 999)).collect()),
            ])
            .unwrap();
            let mut w = FileWriter::new(schema.clone(), Codec::Zstd { level: 1 }, 256);
            w.write(batch).unwrap();
            store
                .put(&format!("fact/{f}.ths"), &w.finish().unwrap())
                .unwrap();
        }
        store
    }

    fn cached_cfg(workers: usize) -> WorkerConfig {
        WorkerConfig {
            result_cache_bytes: 4 << 20,
            fragment_cache_bytes: 4 << 20,
            ..cfg(workers)
        }
    }

    fn total_tasks(r: &QueryResult) -> u64 {
        r.worker_stats.iter().map(|s| s.tasks_executed).sum()
    }

    fn drill(lo: i64, hi: i64) -> Logical {
        Logical::scan("fact", &["k", "v"])
            .filter(Pred::RangeI64 { col: "k".into(), lo, hi })
            .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")])
            .sort("k", false)
    }

    #[test]
    fn warm_exact_hit_executes_zero_cluster_tasks() {
        let client = connect(cached_cfg(2), int_store(400), None).unwrap();
        let cold = client.query(&drill(0, 20)).unwrap();
        assert!(total_tasks(&cold) > 0, "cold run uses the cluster");
        let warm = client.query(&drill(0, 20)).unwrap();
        assert_eq!(total_tasks(&warm), 0, "warm hit must not touch the cluster");
        assert_eq!(
            warm.batch.encode(),
            cold.batch.encode(),
            "cached bytes identical to the execution that filled them"
        );
        let m = client.gateway().cache.as_ref().unwrap().metrics();
        assert_eq!(m.counter_value("cache.result_hit"), 1);
    }

    #[test]
    fn equivalent_rewrites_share_one_cache_entry() {
        let client = connect(cached_cfg(1), int_store(300), None).unwrap();
        let p1 = Pred::RangeI64 { col: "k".into(), lo: 0, hi: 20 };
        let p2 = Pred::RangeI64 { col: "v".into(), lo: 0, hi: 1000 };
        let a = Logical::scan("fact", &["k", "v"])
            .filter(p1.clone().and(p2.clone()))
            .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")])
            .sort("k", false);
        let b = Logical::scan("fact", &["v", "k"]) // swapped cols (absorbed)
            .filter(p2.and(p1)) // swapped conjuncts
            .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")])
            .sort("k", false);
        let ra = client.query(&a).unwrap();
        let rb = client.query(&b).unwrap();
        assert_eq!(total_tasks(&rb), 0, "rewrite must hit a's entry");
        assert_eq!(ra.batch.encode(), rb.batch.encode());
    }

    #[test]
    fn fragment_hit_runs_strictly_fewer_tasks_and_same_bytes() {
        let store = int_store(400);
        // cache-off baseline for byte-identity
        let plain = connect(cfg(2), store.clone(), None).unwrap();
        let cached = connect(cached_cfg(2), store, None).unwrap();
        let q = drill(0, 20);
        let baseline = plain.query(&q).unwrap();
        let cold = cached.query(&q).unwrap(); // fills fragment + result
        assert_eq!(cold.batch.encode(), baseline.batch.encode());
        // a *different* query over the same frontier: limit forces a
        // distinct result-cache key, the shared agg fragment serves it
        let drilldown = drill(0, 20).limit(5);
        let plain_dd = plain.query(&drilldown).unwrap();
        let warm_dd = cached.query(&drilldown).unwrap();
        assert_eq!(warm_dd.batch.encode(), plain_dd.batch.encode());
        assert!(
            total_tasks(&warm_dd) > 0,
            "fragment serving still runs the plan above the frontier"
        );
        assert!(
            total_tasks(&warm_dd) < total_tasks(&plain_dd),
            "fragment hit must run strictly fewer tasks ({} vs {})",
            total_tasks(&warm_dd),
            total_tasks(&plain_dd)
        );
        let m = cached.gateway().cache.as_ref().unwrap().metrics();
        assert!(m.counter_value("cache.fragment_hit") >= 1);
    }

    #[test]
    fn datasource_write_invalidates_cached_results() {
        let store = int_store(200);
        let client = connect(cached_cfg(1), store.clone(), None).unwrap();
        let q = drill(0, 20);
        let before = client.query(&q).unwrap();
        assert_eq!(total_tasks(&client.query(&q).unwrap()), 0, "warm");
        // append a new file to the fact table: version bump
        let schema = Schema::new(vec![
            Field::new("k", DType::Int64),
            Field::new("v", DType::Int64),
        ]);
        let batch = RecordBatch::new(vec![
            Column::i64("k", vec![0; 100]),
            Column::i64("v", vec![7; 100]),
        ])
        .unwrap();
        let mut w = FileWriter::new(schema, Codec::Zstd { level: 1 }, 256);
        w.write(batch).unwrap();
        store.put("fact/2.ths", &w.finish().unwrap()).unwrap();
        let after = client.query(&q).unwrap();
        assert!(total_tasks(&after) > 0, "stale entry must not serve");
        assert_ne!(
            after.batch.encode(),
            before.batch.encode(),
            "fresh bytes reflect the new data"
        );
        let k0_sum = |r: &QueryResult| {
            let keys = r.batch.column("k").unwrap().data.as_i64().unwrap().to_vec();
            let sums = r.batch.column("sum_v").unwrap().data.as_f64().unwrap().to_vec();
            sums[keys.iter().position(|&k| k == 0).unwrap()]
        };
        assert_eq!(k0_sum(&after), k0_sum(&before) + 700.0);
        let m = client.gateway().cache.as_ref().unwrap().metrics();
        assert!(m.counter_value("cache.invalidated") >= 1);
    }

    #[test]
    fn submit_plan_is_fronted_by_the_result_cache() {
        let client = connect(cached_cfg(1), int_store(200), None).unwrap();
        let gw = client.gateway();
        let plan = gw.planner.plan(&canonicalize(&drill(0, 20))).unwrap();
        let cold = gw.submit_plan(&plan).unwrap();
        assert!(total_tasks(&cold) > 0);
        let warm = gw.submit_plan(&plan).unwrap();
        assert_eq!(total_tasks(&warm), 0);
        assert_eq!(warm.batch.encode(), cold.batch.encode());
    }

    #[test]
    fn stats_are_reported() {
        let store = store_with_tables(300);
        let client = connect(cfg(2), store, None).unwrap();
        let q = Logical::scan("fact", &["k", "v"])
            .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")]);
        let r = client.query(&q).unwrap();
        assert_eq!(r.worker_stats.len(), 2);
        assert!(r.worker_stats.iter().all(|s| s.tasks_executed > 0));
        assert!(r.total_wire_bytes() > 0, "exchange must touch the wire");
    }

    #[test]
    fn worker_panic_becomes_query_error_and_cluster_survives() {
        let store = store_with_tables(200);
        let client = connect(cfg(2), store, None).unwrap();
        let q = Logical::scan("fact", &["k", "v"])
            .aggregate("k", vec![AggSpec::new(AggFn::Count, "v")]);
        client.gateway().cluster.workers[1].inject_panic_next();
        let err = client.query(&q).unwrap_err();
        match &err {
            crate::Error::WorkerPanic { worker_id, detail, .. } => {
                assert_eq!(*worker_id, 1);
                assert!(detail.contains("injected"), "payload surfaced: {detail}");
            }
            e => panic!("expected WorkerPanic, got {e}"),
        }
        assert!(!err.is_retryable(), "a panic is a bug, not pressure");
        let m = &client.gateway().cluster.metrics;
        assert_eq!(m.counter_value("gateway.worker_panic_total"), 1);
        // the panicking query died alone: the same cluster serves the
        // next submission (the seed re-panicked in the gateway here)
        let r = client.query(&q).unwrap();
        assert_eq!(r.batch.rows(), 50);
        assert_eq!(
            r.batch.column("count_v").unwrap().data.as_f64().unwrap().iter().sum::<f64>(),
            400.0
        );
    }

    #[test]
    fn weighted_session_returns_identical_bytes() {
        let store = int_store(300);
        let plain = connect(cfg(2), store.clone(), None).unwrap();
        let a = plain.query(&drill(0, 20)).unwrap();
        let client = connect(cfg(2), store, None).unwrap();
        let opts = SessionOpts { weight: 8, priority: 3, timeout: None };
        let b = client.query_with(&drill(0, 20), &opts).unwrap();
        assert_eq!(a.batch.encode(), b.batch.encode(), "weight is a scheduling hint only");
        let m = &client.gateway().cluster.metrics;
        assert_eq!(m.counter_value("gateway.admitted"), 1);
        assert_eq!(m.counter_value("gateway.queued"), 0, "sole query admits immediately");
    }

    #[test]
    fn put_during_execution_does_not_poison_cache() {
        let store = int_store(200);
        let client = connect(cached_cfg(1), store.clone(), None).unwrap();
        let gw = client.gateway();
        let cache = gw.cache.as_ref().unwrap();
        let q = drill(0, 20);
        // replay the gateway's own submit sequence deterministically:
        // snapshot versions → execute → (concurrent writer appends) →
        // insert. The seed inserted unconditionally, serving stale
        // bytes for the pre-put data under a post-put version clock.
        let canon = canonicalize(&q);
        let plan = cache.plan_for(&gw.planner, &canon).unwrap();
        let key = CanonicalKey::of_plan(&plan);
        let versions = cache.version_snapshot(&canon.tables());
        let res = gw.cluster.run_plan(&plan, gw.timeout).unwrap();
        let schema = Schema::new(vec![
            Field::new("k", DType::Int64),
            Field::new("v", DType::Int64),
        ]);
        let batch = RecordBatch::new(vec![
            Column::i64("k", vec![0; 50]),
            Column::i64("v", vec![9; 50]),
        ])
        .unwrap();
        let mut w = FileWriter::new(schema, Codec::None, 256);
        w.write(batch).unwrap();
        store.put("fact/9.ths", &w.finish().unwrap()).unwrap();
        cache.insert_result(key.clone(), &res.batch, versions);
        let m = cache.metrics();
        assert_eq!(
            m.counter_value("cache.stale_insert_dropped"),
            1,
            "insert must notice the version advance and drop the entry"
        );
        let fresh = cache.version_snapshot(&canon.tables());
        assert!(
            cache.lookup_result(&key, &fresh).is_none(),
            "stale result bytes must never serve under the new version"
        );
        // end-to-end: the next submit recomputes over the new file
        let after = client.query(&q).unwrap();
        assert!(total_tasks(&after) > 0);
        let keys = after.batch.column("k").unwrap().data.as_i64().unwrap().to_vec();
        let sums = after.batch.column("sum_v").unwrap().data.as_f64().unwrap().to_vec();
        let k0 = sums[keys.iter().position(|&k| k == 0).unwrap()];
        let keys_b = res.batch.column("k").unwrap().data.as_i64().unwrap().to_vec();
        let sums_b = res.batch.column("sum_v").unwrap().data.as_f64().unwrap().to_vec();
        let k0_b = sums_b[keys_b.iter().position(|&k| k == 0).unwrap()];
        assert_eq!(k0, k0_b + 450.0, "50 new rows of v=9 under k=0");
    }
}
