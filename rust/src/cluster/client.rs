//! Cluster launcher, Gateway, and Client (§3).
//!
//! [`Cluster::launch`] brings up N workers in one process over the
//! configured fabric (in-proc channels or real loopback TCP, both
//! shaped by the profile's link specs). [`Gateway`] plans logical
//! queries and submits the physical plan to every worker — "every
//! worker receives the same physical execution plan with a different
//! subset of files to scan" — then gathers and merges worker outputs
//! for the [`Client`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{TransportKind, WorkerConfig};
use crate::exec::operators::sort::sort_batch;
use crate::exec::PhysicalPlan;
use crate::network::{Endpoint, InprocHub, TcpCluster};
use crate::planner::{gather_mode, GatherMode, Logical, Planner};
use crate::runtime::KernelRegistry;
use crate::sim::SimContext;
use crate::storage::object_store::ObjectStore;
use crate::types::RecordBatch;
use crate::Result;

use super::worker::Worker;

/// Per-worker post-query statistics (bench reporting).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub worker_id: usize,
    pub tasks_executed: u64,
    pub task_retries: u64,
    pub spills: u64,
    pub spilled_bytes: u64,
    pub preload_byte_ranges: u64,
    pub preload_promotions: u64,
    pub net_bytes_precompress: u64,
    pub net_bytes_wire: u64,
    pub compress_time: Duration,
    pub device_peak_bytes: usize,
}

/// A completed query.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub batch: RecordBatch,
    pub elapsed: Duration,
    pub worker_stats: Vec<WorkerStats>,
}

impl QueryResult {
    pub fn total_spills(&self) -> u64 {
        self.worker_stats.iter().map(|s| s.spills).sum()
    }

    pub fn total_wire_bytes(&self) -> u64 {
        self.worker_stats.iter().map(|s| s.net_bytes_wire).sum()
    }
}

/// N workers over one fabric.
pub struct Cluster {
    pub workers: Vec<Arc<Worker>>,
    query_seq: AtomicU64,
    pub config: Arc<WorkerConfig>,
}

impl Cluster {
    /// Launch `config.num_workers` workers over `store`.
    ///
    /// `registry = None` uses host fallbacks (unit tests); pass
    /// `Some(KernelRegistry::shared()?)` for the AOT device path.
    pub fn launch(
        config: WorkerConfig,
        store: Arc<dyn ObjectStore>,
        registry: Option<KernelRegistry>,
    ) -> Result<Cluster> {
        config.validate()?;
        let config = Arc::new(config);
        let n = config.num_workers;
        // compile every AOT stage up front (engine-init time, not query
        // time — the paper's workers initialize kernels at startup)
        if let Some(r) = &registry {
            r.warmup_all()?;
        }
        let sim = SimContext::new(config.profile.clone(), config.time_scale);

        let endpoints: Vec<Arc<dyn Endpoint>> = match config.transport {
            TransportKind::Tcp => TcpCluster::listen_with_limit(
                n,
                &sim,
                TransportKind::Tcp,
                config.max_frame_bytes,
            )?
                .into_endpoints()
                .into_iter()
                .map(|e| Arc::new(e) as Arc<dyn Endpoint>)
                .collect(),
            kind => {
                let hub = InprocHub::new(n, &sim, kind);
                hub.endpoints()
                    .into_iter()
                    .map(|e| Arc::new(e) as Arc<dyn Endpoint>)
                    .collect()
            }
        };

        let workers = endpoints
            .into_iter()
            .enumerate()
            .map(|(id, ep)| {
                Worker::start(id, config.clone(), store.clone(), ep, registry.clone())
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Cluster { workers, query_seq: AtomicU64::new(1), config })
    }

    /// Run one physical plan across all workers; gather per `mode`.
    pub fn run_plan(
        &self,
        plan: &PhysicalPlan,
        timeout: Duration,
    ) -> Result<QueryResult> {
        let qid = self.query_seq.fetch_add(1, Ordering::Relaxed);
        let start = Instant::now();
        // baseline counters so stats are per-query deltas
        let base: Vec<_> = self.workers.iter().map(|w| snapshot(w)).collect();

        let plan = Arc::new(plan.clone());
        let results: Vec<Result<RecordBatch>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .workers
                .iter()
                .map(|w| {
                    let w = w.clone();
                    let plan = plan.clone();
                    s.spawn(move || w.run_query(&plan, qid, timeout))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut parts = Vec::new();
        for r in results {
            parts.push(r?);
        }
        let merged = gather(&plan, parts)?;
        let elapsed = start.elapsed();
        let worker_stats = self
            .workers
            .iter()
            .zip(base)
            .map(|(w, b)| delta(w, b))
            .collect();
        for w in &self.workers {
            w.reset();
        }
        Ok(QueryResult { batch: merged, elapsed, worker_stats })
    }

    pub fn stop(&self) {
        for w in &self.workers {
            w.stop();
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.stop();
    }
}

fn snapshot(w: &Worker) -> WorkerStats {
    let (pre, wire) = w.network.compression_ratio_inputs();
    WorkerStats {
        worker_id: w.ctx.worker_id,
        tasks_executed: w.compute.executed(),
        task_retries: w.compute.retries(),
        // every demotion below the intended tier: OOM push fallbacks +
        // memory-executor spills (§4.2's "spilling")
        spills: w.ctx.env.demotions(),
        spilled_bytes: w.movement.spilled_bytes(),
        preload_byte_ranges: w.preload.byte_range_loads(),
        preload_promotions: w.movement.promotions(),
        net_bytes_precompress: pre,
        net_bytes_wire: wire,
        compress_time: w.network.compress_time(),
        device_peak_bytes: w.ctx.env.arena.peak(),
    }
}

fn delta(w: &Worker, base: WorkerStats) -> WorkerStats {
    let now = snapshot(w);
    WorkerStats {
        worker_id: now.worker_id,
        tasks_executed: now.tasks_executed - base.tasks_executed,
        task_retries: now.task_retries - base.task_retries,
        spills: now.spills - base.spills,
        spilled_bytes: now.spilled_bytes - base.spilled_bytes,
        preload_byte_ranges: now.preload_byte_ranges - base.preload_byte_ranges,
        preload_promotions: now.preload_promotions - base.preload_promotions,
        net_bytes_precompress: now.net_bytes_precompress - base.net_bytes_precompress,
        net_bytes_wire: now.net_bytes_wire - base.net_bytes_wire,
        compress_time: now.compress_time.saturating_sub(base.compress_time),
        device_peak_bytes: now.device_peak_bytes,
    }
}

/// Client-side gather-merge of per-worker root outputs.
fn gather(plan: &PhysicalPlan, parts: Vec<RecordBatch>) -> Result<RecordBatch> {
    let all = RecordBatch::concat(&parts)?;
    Ok(match gather_mode(plan) {
        GatherMode::Concat => all,
        GatherMode::Sort { by, desc } => {
            if all.is_empty() {
                all
            } else {
                sort_batch(&all, &by, desc)?
            }
        }
        GatherMode::Limit { n } => {
            let take = (n as usize).min(all.rows());
            all.slice(0, take)?
        }
        GatherMode::SortLimit { by, desc, n } => {
            if all.is_empty() {
                all
            } else {
                let sorted = sort_batch(&all, &by, desc)?;
                let take = (n as usize).min(sorted.rows());
                sorted.slice(0, take)?
            }
        }
    })
}

/// Gateway: Planner + Cluster.
pub struct Gateway {
    pub cluster: Cluster,
    pub planner: Planner,
    /// Per-query wall-clock timeout.
    pub timeout: Duration,
}

impl Gateway {
    pub fn new(cluster: Cluster) -> Gateway {
        let planner = Planner::new(cluster.config.num_workers);
        Gateway { cluster, planner, timeout: Duration::from_secs(300) }
    }

    /// Plan + execute a logical query.
    pub fn submit(&self, q: &Logical) -> Result<QueryResult> {
        let plan = self.planner.plan(q)?;
        self.cluster.run_plan(&plan, self.timeout)
    }

    /// Execute a pre-built physical plan (bench harness path).
    pub fn submit_plan(&self, plan: &PhysicalPlan) -> Result<QueryResult> {
        self.cluster.run_plan(plan, self.timeout)
    }
}

/// The user-facing handle.
pub struct Client {
    gateway: Arc<Gateway>,
}

impl Client {
    pub fn new(gateway: Arc<Gateway>) -> Client {
        Client { gateway }
    }

    pub fn query(&self, q: &Logical) -> Result<QueryResult> {
        self.gateway.submit(q)
    }

    pub fn gateway(&self) -> &Gateway {
        &self.gateway
    }
}

/// Convenience: launch a full stack (cluster + gateway + client) in one
/// call — the quickstart path.
pub fn connect(
    config: WorkerConfig,
    store: Arc<dyn ObjectStore>,
    registry: Option<KernelRegistry>,
) -> Result<Client> {
    let cluster = Cluster::launch(config, store, registry)?;
    Ok(Client::new(Arc::new(Gateway::new(cluster))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::plan::{AggFn, AggSpec, Pred};
    use crate::sim::SimContext;
    use crate::storage::compression::Codec;
    use crate::storage::format::FileWriter;
    use crate::storage::object_store::SimObjectStore;
    use crate::types::{Column, DType, Field, RecordBatch, Schema};
    use crate::util::rng::Rng;

    /// Two tables: fact(k, v) and dim(k, w) for join tests.
    fn store_with_tables(rows: usize) -> Arc<SimObjectStore> {
        let store = SimObjectStore::in_memory(&SimContext::test());
        let mut rng = Rng::new(7);
        let fact_schema = Schema::new(vec![
            Field::new("k", DType::Int64),
            Field::new("v", DType::Float32),
        ]);
        for f in 0..2 {
            let batch = RecordBatch::new(vec![
                Column::i64("k", (0..rows).map(|_| rng.gen_i64(0, 49)).collect()),
                Column::f32("v", (0..rows).map(|i| i as f32).collect()),
            ])
            .unwrap();
            let mut w = FileWriter::new(fact_schema.clone(), Codec::Zstd { level: 1 }, 256);
            w.write(batch).unwrap();
            store
                .put(&format!("fact/{f}.ths"), &w.finish().unwrap())
                .unwrap();
        }
        let dim_schema = Schema::new(vec![
            Field::new("dk", DType::Int64),
            Field::new("w", DType::Int64),
        ]);
        let batch = RecordBatch::new(vec![
            Column::i64("dk", (0..50).collect()),
            Column::i64("w", (0..50).map(|i| i * 100).collect()),
        ])
        .unwrap();
        let mut w = FileWriter::new(dim_schema, Codec::None, 64);
        w.write(batch).unwrap();
        store.put("dim/0.ths", &w.finish().unwrap()).unwrap();
        store
    }

    fn cfg(workers: usize) -> WorkerConfig {
        WorkerConfig {
            num_workers: workers,
            compute_threads: 2,
            ..WorkerConfig::test()
        }
    }

    #[test]
    fn single_worker_scan_agg() {
        let store = store_with_tables(500);
        let client = connect(cfg(1), store, None).unwrap();
        let q = Logical::scan("fact", &["k", "v"])
            .aggregate("k", vec![AggSpec::new(AggFn::Count, "v")]);
        let r = client.query(&q).unwrap();
        assert_eq!(r.batch.rows(), 50);
        let counts = r.batch.column("count_v").unwrap().data.as_f64().unwrap();
        let total: f64 = counts.iter().sum();
        assert_eq!(total, 1000.0);
    }

    #[test]
    fn two_workers_exchange_and_agg() {
        let store = store_with_tables(500);
        let client = connect(cfg(2), store, None).unwrap();
        let q = Logical::scan("fact", &["k", "v"])
            .aggregate("k", vec![AggSpec::new(AggFn::Count, "v")])
            .sort("k", false);
        let r = client.query(&q).unwrap();
        assert_eq!(r.batch.rows(), 50, "each key once after exchange");
        let counts = r.batch.column("count_v").unwrap().data.as_f64().unwrap();
        assert_eq!(counts.iter().sum::<f64>(), 1000.0);
        let keys = r.batch.column("k").unwrap().data.as_i64().unwrap();
        assert!(keys.windows(2).all(|w| w[0] < w[1]), "gather sort");
    }

    #[test]
    fn join_across_workers_with_lip() {
        let store = store_with_tables(400);
        let client = connect(cfg(2), store, None).unwrap();
        // build = dim, probe = fact; sum joined weights per key
        let q = Logical::scan("dim", &["dk", "w"])
            .join(Logical::scan("fact", &["k", "v"]), "dk", "k", true)
            .aggregate("dk", vec![AggSpec::new(AggFn::Count, "w"), AggSpec::new(AggFn::Max, "w")])
            .sort("dk", false);
        let r = client.query(&q).unwrap();
        assert_eq!(r.batch.rows(), 50);
        let counts = r.batch.column("count_w").unwrap().data.as_f64().unwrap();
        assert_eq!(counts.iter().sum::<f64>(), 800.0, "every fact row joins once");
        let maxs = r.batch.column("max_w").unwrap().data.as_f64().unwrap();
        let keys = r.batch.column("dk").unwrap().data.as_i64().unwrap();
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(maxs[i], (k * 100) as f64);
        }
    }

    #[test]
    fn filter_pushdown_and_limit() {
        let store = store_with_tables(300);
        let client = connect(cfg(2), store, None).unwrap();
        let q = Logical::scan("fact", &["k", "v"])
            .filter(Pred::RangeI64 { col: "k".into(), lo: 0, hi: 10 })
            .aggregate("k", vec![AggSpec::new(AggFn::Count, "v")])
            .sort("k", false)
            .limit(5);
        let r = client.query(&q).unwrap();
        assert_eq!(r.batch.rows(), 5);
        let keys = r.batch.column("k").unwrap().data.as_i64().unwrap();
        assert_eq!(keys, &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn sequential_queries_reuse_cluster() {
        let store = store_with_tables(200);
        let client = connect(cfg(2), store, None).unwrap();
        for _ in 0..3 {
            let q = Logical::scan("fact", &["k", "v"])
                .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")]);
            let r = client.query(&q).unwrap();
            assert_eq!(r.batch.rows(), 50);
        }
    }

    #[test]
    fn stats_are_reported() {
        let store = store_with_tables(300);
        let client = connect(cfg(2), store, None).unwrap();
        let q = Logical::scan("fact", &["k", "v"])
            .aggregate("k", vec![AggSpec::new(AggFn::Sum, "v")]);
        let r = client.query(&q).unwrap();
        assert_eq!(r.worker_stats.len(), 2);
        assert!(r.worker_stats.iter().all(|s| s.tasks_executed > 0));
        assert!(r.total_wire_bytes() > 0, "exchange must touch the wire");
    }
}
