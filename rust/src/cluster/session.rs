//! Query-session layer (PR 8): one [`QuerySession`] per in-flight
//! query, plus the admission control that makes concurrent
//! `Gateway::submit` safe under a bounded device budget.
//!
//! The paper's gateway "receives queries and routes them to an
//! available cluster"; running many queries against one cluster only
//! works if their aggregate working sets respect the device budget the
//! `MemoryGovernor` enforces per worker. The admission layer gates
//! query *entry* on that budget: each query is sized by its plan's
//! per-worker scan footprint and holds an admission [`Reservation`]
//! for its whole execution. Refused admissions queue FIFO within a
//! priority class; a starvation bound (`admission_bypass_limit`)
//! guarantees a low-priority query is bypassed at most `limit` times
//! before it becomes the head of the line and nothing may overtake it.
//!
//! The policy core ([`AdmissionQueue`]) is a pure, single-threaded
//! state machine so tests (and the shrink-based property test in
//! `tests/props.rs`) can drive every interleaving deterministically.
//! [`AdmissionController`] wraps it with a mutex + condvar and a
//! dedicated governor whose reservations are the proof that aggregate
//! admitted bytes never exceed the budget.

use std::collections::HashMap;
use std::sync::Arc;

use crate::sync::{ranks, OrderedCondvar, OrderedMutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::memory::{DeviceArena, MemoryGovernor, Reservation};
use crate::metrics::Metrics;

/// Condvar wait chunk: bounded so a missed notify can't park a
/// submitter past its deadline (mirrors the governor's wait loop).
const WAIT_CHUNK: Duration = Duration::from_millis(20);

/// Per-submission knobs. `Default` reproduces the single-query
/// behavior of earlier PRs exactly: weight 1 leaves the residency
/// bonus unscaled, priority 0 is the base class, and no timeout
/// override falls back to the gateway's `query_timeout_ms`.
#[derive(Clone, Debug)]
pub struct SessionOpts {
    /// Scales the residency bonus in compute scheduling and the
    /// promotion urgency in the movement plane. Clamped to >= 1.
    pub weight: i64,
    /// Admission class: higher admits first among waiters (subject to
    /// the starvation bound). Does not affect execution, only entry.
    pub priority: i64,
    /// Per-session override of the gateway query timeout.
    pub timeout: Option<Duration>,
}

impl Default for SessionOpts {
    fn default() -> Self {
        SessionOpts { weight: 1, priority: 0, timeout: None }
    }
}

/// One in-flight query: identity plus the knobs it entered with. The
/// gateway mints one per submission; its `qid` scopes every per-query
/// counter on the workers and tags the exchange channel space.
#[derive(Clone, Debug)]
pub struct QuerySession {
    pub qid: u64,
    pub weight: i64,
    pub priority: i64,
    /// Wall-clock execution deadline (admission wait not included —
    /// admission has its own deadline from the same budget).
    pub deadline: Instant,
}

impl QuerySession {
    pub fn new(qid: u64, opts: &SessionOpts, default_timeout: Duration) -> QuerySession {
        let t = opts.timeout.unwrap_or(default_timeout);
        QuerySession {
            qid,
            weight: opts.weight.max(1),
            priority: opts.priority,
            deadline: Instant::now() + t,
        }
    }
}

/// A waiting query in the admission queue.
#[derive(Clone, Debug)]
struct Ticket {
    seq: u64,
    priority: i64,
    bytes: usize,
    /// Times a younger, higher-priority ticket was admitted past this
    /// one. Capped by construction at the bypass limit: once a ticket
    /// reaches the limit it is *starved* and becomes the queue head —
    /// nothing may be admitted before it.
    bypassed: usize,
}

/// Pure admission policy: FIFO within priority class, higher class
/// first, bounded bypassing. Strictly head-of-line: only the current
/// [`candidate`](AdmissionQueue::candidate) may be admitted, so a
/// small query can never slip past a starved large one (no unbounded
/// "fit anyone who fits" starvation).
///
/// Byte accounting lives here too so the machine is self-contained
/// for deterministic tests; the controller mirrors each admission
/// with a real governor [`Reservation`] of the same size.
pub struct AdmissionQueue {
    capacity: usize,
    bypass_limit: usize,
    next_seq: u64,
    waiting: Vec<Ticket>,
    /// ticket seq -> bytes, for admitted-but-unfinished queries.
    admitted: HashMap<u64, usize>,
    admitted_bytes: usize,
}

impl AdmissionQueue {
    /// `capacity` is the aggregate admitted-bytes budget;
    /// `bypass_limit` the starvation bound (>= 1).
    pub fn new(capacity: usize, bypass_limit: usize) -> AdmissionQueue {
        AdmissionQueue {
            capacity: capacity.max(1),
            bypass_limit: bypass_limit.max(1),
            next_seq: 0,
            waiting: Vec::new(),
            admitted: HashMap::new(),
            admitted_bytes: 0,
        }
    }

    /// Enqueue a query; returns its ticket id. Footprints beyond the
    /// budget are clamped so an oversized scan degrades to "runs
    /// alone" instead of waiting forever.
    pub fn arrive(&mut self, priority: i64, bytes: usize) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.waiting.push(Ticket {
            seq,
            priority,
            bytes: bytes.min(self.capacity),
            bypassed: 0,
        });
        seq
    }

    /// The only ticket eligible for admission right now: the oldest
    /// starved ticket if any (its bypass budget is spent), otherwise
    /// the highest-priority ticket, oldest first within a class.
    pub fn candidate(&self) -> Option<u64> {
        if self.waiting.is_empty() {
            return None;
        }
        let starved = self
            .waiting
            .iter()
            .filter(|t| t.bypassed >= self.bypass_limit)
            .min_by_key(|t| t.seq);
        if let Some(t) = starved {
            return Some(t.seq);
        }
        self.waiting
            .iter()
            .max_by_key(|t| (t.priority, std::cmp::Reverse(t.seq)))
            .map(|t| t.seq)
    }

    /// Bytes a waiting ticket asked for.
    pub fn bytes_of(&self, ticket: u64) -> Option<usize> {
        self.waiting.iter().find(|t| t.seq == ticket).map(|t| t.bytes)
    }

    /// Would the candidate fit under the budget right now?
    pub fn candidate_fits(&self) -> bool {
        match self.candidate().and_then(|c| self.bytes_of(c)) {
            Some(b) => self.admitted_bytes + b <= self.capacity,
            None => false,
        }
    }

    /// Commit an admission decided elsewhere (the controller, after
    /// its governor reservation succeeded). `ticket` MUST be the
    /// current candidate — admitting anything else would break the
    /// head-of-line guarantee, so this panics in debug builds.
    pub fn commit(&mut self, ticket: u64) {
        debug_assert_eq!(self.candidate(), Some(ticket), "admitting a non-candidate");
        let idx = self
            .waiting
            .iter()
            .position(|t| t.seq == ticket)
            .expect("commit of unknown ticket");
        let t = self.waiting.remove(idx);
        // Every older waiter was just overtaken. None of them can be
        // at the limit already (a starved older ticket would itself
        // have been the candidate), so bypassed never exceeds the
        // limit.
        for w in self.waiting.iter_mut().filter(|w| w.seq < t.seq) {
            w.bypassed += 1;
        }
        self.admitted_bytes += t.bytes;
        self.admitted.insert(t.seq, t.bytes);
    }

    /// Admit the candidate if it fits; pure-path equivalent of the
    /// controller's reserve-then-commit. Returns the admitted ticket.
    pub fn try_admit(&mut self) -> Option<u64> {
        if !self.candidate_fits() {
            return None;
        }
        let c = self.candidate()?;
        self.commit(c);
        Some(c)
    }

    /// Query finished: return its bytes to the budget.
    pub fn release(&mut self, ticket: u64) {
        if let Some(b) = self.admitted.remove(&ticket) {
            self.admitted_bytes -= b;
        }
    }

    /// Abandon a waiting ticket (admission timeout).
    pub fn cancel(&mut self, ticket: u64) {
        self.waiting.retain(|t| t.seq != ticket);
    }

    pub fn admitted_bytes(&self) -> usize {
        self.admitted_bytes
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// `(seq, priority, bypassed)` of every waiter — test
    /// introspection for the fairness invariants.
    pub fn waiting_snapshot(&self) -> Vec<(u64, i64, usize)> {
        self.waiting.iter().map(|t| (t.seq, t.priority, t.bypassed)).collect()
    }
}

struct CtrlState {
    queue: AdmissionQueue,
    /// Admissions decided but not yet collected by their submitter:
    /// ticket -> the governor reservation backing it.
    ready: HashMap<u64, Reservation>,
}

struct CtrlInner {
    state: OrderedMutex<CtrlState>,
    cv: OrderedCondvar,
    governor: MemoryGovernor,
    metrics: Arc<Metrics>,
}

/// Blocking front of the admission queue. Each admitted query holds a
/// [`Reservation`] against a dedicated governor sized at the gateway's
/// admission budget, so `governor.reserved() <= capacity` *is* the
/// admission bound — the same RAII discipline the workers use for
/// operator memory, applied one level up.
#[derive(Clone)]
pub struct AdmissionController {
    inner: Arc<CtrlInner>,
}

/// RAII admission: holds the ticket and its reservation for the
/// query's whole execution; dropping it (success or error) returns
/// the bytes and wakes waiting submitters.
pub struct AdmissionGrant {
    inner: Arc<CtrlInner>,
    ticket: u64,
    reservation: Option<Reservation>,
}

impl AdmissionGrant {
    /// Bytes this admission holds against the budget.
    pub fn bytes(&self) -> usize {
        self.reservation.as_ref().map(|r| r.bytes()).unwrap_or(0)
    }
}

impl Drop for AdmissionGrant {
    fn drop(&mut self) {
        let mut st = self.inner.state.lock();
        st.queue.release(self.ticket);
        // Release the governor bytes while still holding the queue
        // lock so a waiter pumped by notify sees both books balanced
        // (admission.state 100 -> governor.reserved 300, a declared
        // descent).
        drop(self.reservation.take());
        if self.inner.pump(&mut st) {
            self.inner.cv.notify_all(&st);
        }
    }
}

impl CtrlInner {
    /// Admit every candidate that now fits. Returns true if anything
    /// became ready (callers notify outside the lock).
    fn pump(&self, st: &mut CtrlState) -> bool {
        let mut any = false;
        while st.queue.candidate_fits() {
            let c = st.queue.candidate().expect("fits implies candidate");
            let bytes = st.queue.bytes_of(c).expect("candidate has bytes");
            let Some(r) = self.governor.try_reserve(bytes) else {
                // Dedicated governor disagrees with queue accounting —
                // only possible if someone reserved against it out of
                // band. Stop pumping; the next release retries.
                break;
            };
            st.queue.commit(c);
            st.ready.insert(c, r);
            let g = self.metrics.gauge("gateway.admission_peak_bytes");
            let now = self.governor.reserved() as i64;
            if now > g.get() {
                g.set(now);
            }
            any = true;
        }
        any
    }
}

impl AdmissionController {
    /// `capacity` = admission budget in bytes (the gateway passes
    /// `admission_capacity_bytes`, or `device_capacity` when 0);
    /// `bypass_limit` = starvation bound.
    pub fn new(capacity: usize, bypass_limit: usize, metrics: Arc<Metrics>) -> AdmissionController {
        let capacity = capacity.max(1);
        AdmissionController {
            inner: Arc::new(CtrlInner {
                state: OrderedMutex::new(
                    ranks::ADMISSION_STATE,
                    "admission.state",
                    CtrlState {
                        queue: AdmissionQueue::new(capacity, bypass_limit),
                        ready: HashMap::new(),
                    },
                ),
                cv: OrderedCondvar::new(),
                governor: MemoryGovernor::new(DeviceArena::new(capacity)),
                metrics,
            }),
        }
    }

    /// Block until admitted or `timeout` elapses. On timeout the
    /// ticket is withdrawn and the caller gets the same
    /// [`Error::ReservationTimeout`] shape operators see, with tier
    /// `"admission"` so callers can tell entry pressure from
    /// execution pressure (it is retryable).
    pub fn admit(&self, priority: i64, bytes: usize, timeout: Duration) -> Result<AdmissionGrant> {
        let start = Instant::now();
        let deadline = start + timeout;
        let inner = &self.inner;
        let mut st = inner.state.lock();
        let ticket = st.queue.arrive(priority, bytes);
        if inner.pump(&mut st) {
            inner.cv.notify_all(&st);
        }
        if !st.ready.contains_key(&ticket) {
            inner.metrics.counter("gateway.queued").inc();
        }
        loop {
            if let Some(r) = st.ready.remove(&ticket) {
                drop(st);
                inner.metrics.counter("gateway.admitted").inc();
                inner
                    .metrics
                    .histogram("gateway.admission_wait_ms")
                    .record(start.elapsed());
                return Ok(AdmissionGrant {
                    inner: inner.clone(),
                    ticket,
                    reservation: Some(r),
                });
            }
            let now = Instant::now();
            if now >= deadline {
                st.queue.cancel(ticket);
                return Err(Error::ReservationTimeout {
                    requested: bytes,
                    tier: "admission",
                    waited_ms: start.elapsed().as_millis() as u64,
                });
            }
            let chunk = WAIT_CHUNK.min(deadline - now);
            let (guard, _) = inner.cv.wait_timeout(st, chunk);
            st = guard;
            // A grant may have been released without pumping our
            // ticket in (capacity freed but notify raced): pump here
            // so progress never depends on who woke first.
            if inner.pump(&mut st) {
                inner.cv.notify_all(&st);
            }
        }
    }

    /// Aggregate bytes currently held by admitted queries — backed by
    /// the governor, not the queue's shadow accounting.
    pub fn reserved_bytes(&self) -> usize {
        self.inner.governor.reserved()
    }

    /// Admission budget.
    pub fn capacity(&self) -> usize {
        self.inner.state.lock().queue.capacity()
    }

    /// Queries waiting for admission right now.
    pub fn waiting(&self) -> usize {
        self.inner.state.lock().queue.waiting_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_class_and_priority_across() {
        let mut q = AdmissionQueue::new(100, 4);
        let a = q.arrive(0, 10);
        let b = q.arrive(0, 10);
        let hi = q.arrive(5, 10);
        // higher class first, then FIFO within class 0
        assert_eq!(q.try_admit(), Some(hi));
        assert_eq!(q.try_admit(), Some(a));
        assert_eq!(q.try_admit(), Some(b));
        assert_eq!(q.try_admit(), None);
        assert_eq!(q.admitted_bytes(), 30);
        q.release(a);
        assert_eq!(q.admitted_bytes(), 20);
    }

    #[test]
    fn head_of_line_blocks_small_fits() {
        let mut q = AdmissionQueue::new(100, 4);
        let big = q.arrive(0, 90);
        let small = q.arrive(0, 10);
        assert_eq!(q.try_admit(), Some(big));
        let big2 = q.arrive(0, 90);
        // small is older than big2, same class: small is the candidate
        // and fits in the remaining 10 bytes.
        assert_eq!(q.candidate(), Some(small));
        assert_eq!(q.try_admit(), Some(small));
        // big2 doesn't fit (90 + 100 > 100): nothing admitted, and no
        // later arrival may slip past it within its class.
        let small2 = q.arrive(0, 1);
        assert_eq!(q.candidate(), Some(big2));
        assert_eq!(q.try_admit(), None, "strict head-of-line");
        q.release(big);
        q.release(small);
        assert_eq!(q.try_admit(), Some(big2));
        assert_eq!(q.try_admit(), Some(small2));
    }

    #[test]
    fn starvation_bound_promotes_bypassed_ticket() {
        let limit = 2;
        let mut q = AdmissionQueue::new(100, limit);
        let low = q.arrive(0, 10);
        // high-priority arrivals keep overtaking low...
        for i in 0..limit {
            let hi = q.arrive(9, 10);
            assert_eq!(q.try_admit(), Some(hi), "round {i}");
            q.release(hi);
        }
        // ...until its bypass budget is spent: now it is the head and
        // even a fresh priority-9 arrival cannot pass it.
        let snap = q.waiting_snapshot();
        assert_eq!(snap, vec![(low, 0, limit)]);
        let hi = q.arrive(9, 10);
        assert_eq!(q.candidate(), Some(low));
        assert_eq!(q.try_admit(), Some(low));
        assert_eq!(q.try_admit(), Some(hi));
    }

    #[test]
    fn oversized_footprint_clamped_to_capacity() {
        let mut q = AdmissionQueue::new(50, 4);
        let huge = q.arrive(0, usize::MAX);
        assert_eq!(q.bytes_of(huge), Some(50));
        assert_eq!(q.try_admit(), Some(huge), "oversized query runs alone");
        assert_eq!(q.admitted_bytes(), 50);
    }

    #[test]
    fn controller_admits_within_budget_and_blocks_overflow() {
        let m = Arc::new(Metrics::default());
        let ctl = AdmissionController::new(100, 4, m.clone());
        let g1 = ctl.admit(0, 60, Duration::from_secs(1)).unwrap();
        assert_eq!(g1.bytes(), 60);
        assert_eq!(ctl.reserved_bytes(), 60);
        // 60 + 60 > 100: second admission must time out
        let err = ctl.admit(0, 60, Duration::from_millis(50)).unwrap_err();
        match err {
            Error::ReservationTimeout { tier, requested, .. } => {
                assert_eq!(tier, "admission");
                assert_eq!(requested, 60);
            }
            e => panic!("unexpected error: {e}"),
        }
        assert!(err.is_retryable());
        assert_eq!(m.counter_value("gateway.queued"), 1);
        assert_eq!(m.counter_value("gateway.admitted"), 1);
        // budget frees on drop; next admit is immediate
        drop(g1);
        assert_eq!(ctl.reserved_bytes(), 0);
        let g2 = ctl.admit(0, 100, Duration::from_millis(50)).unwrap();
        assert_eq!(ctl.reserved_bytes(), 100);
        assert!(m.gauge_value("gateway.admission_peak_bytes") >= 100);
        drop(g2);
    }

    #[test]
    fn controller_hands_freed_budget_to_waiter() {
        let m = Arc::new(Metrics::default());
        let ctl = AdmissionController::new(100, 4, m.clone());
        let g1 = ctl.admit(0, 80, Duration::from_secs(1)).unwrap();
        let ctl2 = ctl.clone();
        let waiter = std::thread::spawn(move || {
            ctl2.admit(0, 80, Duration::from_secs(5)).map(|g| g.bytes())
        });
        // let the waiter queue up, then free the budget
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(ctl.waiting(), 1);
        drop(g1);
        assert_eq!(waiter.join().unwrap().unwrap(), 80);
        assert_eq!(m.counter_value("gateway.admitted"), 2);
        assert_eq!(m.counter_value("gateway.queued"), 1);
        assert!(m.histogram("gateway.admission_wait_ms").count() >= 2);
    }

    #[test]
    fn session_opts_defaults_match_single_query_behavior() {
        let o = SessionOpts::default();
        assert_eq!((o.weight, o.priority), (1, 0));
        assert!(o.timeout.is_none());
        let s = QuerySession::new(7, &o, Duration::from_secs(300));
        assert_eq!(s.qid, 7);
        assert_eq!(s.weight, 1);
        // weight is clamped up so it can never zero out the bonus
        let s = QuerySession::new(8, &SessionOpts { weight: -3, ..o }, Duration::from_secs(1));
        assert_eq!(s.weight, 1);
    }
}
