//! Record batches: the unit of data flow through the DAG (§3.1 — "a
//! batch is a slice of all data that will flow through the operator,
//! represented by a set of columns with the same number of rows").

use crate::types::schema::{DType, Schema};
use crate::util::bytes::{as_bytes, from_bytes, Reader};
use crate::{Error, Result};

/// Physical column storage. All i64-backed logical types (int, decimal,
/// date, dict code) share `I64` so device kernels see two layouts only.
#[derive(Clone, Debug, PartialEq)]
pub enum ColumnData {
    I64(Vec<i64>),
    F32(Vec<f32>),
    F64(Vec<f64>),
}

impl ColumnData {
    pub fn len(&self) -> usize {
        match self {
            ColumnData::I64(v) => v.len(),
            ColumnData::F32(v) => v.len(),
            ColumnData::F64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        match self {
            ColumnData::I64(v) => v.len() * 8,
            ColumnData::F32(v) => v.len() * 4,
            ColumnData::F64(v) => v.len() * 8,
        }
    }

    pub fn as_i64(&self) -> Result<&[i64]> {
        match self {
            ColumnData::I64(v) => Ok(v),
            _ => Err(Error::internal("column is not i64-backed")),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            ColumnData::F32(v) => Ok(v),
            _ => Err(Error::internal("column is not f32-backed")),
        }
    }

    pub fn as_f64(&self) -> Result<&[f64]> {
        match self {
            ColumnData::F64(v) => Ok(v),
            _ => Err(Error::internal("column is not f64-backed")),
        }
    }

    /// Gather rows by index (the host-side compaction after a device
    /// filter mask; memory-bound by design — see kernels/filter.py).
    pub fn gather(&self, idx: &[u32]) -> ColumnData {
        match self {
            ColumnData::I64(v) => {
                ColumnData::I64(idx.iter().map(|&i| v[i as usize]).collect())
            }
            ColumnData::F32(v) => {
                ColumnData::F32(idx.iter().map(|&i| v[i as usize]).collect())
            }
            ColumnData::F64(v) => {
                ColumnData::F64(idx.iter().map(|&i| v[i as usize]).collect())
            }
        }
    }

    /// Gather rows of `other` by index and append them here — the
    /// scatter half of the coalescing exchange, without the
    /// intermediate per-fragment column allocation `gather` + `append`
    /// would pay per destination.
    pub fn append_gather(&mut self, other: &ColumnData, idx: &[u32]) -> Result<()> {
        match (self, other) {
            (ColumnData::I64(a), ColumnData::I64(b)) => {
                a.extend(idx.iter().map(|&i| b[i as usize]))
            }
            (ColumnData::F32(a), ColumnData::F32(b)) => {
                a.extend(idx.iter().map(|&i| b[i as usize]))
            }
            (ColumnData::F64(a), ColumnData::F64(b)) => {
                a.extend(idx.iter().map(|&i| b[i as usize]))
            }
            _ => return Err(Error::internal("append_gather: column layout mismatch")),
        }
        Ok(())
    }

    pub fn slice(&self, off: usize, len: usize) -> ColumnData {
        match self {
            ColumnData::I64(v) => ColumnData::I64(v[off..off + len].to_vec()),
            ColumnData::F32(v) => ColumnData::F32(v[off..off + len].to_vec()),
            ColumnData::F64(v) => ColumnData::F64(v[off..off + len].to_vec()),
        }
    }

    pub fn append(&mut self, other: &ColumnData) -> Result<()> {
        match (self, other) {
            (ColumnData::I64(a), ColumnData::I64(b)) => a.extend_from_slice(b),
            (ColumnData::F32(a), ColumnData::F32(b)) => a.extend_from_slice(b),
            (ColumnData::F64(a), ColumnData::F64(b)) => a.extend_from_slice(b),
            _ => return Err(Error::internal("append: column layout mismatch")),
        }
        Ok(())
    }

    fn layout_tag(&self) -> u8 {
        match self {
            ColumnData::I64(_) => 0,
            ColumnData::F32(_) => 1,
            ColumnData::F64(_) => 2,
        }
    }

    pub fn raw_bytes(&self) -> &[u8] {
        match self {
            ColumnData::I64(v) => as_bytes(v),
            ColumnData::F32(v) => as_bytes(v),
            ColumnData::F64(v) => as_bytes(v),
        }
    }

    pub fn from_raw(tag: u8, bytes: &[u8]) -> Result<ColumnData> {
        Ok(match tag {
            0 => ColumnData::I64(from_bytes(bytes)?),
            1 => ColumnData::F32(from_bytes(bytes)?),
            2 => ColumnData::F64(from_bytes(bytes)?),
            _ => return Err(Error::Format(format!("bad layout tag {tag}"))),
        })
    }

    /// Storage layout for a logical dtype.
    pub fn layout_for(dtype: DType) -> u8 {
        match dtype {
            DType::Float32 => 1,
            DType::Float64 => 2,
            _ => 0,
        }
    }
}

/// A named column.
#[derive(Clone, Debug, PartialEq)]
pub struct Column {
    pub name: String,
    pub dtype: DType,
    pub data: ColumnData,
}

impl Column {
    pub fn new(name: impl Into<String>, dtype: DType, data: ColumnData) -> Self {
        Column { name: name.into(), dtype, data }
    }

    pub fn i64(name: impl Into<String>, v: Vec<i64>) -> Self {
        Column::new(name, DType::Int64, ColumnData::I64(v))
    }

    pub fn f32(name: impl Into<String>, v: Vec<f32>) -> Self {
        Column::new(name, DType::Float32, ColumnData::F32(v))
    }

    pub fn f64(name: impl Into<String>, v: Vec<f64>) -> Self {
        Column::new(name, DType::Float64, ColumnData::F64(v))
    }

    pub fn decimal(name: impl Into<String>, scaled: Vec<i64>) -> Self {
        Column::new(name, DType::Decimal, ColumnData::I64(scaled))
    }

    pub fn date(name: impl Into<String>, days: Vec<i64>) -> Self {
        Column::new(name, DType::Date, ColumnData::I64(days))
    }

    pub fn dict(name: impl Into<String>, codes: Vec<i64>) -> Self {
        Column::new(name, DType::Dict, ColumnData::I64(codes))
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Equal-length columns + row count. The fundamental dataflow unit.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct RecordBatch {
    pub columns: Vec<Column>,
    rows: usize,
}

impl RecordBatch {
    pub fn new(columns: Vec<Column>) -> Result<Self> {
        let rows = columns.first().map_or(0, |c| c.len());
        for c in &columns {
            if c.len() != rows {
                return Err(Error::internal(format!(
                    "ragged batch: column '{}' has {} rows, expected {}",
                    c.name,
                    c.len(),
                    rows
                )));
            }
        }
        Ok(RecordBatch { columns, rows })
    }

    pub fn empty() -> Self {
        RecordBatch::default()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    /// Total payload bytes (feeds batch-holder accounting and the
    /// exchange's size estimation).
    pub fn byte_size(&self) -> usize {
        self.columns.iter().map(|c| c.data.byte_len()).sum()
    }

    pub fn column(&self, name: &str) -> Result<&Column> {
        self.columns
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| Error::Plan(format!("no column named '{name}' in batch")))
    }

    pub fn column_idx(&self, i: usize) -> &Column {
        &self.columns[i]
    }

    /// Keep only the rows whose mask entry is non-zero.
    pub fn compact(&self, mask: &[i32]) -> Result<RecordBatch> {
        if mask.len() < self.rows {
            return Err(Error::internal("mask shorter than batch"));
        }
        let idx: Vec<u32> = (0..self.rows as u32)
            .filter(|&i| mask[i as usize] != 0)
            .collect();
        self.take(&idx)
    }

    /// Gather rows by index.
    pub fn take(&self, idx: &[u32]) -> Result<RecordBatch> {
        let columns = self
            .columns
            .iter()
            .map(|c| Column::new(c.name.clone(), c.dtype, c.data.gather(idx)))
            .collect();
        RecordBatch::new(columns)
    }

    /// Contiguous row range.
    pub fn slice(&self, off: usize, len: usize) -> Result<RecordBatch> {
        if off + len > self.rows {
            return Err(Error::internal("slice out of bounds"));
        }
        let columns = self
            .columns
            .iter()
            .map(|c| Column::new(c.name.clone(), c.dtype, c.data.slice(off, len)))
            .collect();
        RecordBatch::new(columns)
    }

    /// Vertically concatenate batches with identical layouts.
    pub fn concat(batches: &[RecordBatch]) -> Result<RecordBatch> {
        let mut it = batches.iter().filter(|b| !b.is_empty());
        let first = match it.next() {
            Some(b) => b.clone(),
            None => return Ok(RecordBatch::empty()),
        };
        let mut cols = first.columns;
        let mut rows = first.rows;
        for b in it {
            if b.columns.len() != cols.len() {
                return Err(Error::internal("concat: column count mismatch"));
            }
            for (a, c) in cols.iter_mut().zip(&b.columns) {
                a.data.append(&c.data)?;
            }
            rows += b.rows;
        }
        for c in &mut cols {
            debug_assert_eq!(c.len(), rows);
        }
        RecordBatch::new(cols)
    }

    /// Project columns by name, in order.
    pub fn project(&self, names: &[&str]) -> Result<RecordBatch> {
        let columns = names
            .iter()
            .map(|n| self.column(n).cloned())
            .collect::<Result<Vec<_>>>()?;
        RecordBatch::new(columns)
    }

    /// Split into chunks of at most `chunk_rows` rows (operator batch
    /// sizing, §3.1). Takes `self` by value: the common already-small
    /// batch returns itself without deep-cloning every column.
    pub fn split(self, chunk_rows: usize) -> Vec<RecordBatch> {
        if self.rows <= chunk_rows {
            return vec![self];
        }
        let mut out = Vec::with_capacity(self.rows.div_ceil(chunk_rows));
        let mut off = 0;
        while off < self.rows {
            let len = chunk_rows.min(self.rows - off);
            out.push(self.slice(off, len).expect("in-bounds"));
            off += len;
        }
        out
    }

    // ---------------------------------------------------------------- IPC

    /// Exact [`RecordBatch::encode`] output size — lets slab-native
    /// callers reserve pool buffers up front (all-or-nothing, so a dry
    /// pool fails before any byte is staged).
    pub fn encoded_len(&self) -> usize {
        let mut n = 4 + 8; // column count + row count
        for c in &self.columns {
            // name (u32 len + bytes), dtype tag, layout tag,
            // payload (u64 len + raw bytes)
            n += 4 + c.name.len() + 1 + 1 + 8 + c.data.raw_bytes().len();
        }
        n
    }

    /// Stream the wire encoding into any writer — byte-identical to
    /// [`RecordBatch::encode`] (which delegates here). The coalescing
    /// exchange encodes straight into a `SlabWriter`, so shuffled bytes
    /// land in pinned pool buffers without a heap bounce `Vec`.
    pub fn encode_into(&self, w: &mut impl std::io::Write) -> std::io::Result<()> {
        w.write_all(&(self.columns.len() as u32).to_le_bytes())?;
        w.write_all(&(self.rows as u64).to_le_bytes())?;
        for c in &self.columns {
            w.write_all(&(c.name.len() as u32).to_le_bytes())?;
            w.write_all(c.name.as_bytes())?;
            w.write_all(&[c.dtype.tag(), c.data.layout_tag()])?;
            let raw = c.data.raw_bytes();
            w.write_all(&(raw.len() as u64).to_le_bytes())?;
            w.write_all(raw)?;
        }
        Ok(())
    }

    /// Serialize for spill files and network frames.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut buf).expect("Vec write is infallible");
        buf
    }

    pub fn decode(buf: &[u8]) -> Result<RecordBatch> {
        let mut r = Reader::new(buf);
        let ncols = r.u32()? as usize;
        let rows = r.u64()? as usize;
        let mut columns = Vec::with_capacity(ncols);
        for _ in 0..ncols {
            let name = r.str()?;
            let dtype = DType::from_tag(r.u8()?)?;
            let tag = r.u8()?;
            // the encoder always writes layout_for(dtype); a frame that
            // disagrees is corrupt (or hostile) and must be rejected at
            // the boundary — a dtype/storage mismatch deeper in the
            // engine (builder appends, kernels) is unrecoverable
            if tag != ColumnData::layout_for(dtype) {
                return Err(Error::Format(format!(
                    "column '{name}': layout tag {tag} does not match dtype {dtype}"
                )));
            }
            let data = ColumnData::from_raw(tag, r.bytes()?)?;
            if data.len() != rows {
                return Err(Error::Format(format!(
                    "column '{}' decoded {} rows, header says {}",
                    name,
                    data.len(),
                    rows
                )));
            }
            columns.push(Column::new(name, dtype, data));
        }
        RecordBatch::new(columns)
    }

    /// Schema view of this batch (dictionaries are not carried on
    /// batches; they live in the table schema).
    pub fn schema_shape(&self) -> Schema {
        Schema::new(
            self.columns
                .iter()
                .map(|c| crate::types::schema::Field::new(c.name.clone(), c.dtype))
                .collect(),
        )
    }
}

/// Append-only batch accumulator: the per-destination coalescing buffer
/// of the shuffle write path (§3.4 — move fewer, bigger messages).
///
/// Scattered row sets from many small input batches append into one
/// growing set of column vectors; [`BatchBuilder::finish`] seals the
/// accumulated rows as a single `RecordBatch` and resets the builder
/// for the next fill. Layout (column names, dtypes, physical storage)
/// is pinned by the first append; later appends with a different
/// layout are rejected rather than silently misaligned.
#[derive(Default)]
pub struct BatchBuilder {
    columns: Vec<Column>,
    rows: usize,
}

impl BatchBuilder {
    pub fn new() -> BatchBuilder {
        BatchBuilder::default()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Accumulated payload bytes (drives the exchange's flush
    /// threshold).
    pub fn byte_size(&self) -> usize {
        self.columns.iter().map(|c| c.data.byte_len()).sum()
    }

    fn check_layout(&self, batch: &RecordBatch) -> Result<()> {
        if self.columns.len() != batch.columns.len() {
            return Err(Error::internal(format!(
                "builder append: {} columns, batch has {}",
                self.columns.len(),
                batch.columns.len()
            )));
        }
        for (a, b) in self.columns.iter().zip(&batch.columns) {
            // physical layout included: a name+dtype match with a
            // different ColumnData variant would error mid-append and
            // leave the builder's columns at unequal lengths (a later
            // finish() would panic) — reject before mutating anything
            if a.name != b.name
                || a.dtype != b.dtype
                || a.data.layout_tag() != b.data.layout_tag()
            {
                return Err(Error::internal(format!(
                    "builder append: column '{}:{}' vs '{}:{}'",
                    a.name, a.dtype, b.name, b.dtype
                )));
            }
        }
        Ok(())
    }

    /// Append rows `idx` of `batch` (gather + append in one pass, no
    /// per-fragment intermediate batch).
    pub fn append_gather(&mut self, batch: &RecordBatch, idx: &[u32]) -> Result<()> {
        if idx.is_empty() {
            return Ok(());
        }
        if self.columns.is_empty() && self.rows == 0 {
            self.columns = batch
                .columns
                .iter()
                .map(|c| Column::new(c.name.clone(), c.dtype, c.data.gather(idx)))
                .collect();
        } else {
            self.check_layout(batch)?;
            for (a, b) in self.columns.iter_mut().zip(&batch.columns) {
                a.data.append_gather(&b.data, idx)?;
            }
        }
        self.rows += idx.len();
        Ok(())
    }

    /// Append every row of `batch`.
    pub fn append_batch(&mut self, batch: &RecordBatch) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        if self.columns.is_empty() && self.rows == 0 {
            self.columns = batch.columns.clone();
        } else {
            self.check_layout(batch)?;
            for (a, b) in self.columns.iter_mut().zip(&batch.columns) {
                a.data.append(&b.data)?;
            }
        }
        self.rows += batch.rows();
        Ok(())
    }

    /// Seal the accumulated rows and reset for the next fill.
    pub fn finish(&mut self) -> RecordBatch {
        let columns = std::mem::take(&mut self.columns);
        self.rows = 0;
        RecordBatch::new(columns).expect("builder columns stay equal length")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RecordBatch {
        RecordBatch::new(vec![
            Column::i64("k", vec![1, 2, 3, 4, 5]),
            Column::f32("v", vec![0.5, 1.5, 2.5, 3.5, 4.5]),
            Column::decimal("d", vec![100, 200, 300, 400, 500]),
        ])
        .unwrap()
    }

    #[test]
    fn ragged_rejected() {
        let r = RecordBatch::new(vec![
            Column::i64("a", vec![1, 2]),
            Column::i64("b", vec![1]),
        ]);
        assert!(r.is_err());
    }

    #[test]
    fn compact_by_mask() {
        let b = sample();
        let out = b.compact(&[1, 0, 1, 0, 1]).unwrap();
        assert_eq!(out.rows(), 3);
        assert_eq!(out.column("k").unwrap().data.as_i64().unwrap(), &[1, 3, 5]);
        assert_eq!(out.column("v").unwrap().data.as_f32().unwrap(), &[0.5, 2.5, 4.5]);
    }

    #[test]
    fn slice_and_concat_roundtrip() {
        let b = sample();
        let a = b.slice(0, 2).unwrap();
        let c = b.slice(2, 3).unwrap();
        let whole = RecordBatch::concat(&[a, c]).unwrap();
        assert_eq!(whole, b);
    }

    #[test]
    fn split_sizes() {
        let b = sample();
        let parts = b.clone().split(2);
        assert_eq!(parts.iter().map(|p| p.rows()).collect::<Vec<_>>(), vec![2, 2, 1]);
        assert_eq!(RecordBatch::concat(&parts).unwrap(), b);
        // single-chunk split hands the batch back, no copy
        let whole = b.clone().split(10);
        assert_eq!(whole.len(), 1);
        assert_eq!(whole[0], b);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let b = sample();
        let buf = b.encode();
        let got = RecordBatch::decode(&buf).unwrap();
        assert_eq!(got, b);
    }

    #[test]
    fn byte_size_counts_payload() {
        let b = sample();
        assert_eq!(b.byte_size(), 5 * 8 + 5 * 4 + 5 * 8);
    }

    #[test]
    fn project_reorders() {
        let b = sample();
        let p = b.project(&["v", "k"]).unwrap();
        assert_eq!(p.columns[0].name, "v");
        assert_eq!(p.num_columns(), 2);
    }

    #[test]
    fn encode_into_matches_encode_and_encoded_len() {
        for b in [sample(), RecordBatch::empty(), sample().slice(0, 0).unwrap()] {
            let via_vec = b.encode();
            assert_eq!(via_vec.len(), b.encoded_len());
            let mut streamed = Vec::new();
            b.encode_into(&mut streamed).unwrap();
            assert_eq!(streamed, via_vec);
            assert_eq!(RecordBatch::decode(&streamed).unwrap(), b);
        }
    }

    #[test]
    fn builder_accumulates_scattered_rows() {
        let b = sample();
        let mut builder = BatchBuilder::new();
        assert!(builder.is_empty());
        builder.append_gather(&b, &[4, 0]).unwrap();
        builder.append_gather(&b, &[]).unwrap(); // no-op
        builder.append_gather(&b, &[2]).unwrap();
        assert_eq!(builder.rows(), 3);
        assert_eq!(builder.byte_size(), 3 * (8 + 4 + 8));
        let got = builder.finish();
        assert_eq!(got.column("k").unwrap().data.as_i64().unwrap(), &[5, 1, 3]);
        assert_eq!(got.column("v").unwrap().data.as_f32().unwrap(), &[4.5, 0.5, 2.5]);
        // the builder reset: a fresh fill starts from scratch
        assert!(builder.is_empty());
        builder.append_batch(&b).unwrap();
        assert_eq!(builder.finish(), b);
    }

    #[test]
    fn builder_rejects_layout_drift() {
        let b = sample();
        let mut builder = BatchBuilder::new();
        builder.append_gather(&b, &[0]).unwrap();
        let other =
            RecordBatch::new(vec![Column::i64("different", vec![1, 2])]).unwrap();
        assert!(builder.append_gather(&other, &[0]).is_err());
        assert!(builder.append_batch(&other).is_err());
        assert_eq!(builder.rows(), 1, "failed appends leave the fill intact");
    }

    #[test]
    fn decode_rejects_corrupt_rowcount() {
        let b = sample();
        let mut buf = b.encode();
        // corrupt the row-count field
        buf[4] = 99;
        assert!(RecordBatch::decode(&buf).is_err());
    }

    #[test]
    fn decode_rejects_layout_dtype_mismatch() {
        // column 'k' (Int64) with its layout byte flipped to F64 —
        // same element width, so only the cross-check can catch it
        let b = sample();
        let mut buf = b.encode();
        // layout: ncols(4) + rows(8) + name len(4) + "k"(1) + dtype(1)
        let layout_at = 4 + 8 + 4 + 1 + 1;
        assert_eq!(buf[layout_at], 0, "i64 layout tag");
        buf[layout_at] = 2; // F64 layout under an Int64 dtype
        assert!(RecordBatch::decode(&buf).is_err());
    }
}
