//! Schema: field names + dtypes, with binary serde for plan/IPC use.

use crate::util::bytes::{Reader, Writer};
use crate::{Error, Result};

/// Physical column type.
///
/// `Decimal` values are stored as i64 scaled by 100 (the paper's inputs
/// are precision-11/scale-2 decimals — they fit i64; the 128-bit width
/// in the paper exists for generality, not range, at this scale).
/// `Dict` is a dictionary-encoded string column: i64 codes plus a
/// per-column dictionary in the schema.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    Int64,
    Float32,
    Float64,
    Decimal, // scaled i64 (x100)
    Date,    // days since epoch, i64
    Dict,    // dictionary code, i64
}

impl DType {
    /// Bytes per value in device/host columnar buffers.
    pub fn width(self) -> usize {
        match self {
            DType::Float32 => 4,
            _ => 8,
        }
    }

    pub fn tag(self) -> u8 {
        match self {
            DType::Int64 => 0,
            DType::Float32 => 1,
            DType::Float64 => 2,
            DType::Decimal => 3,
            DType::Date => 4,
            DType::Dict => 5,
        }
    }

    pub fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => DType::Int64,
            1 => DType::Float32,
            2 => DType::Float64,
            3 => DType::Decimal,
            4 => DType::Date,
            5 => DType::Dict,
            _ => return Err(Error::Format(format!("bad dtype tag {t}"))),
        })
    }

    /// True if the value payload is i64-backed.
    pub fn is_i64_backed(self) -> bool {
        !matches!(self, DType::Float32 | DType::Float64)
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            DType::Int64 => "i64",
            DType::Float32 => "f32",
            DType::Float64 => "f64",
            DType::Decimal => "dec(11,2)",
            DType::Date => "date",
            DType::Dict => "dict",
        };
        f.write_str(s)
    }
}

/// One column of a schema. Dictionary-encoded columns carry their
/// dictionary (code -> string) inline.
#[derive(Clone, Debug, PartialEq)]
pub struct Field {
    pub name: String,
    pub dtype: DType,
    /// For `DType::Dict`: code i -> dictionary[i].
    pub dictionary: Vec<String>,
}

impl Field {
    pub fn new(name: impl Into<String>, dtype: DType) -> Self {
        Field { name: name.into(), dtype, dictionary: Vec::new() }
    }

    pub fn dict(name: impl Into<String>, dictionary: Vec<String>) -> Self {
        Field { name: name.into(), dtype: DType::Dict, dictionary }
    }

    /// Dictionary code for `s`, if present.
    pub fn code_of(&self, s: &str) -> Option<i64> {
        self.dictionary.iter().position(|d| d == s).map(|i| i as i64)
    }
}

/// Ordered field list.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Schema {
    pub fields: Vec<Field>,
}

impl Schema {
    pub fn new(fields: Vec<Field>) -> Self {
        Schema { fields }
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.fields
            .iter()
            .position(|f| f.name == name)
            .ok_or_else(|| Error::Plan(format!("no column named '{name}'")))
    }

    pub fn field(&self, name: &str) -> Result<&Field> {
        Ok(&self.fields[self.index_of(name)?])
    }

    /// Project a subset of columns (scan pushdown).
    pub fn project(&self, names: &[&str]) -> Result<Schema> {
        let fields = names
            .iter()
            .map(|n| self.field(n).cloned())
            .collect::<Result<Vec<_>>>()?;
        Ok(Schema { fields })
    }

    /// Bytes per row (used by memory estimation heuristics).
    pub fn row_width(&self) -> usize {
        self.fields.iter().map(|f| f.dtype.width()).sum()
    }

    pub fn encode(&self, w: &mut Writer) {
        w.u32(self.fields.len() as u32);
        for f in &self.fields {
            w.str(&f.name);
            w.u8(f.dtype.tag());
            w.u32(f.dictionary.len() as u32);
            for d in &f.dictionary {
                w.str(d);
            }
        }
    }

    pub fn decode(r: &mut Reader) -> Result<Schema> {
        let n = r.u32()? as usize;
        let mut fields = Vec::with_capacity(n);
        for _ in 0..n {
            let name = r.str()?;
            let dtype = DType::from_tag(r.u8()?)?;
            let nd = r.u32()? as usize;
            let mut dictionary = Vec::with_capacity(nd);
            for _ in 0..nd {
                dictionary.push(r.str()?);
            }
            fields.push(Field { name, dtype, dictionary });
        }
        Ok(Schema { fields })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Schema {
        Schema::new(vec![
            Field::new("l_orderkey", DType::Int64),
            Field::new("l_quantity", DType::Decimal),
            Field::new("l_shipdate", DType::Date),
            Field::dict("l_returnflag", vec!["A".into(), "N".into(), "R".into()]),
            Field::new("l_extendedprice", DType::Float32),
        ])
    }

    #[test]
    fn index_and_project() {
        let s = sample();
        assert_eq!(s.index_of("l_shipdate").unwrap(), 2);
        assert!(s.index_of("nope").is_err());
        let p = s.project(&["l_quantity", "l_orderkey"]).unwrap();
        assert_eq!(p.fields[0].name, "l_quantity");
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn dict_codes() {
        let s = sample();
        let f = s.field("l_returnflag").unwrap();
        assert_eq!(f.code_of("N"), Some(1));
        assert_eq!(f.code_of("X"), None);
    }

    #[test]
    fn serde_roundtrip() {
        let s = sample();
        let mut w = Writer::new();
        s.encode(&mut w);
        let buf = w.finish();
        let got = Schema::decode(&mut Reader::new(&buf)).unwrap();
        assert_eq!(got, s);
    }

    #[test]
    fn row_width_sums_dtype_widths() {
        assert_eq!(sample().row_width(), 8 + 8 + 8 + 8 + 4);
    }
}
