//! Columnar data model (Arrow-inspired, §2: "Theseus adopts Apache
//! Arrow's columnar memory model").
//!
//! A [`RecordBatch`] is a set of equal-length [`Column`]s plus a schema.
//! Strings are dictionary-encoded at generation time (predicates on
//! strings are pushed down as integer codes — the same trick the paper's
//! Calcite planner plays for the device kernels). Decimals are fixed
//! 128-bit in the paper; we carry them as scaled i64 (precision 11,
//! scale 2 fits in i64 comfortably) and document the narrowing.

pub mod batch;
pub mod schema;

pub use batch::{BatchBuilder, Column, ColumnData, RecordBatch};
pub use schema::{DType, Field, Schema};
