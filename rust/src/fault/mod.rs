//! Deterministic fault injection + the bounded-retry helper the
//! recovery paths share (see FAULTS.md for the operator-facing view).
//!
//! A process-global [`FaultInjector`] holds at most one installed
//! [`FaultPlan`]. Injection sites ([`FaultSite`]) are threaded through
//! the storage, spill, and network planes as `fault::check(site)?`
//! calls; with no plan installed the check is a single relaxed atomic
//! load — the disabled fast path adds zero I/O and zero allocation
//! (micro benches #5/#7 assert it stays invisible).
//!
//! Plans are deterministic by construction: explicit rules fire on the
//! Nth operation of a site (a per-site op counter, 1-based), and the
//! seeded mode drives a xorshift RNG from a caller-supplied seed — the
//! same plan against the same workload fires at the same ops. Every
//! firing returns [`Error::Transient`] (so the recovery ladders treat
//! injected and real transient failures identically) and is counted on
//! `fault.injected_total` plus a per-site counter.
//!
//! Install is scoped and serialized: [`install`] returns a
//! [`FaultScope`] holding a process-wide guard, so concurrent tests
//! installing plans queue instead of corrupting each other's
//! schedules; dropping the scope uninstalls the plan and re-arms the
//! no-op fast path.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::metrics::Metrics;
use crate::sync::{ranks, OrderedMutex};
use crate::{Error, Result};

/// Named injection sites — one per plane boundary the recovery
/// machinery defends.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// Object-store range read (`ObjectStore::get_range` /
    /// `get_range_into`).
    StorageGet,
    /// Object-store write (`ObjectStore::put`).
    StoragePut,
    /// Spill-segment positional read (`SpillStore` read paths).
    SpillRead,
    /// Spill-segment positional write (`SpillStore::write_vectored`
    /// attempt — fires *before* bytes land, so failover retries into a
    /// fresh segment).
    SpillWrite,
    /// Endpoint / sender-lane send (checked before the frame is
    /// consumed, so the lane can retry).
    NetSend,
    /// Endpoint receive / reader loop (a firing drops the frame —
    /// modeling loss on a dying connection).
    NetRecv,
}

impl FaultSite {
    pub const ALL: [FaultSite; 6] = [
        FaultSite::StorageGet,
        FaultSite::StoragePut,
        FaultSite::SpillRead,
        FaultSite::SpillWrite,
        FaultSite::NetSend,
        FaultSite::NetRecv,
    ];

    fn index(self) -> usize {
        match self {
            FaultSite::StorageGet => 0,
            FaultSite::StoragePut => 1,
            FaultSite::SpillRead => 2,
            FaultSite::SpillWrite => 3,
            FaultSite::NetSend => 4,
            FaultSite::NetRecv => 5,
        }
    }

    /// Stable short name (error text, jitter hashing, test plans).
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::StorageGet => "storage_get",
            FaultSite::StoragePut => "storage_put",
            FaultSite::SpillRead => "spill_read",
            FaultSite::SpillWrite => "spill_write",
            FaultSite::NetSend => "net_send",
            FaultSite::NetRecv => "net_recv",
        }
    }

    /// Per-site firing counter name (registered in
    /// [`crate::metrics::registry`]).
    pub fn metric(self) -> &'static str {
        match self {
            FaultSite::StorageGet => "fault.injected_total.storage_get",
            FaultSite::StoragePut => "fault.injected_total.storage_put",
            FaultSite::SpillRead => "fault.injected_total.spill_read",
            FaultSite::SpillWrite => "fault.injected_total.spill_write",
            FaultSite::NetSend => "fault.injected_total.net_send",
            FaultSite::NetRecv => "fault.injected_total.net_recv",
        }
    }
}

/// One explicit schedule entry: fire on ops `nth ..= nth+count-1` of
/// `site` (the per-site op counter is 1-based).
#[derive(Clone, Copy, Debug)]
struct Rule {
    site: FaultSite,
    nth: u64,
    count: u64,
}

/// Seeded random mode: each checked op fires with probability
/// `per_mille`/1000, up to `max_faults` total firings, driven by a
/// xorshift64 stream — same seed, same workload, same firings.
#[derive(Clone, Copy, Debug)]
struct Seeded {
    state: u64,
    per_mille: u64,
    max_faults: u64,
    fired: u64,
}

/// A deterministic schedule of injected transient faults.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    rules: Vec<Rule>,
    seeded: Option<Seeded>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Fire on the `nth` operation of `site` (1-based).
    pub fn fail_nth(self, site: FaultSite, nth: u64) -> FaultPlan {
        self.fail_nth_count(site, nth, 1)
    }

    /// Fire on `count` consecutive operations of `site` starting at the
    /// `nth` (1-based) — the shape that exercises bounded retry ladders.
    pub fn fail_nth_count(mut self, site: FaultSite, nth: u64, count: u64) -> FaultPlan {
        self.rules.push(Rule { site, nth: nth.max(1), count });
        self
    }

    /// Seeded random mode on top of any explicit rules: every checked
    /// op fires with probability `per_mille`/1000 until `max_faults`
    /// firings happened.
    pub fn seeded(seed: u64, per_mille: u64, max_faults: u64) -> FaultPlan {
        FaultPlan {
            rules: Vec::new(),
            seeded: Some(Seeded {
                // xorshift needs a nonzero state
                state: seed | 1,
                per_mille: per_mille.min(1000),
                max_faults,
                fired: 0,
            }),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty() && self.seeded.is_none()
    }
}

/// Installed-plan state: the plan, per-site op counters, and an
/// optional metrics sink the firings are mirrored into.
struct ActivePlan {
    plan: FaultPlan,
    ops: [u64; 6],
    metrics: Option<Arc<Metrics>>,
}

/// The process-global injector's lock pair — a struct rather than loose
/// statics so the lock-hierarchy lint can key both fields in
/// `lockorder.toml` (entries `fault.install` / `fault.state`).
struct FaultInjector {
    /// Serializes installers process-wide. Rank 10 — outermost: a
    /// [`FaultScope`] holds it across whole test bodies, so every other
    /// lock in the system must rank above it.
    install: OrderedMutex<()>,
    /// The installed plan + per-site op counters. Rank 950 — near-leaf:
    /// `check` runs under locks from every plane, so only the metrics
    /// sinks rank above it.
    state: OrderedMutex<Option<ActivePlan>>,
}

// `ENABLED` is the whole disabled fast path: one relaxed load, no lock,
// no branch on plan contents.
static ENABLED: AtomicBool = AtomicBool::new(false);
static INJECTOR: FaultInjector = FaultInjector {
    install: OrderedMutex::new(ranks::FAULT_INSTALL, "fault.install", ()),
    state: OrderedMutex::new(ranks::FAULT_STATE, "fault.state", None),
};
static INJECTED_TOTAL: AtomicU64 = AtomicU64::new(0);
static INJECTED_BY_SITE: [AtomicU64; 6] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

/// RAII scope for an installed plan: holds the process-wide install
/// guard (concurrent installers queue behind it) and uninstalls the
/// plan on drop, restoring the no-op fast path.
pub struct FaultScope {
    _guard: crate::sync::ordered::OrderedGuard<'static, ()>,
}

impl Drop for FaultScope {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
        *INJECTOR.state.lock() = None;
    }
}

/// Install `plan` for the lifetime of the returned scope. Serialized
/// process-wide: a second installer blocks until the first scope drops.
pub fn install(plan: FaultPlan) -> FaultScope {
    install_with_metrics(plan, None)
}

/// [`install`], with firings mirrored into `metrics`
/// (`fault.injected_total` + the per-site counters) so fault-suite
/// artifacts show the schedule that actually ran.
pub fn install_with_metrics(plan: FaultPlan, metrics: Option<Arc<Metrics>>) -> FaultScope {
    let guard = INJECTOR.install.lock();
    {
        let mut st = INJECTOR.state.lock();
        *st = Some(ActivePlan { plan, ops: [0; 6], metrics });
    }
    ENABLED.store(true, Ordering::SeqCst);
    FaultScope { _guard: guard }
}

/// The injection gate every site calls. With no plan installed this is
/// one relaxed atomic load. With a plan, the site's op counter advances
/// and a scheduled op returns [`Error::Transient`].
#[inline]
pub fn check(site: FaultSite) -> Result<()> {
    if !ENABLED.load(Ordering::Relaxed) {
        return Ok(());
    }
    check_slow(site)
}

#[cold]
fn check_slow(site: FaultSite) -> Result<()> {
    let mut st = INJECTOR.state.lock();
    let active = match st.as_mut() {
        Some(a) => a,
        None => return Ok(()),
    };
    let idx = site.index();
    active.ops[idx] += 1;
    let op = active.ops[idx];
    let mut fire = active
        .plan
        .rules
        .iter()
        .any(|r| r.site == site && op >= r.nth && op < r.nth + r.count);
    if !fire {
        if let Some(s) = active.plan.seeded.as_mut() {
            if s.fired < s.max_faults {
                // xorshift64
                s.state ^= s.state << 13;
                s.state ^= s.state >> 7;
                s.state ^= s.state << 17;
                if s.state % 1000 < s.per_mille {
                    s.fired += 1;
                    fire = true;
                }
            }
        }
    }
    if !fire {
        return Ok(());
    }
    INJECTED_TOTAL.fetch_add(1, Ordering::Relaxed);
    INJECTED_BY_SITE[idx].fetch_add(1, Ordering::Relaxed);
    if let Some(m) = active.metrics.as_ref() {
        m.counter("fault.injected_total").inc();
        m.counter(site.metric()).inc();
    }
    Err(Error::Transient {
        site: site.name(),
        detail: format!("injected fault at {} op {op}", site.name()),
    })
}

/// Process-lifetime count of injected faults (all sites). Benches use
/// this to assert the disabled injector stayed invisible.
pub fn injected_total() -> u64 {
    INJECTED_TOTAL.load(Ordering::Relaxed)
}

/// Process-lifetime count of injected faults at one site.
pub fn injected_for(site: FaultSite) -> u64 {
    INJECTED_BY_SITE[site.index()].load(Ordering::Relaxed)
}

// ----------------------------------------------------------- retry

/// Bounded-retry knobs for one storage-plane caller
/// (`storage_retry_limit` / `storage_backoff_base_ms`).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Max attempts per operation (so `limit - 1` retries). Values
    /// below 1 behave as 1 — a single, unretried attempt.
    pub limit: usize,
    /// Backoff base, ms: attempt `n` sleeps `base * 2^(n-1)` (capped at
    /// 32x) plus deterministic jitter. 0 retries immediately.
    pub base_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { limit: 3, base_ms: 10 }
    }
}

/// Capped exponential backoff with deterministic jitter: the delay for
/// a given (site, attempt) pair is a pure function, so a faulted run's
/// timing is reproducible.
pub fn backoff(site: &str, attempt: usize, base_ms: u64) -> Duration {
    if base_ms == 0 {
        return Duration::ZERO;
    }
    let exp = base_ms.saturating_mul(1u64 << attempt.saturating_sub(1).min(5));
    // FNV-1a over (site, attempt): jitter in [0, base_ms/2]
    let mut h: u64 = 0xcbf29ce484222325;
    for b in site.bytes().chain(attempt.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let jitter = if base_ms < 2 { 0 } else { h % (base_ms / 2) };
    Duration::from_millis(exp + jitter)
}

/// Run `op` with up to `policy.limit` attempts, retrying only
/// [`Error::is_transient`] failures, sleeping [`backoff`] between
/// attempts and counting each retry on `retry.attempts_total`. The
/// final failure propagates as-is (still transient — query-level retry
/// is the next rung of the ladder).
pub fn with_retry<T>(
    policy: RetryPolicy,
    metrics: Option<&Arc<Metrics>>,
    site: &str,
    mut op: impl FnMut() -> Result<T>,
) -> Result<T> {
    let limit = policy.limit.max(1);
    let mut attempt = 1;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if e.is_transient() && attempt < limit => {
                log::warn!("{site}: transient failure (attempt {attempt}/{limit}): {e}");
                if let Some(m) = metrics {
                    m.counter("retry.attempts_total").inc();
                }
                std::thread::sleep(backoff(site, attempt, policy.base_ms));
                attempt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    // Plan-installing tests live in `tests/fault_injection.rs` — their
    // own binary, so an installed plan can never leak faults into
    // unrelated lib tests running concurrently. Only injector-free
    // pieces (the fast path, backoff, with_retry) are tested here.
    use super::*;

    #[test]
    fn disabled_injector_is_a_no_op() {
        // no plan installed: every site passes and nothing is counted
        let before = injected_total();
        for site in FaultSite::ALL {
            assert!(check(site).is_ok());
        }
        assert_eq!(injected_total(), before);
    }

    #[test]
    fn backoff_is_deterministic_capped_and_grows() {
        let d1 = backoff("storage_get", 1, 10);
        let d2 = backoff("storage_get", 2, 10);
        assert_eq!(d1, backoff("storage_get", 1, 10), "pure function");
        assert!(d2 > d1, "exponential growth");
        let cap = backoff("storage_get", 64, 10);
        assert!(cap <= Duration::from_millis(10 * 32 + 5), "capped at 32x + jitter");
        assert_eq!(backoff("x", 3, 0), Duration::ZERO, "base 0 = no sleep");
    }

    #[test]
    fn with_retry_recovers_within_limit_and_counts() {
        let m = Arc::new(Metrics::default());
        let mut calls = 0;
        let out = with_retry(
            RetryPolicy { limit: 3, base_ms: 0 },
            Some(&m),
            "storage_get",
            || {
                calls += 1;
                if calls < 3 {
                    Err(Error::Transient { site: "storage_get", detail: "t".into() })
                } else {
                    Ok(7)
                }
            },
        );
        assert_eq!(out.unwrap(), 7);
        assert_eq!(calls, 3);
        assert_eq!(m.counter_value("retry.attempts_total"), 2);
    }

    #[test]
    fn with_retry_propagates_exhaustion_and_permanent_errors() {
        // exhausted transient: still transient on the way out
        let out: Result<()> =
            with_retry(RetryPolicy { limit: 2, base_ms: 0 }, None, "s", || {
                Err(Error::Transient { site: "s", detail: "t".into() })
            });
        assert!(out.unwrap_err().is_transient());
        // permanent errors are never retried
        let mut calls = 0;
        let out: Result<()> =
            with_retry(RetryPolicy { limit: 5, base_ms: 0 }, None, "s", || {
                calls += 1;
                Err(Error::internal("permanent"))
            });
        assert!(!out.unwrap_err().is_transient());
        assert_eq!(calls, 1, "permanent error must fail fast");
    }
}
