//! The Planner (§3): lowers a logical query tree to the distributed
//! [`PhysicalPlan`] every worker executes.
//!
//! The paper uses Apache Calcite; this is the analog for our plan
//! algebra. The distribution rules are the classic ones:
//!
//! * **Join** — both sides are hash-exchanged on their join keys so
//!   co-partitioned rows meet on one worker (the Adaptive Exchange can
//!   still decide to broadcast a small side at runtime, §3.2 — the
//!   *plan* only fixes the pairing; the *mode* is adaptive).
//! * **Aggregate** — input is hash-exchanged on the group key, then
//!   each worker aggregates its partition exactly.
//! * **Sort / Limit** — executed per worker; the Client gather-merges
//!   (re-sorts / re-limits) worker outputs.

use crate::exec::plan::{AggSpec, ExchangeRole, OpSpec, PhysicalPlan, Pred};
use crate::Result;

/// Logical query tree (what a SQL frontend would produce).
#[derive(Clone, Debug)]
pub enum Logical {
    Scan { table: String, cols: Vec<String>, pred: Option<Pred> },
    Filter { input: Box<Logical>, pred: Pred },
    Project { input: Box<Logical>, cols: Vec<String> },
    Aggregate { input: Box<Logical>, group_by: String, aggs: Vec<AggSpec> },
    Join { left: Box<Logical>, right: Box<Logical>, left_on: String, right_on: String, lip: bool },
    Sort { input: Box<Logical>, by: String, desc: bool },
    Limit { input: Box<Logical>, n: u64 },
}

impl Logical {
    // ------------------------------------------------ builder methods

    pub fn scan(table: impl Into<String>, cols: &[&str]) -> Logical {
        Logical::Scan {
            table: table.into(),
            cols: cols.iter().map(|s| s.to_string()).collect(),
            pred: None,
        }
    }

    /// Scan with a pushed-down predicate (enables row-group pruning;
    /// the filter itself still runs, exactly once, below).
    pub fn scan_where(table: impl Into<String>, cols: &[&str], pred: Pred) -> Logical {
        Logical::Scan {
            table: table.into(),
            cols: cols.iter().map(|s| s.to_string()).collect(),
            pred: Some(pred),
        }
    }

    pub fn filter(self, pred: Pred) -> Logical {
        Logical::Filter { input: Box::new(self), pred }
    }

    pub fn project(self, cols: &[&str]) -> Logical {
        Logical::Project {
            input: Box::new(self),
            cols: cols.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn aggregate(self, group_by: impl Into<String>, aggs: Vec<AggSpec>) -> Logical {
        Logical::Aggregate { input: Box::new(self), group_by: group_by.into(), aggs }
    }

    /// `self` is the build (left) side.
    pub fn join(
        self,
        right: Logical,
        left_on: impl Into<String>,
        right_on: impl Into<String>,
        lip: bool,
    ) -> Logical {
        Logical::Join {
            left: Box::new(self),
            right: Box::new(right),
            left_on: left_on.into(),
            right_on: right_on.into(),
            lip,
        }
    }

    pub fn sort(self, by: impl Into<String>, desc: bool) -> Logical {
        Logical::Sort { input: Box::new(self), by: by.into(), desc }
    }

    pub fn limit(self, n: u64) -> Logical {
        Logical::Limit { input: Box::new(self), n }
    }
}

/// The planner.
pub struct Planner {
    /// Skip exchanges entirely on single-worker clusters (they would
    /// be pure overhead; the paper's single-GPU config does the same).
    pub num_workers: usize,
    /// Enable Lookahead Information Passing on joins that ask for it.
    pub lip_enabled: bool,
}

impl Planner {
    pub fn new(num_workers: usize) -> Planner {
        Planner { num_workers, lip_enabled: true }
    }

    /// Lower a logical tree to the physical DAG.
    pub fn plan(&self, logical: &Logical) -> Result<PhysicalPlan> {
        let mut plan = PhysicalPlan::new();
        self.lower(logical, &mut plan)?;
        plan.validate()?;
        Ok(plan)
    }

    fn lower(&self, node: &Logical, plan: &mut PhysicalPlan) -> Result<usize> {
        Ok(match node {
            Logical::Scan { table, cols, pred } => plan.add(
                OpSpec::Scan {
                    table: table.clone(),
                    cols: cols.clone(),
                    pred: pred.clone(),
                },
                vec![],
            ),
            Logical::Filter { input, pred } => {
                let i = self.lower(input, plan)?;
                plan.add(OpSpec::Filter { pred: pred.clone() }, vec![i])
            }
            Logical::Project { input, cols } => {
                let i = self.lower(input, plan)?;
                plan.add(OpSpec::Project { cols: cols.clone() }, vec![i])
            }
            Logical::Aggregate { input, group_by, aggs } => {
                let mut i = self.lower(input, plan)?;
                if self.num_workers > 1 {
                    i = plan.add(
                        OpSpec::Exchange {
                            key: group_by.clone(),
                            role: ExchangeRole::Shuffle,
                        },
                        vec![i],
                    );
                }
                plan.add(
                    OpSpec::HashAgg { group_by: group_by.clone(), aggs: aggs.clone() },
                    vec![i],
                )
            }
            Logical::Join { left, right, left_on, right_on, lip } => {
                let mut l = self.lower(left, plan)?;
                let mut r = self.lower(right, plan)?;
                if self.num_workers > 1 {
                    // the paper's paired Adaptive Exchanges (§3.2): the
                    // build side may broadcast when small, in which case
                    // its probe partner passes through locally.
                    l = plan.add(
                        OpSpec::Exchange {
                            key: left_on.clone(),
                            role: ExchangeRole::Build,
                        },
                        vec![l],
                    );
                    r = plan.add(
                        OpSpec::Exchange {
                            key: right_on.clone(),
                            role: ExchangeRole::Probe { partner: l },
                        },
                        vec![r],
                    );
                }
                plan.add(
                    OpSpec::HashJoin {
                        left_on: left_on.clone(),
                        right_on: right_on.clone(),
                        lip: *lip && self.lip_enabled,
                    },
                    vec![l, r],
                )
            }
            Logical::Sort { input, by, desc } => {
                let i = self.lower(input, plan)?;
                plan.add(OpSpec::Sort { by: by.clone(), desc: *desc }, vec![i])
            }
            Logical::Limit { input, n } => {
                let i = self.lower(input, plan)?;
                plan.add(OpSpec::Limit { n: *n }, vec![i])
            }
        })
    }
}

/// Gather-merge spec: how the Client combines per-worker root outputs.
#[derive(Clone, Debug, PartialEq)]
pub enum GatherMode {
    /// Plain concatenation.
    Concat,
    /// Re-sort the concatenation (root is a Sort).
    Sort { by: String, desc: bool },
    /// Re-sort then truncate (Sort under Limit).
    SortLimit { by: String, desc: bool, n: u64 },
    /// Truncate only (root is a Limit).
    Limit { n: u64 },
}

/// Derive the gather mode from a physical plan's root.
pub fn gather_mode(plan: &PhysicalPlan) -> GatherMode {
    let nodes = &plan.nodes;
    match nodes.last().map(|n| &n.spec) {
        Some(OpSpec::Sort { by, desc }) => GatherMode::Sort { by: by.clone(), desc: *desc },
        Some(OpSpec::Limit { n }) => {
            // Limit over Sort -> SortLimit
            let input = &nodes[nodes[nodes.len() - 1].inputs[0]];
            if let OpSpec::Sort { by, desc } = &input.spec {
                GatherMode::SortLimit { by: by.clone(), desc: *desc, n: *n }
            } else {
                GatherMode::Limit { n: *n }
            }
        }
        _ => GatherMode::Concat,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::plan::AggFn;

    fn q() -> Logical {
        Logical::scan("orders", &["o_orderkey", "o_totalprice"])
            .join(
                Logical::scan("lineitem", &["l_orderkey", "l_quantity"])
                    .filter(Pred::RangeI64 { col: "l_quantity".into(), lo: 0, hi: 25 }),
                "o_orderkey",
                "l_orderkey",
                true,
            )
            .aggregate("o_orderkey", vec![AggSpec::new(AggFn::Sum, "l_quantity")])
            .sort("sum_l_quantity", true)
            .limit(10)
    }

    #[test]
    fn multiworker_plan_inserts_exchanges() {
        let plan = Planner::new(4).plan(&q()).unwrap();
        let exchanges = plan
            .nodes
            .iter()
            .filter(|n| matches!(n.spec, OpSpec::Exchange { .. }))
            .count();
        assert_eq!(exchanges, 3, "2 join sides + 1 agg:\n{}", plan.render());
    }

    #[test]
    fn single_worker_plan_has_no_exchanges() {
        let plan = Planner::new(1).plan(&q()).unwrap();
        assert!(
            !plan.nodes.iter().any(|n| matches!(n.spec, OpSpec::Exchange { .. })),
            "{}",
            plan.render()
        );
    }

    #[test]
    fn lip_flag_respects_planner_switch() {
        let mut p = Planner::new(2);
        p.lip_enabled = false;
        let plan = p.plan(&q()).unwrap();
        let lip_on = plan.nodes.iter().any(
            |n| matches!(n.spec, OpSpec::HashJoin { lip: true, .. }),
        );
        assert!(!lip_on);
    }

    #[test]
    fn gather_modes() {
        let plan = Planner::new(2).plan(&q()).unwrap();
        assert_eq!(
            gather_mode(&plan),
            GatherMode::SortLimit { by: "sum_l_quantity".into(), desc: true, n: 10 }
        );
        let plain = Planner::new(2)
            .plan(&Logical::scan("t", &["a"]))
            .unwrap();
        assert_eq!(gather_mode(&plain), GatherMode::Concat);
    }

    #[test]
    fn plans_validate_and_roundtrip() {
        for w in [1, 2, 8] {
            let plan = Planner::new(w).plan(&q()).unwrap();
            let buf = plan.encode();
            assert_eq!(PhysicalPlan::decode(&buf).unwrap(), plan);
        }
    }
}
