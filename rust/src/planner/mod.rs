//! The Planner (§3): lowers a logical query tree to the distributed
//! [`PhysicalPlan`] every worker executes.
//!
//! The paper uses Apache Calcite; this is the analog for our plan
//! algebra. The distribution rules are the classic ones:
//!
//! * **Join** — both sides are hash-exchanged on their join keys so
//!   co-partitioned rows meet on one worker (the Adaptive Exchange can
//!   still decide to broadcast a small side at runtime, §3.2 — the
//!   *plan* only fixes the pairing; the *mode* is adaptive).
//! * **Aggregate** — input is hash-exchanged on the group key, then
//!   each worker aggregates its partition exactly.
//! * **Sort / Limit** — executed per worker; the Client gather-merges
//!   (re-sorts / re-limits) worker outputs.

use std::sync::Arc;

use crate::exec::plan::{AggSpec, ExchangeRole, OpSpec, PhysicalPlan, Pred};
use crate::Result;

/// Logical query tree (what a SQL frontend would produce).
#[derive(Clone, Debug, PartialEq)]
pub enum Logical {
    Scan { table: String, cols: Vec<String>, pred: Option<Pred> },
    Filter { input: Box<Logical>, pred: Pred },
    Project { input: Box<Logical>, cols: Vec<String> },
    Aggregate { input: Box<Logical>, group_by: String, aggs: Vec<AggSpec> },
    Join { left: Box<Logical>, right: Box<Logical>, left_on: String, right_on: String, lip: bool },
    Sort { input: Box<Logical>, by: String, desc: bool },
    Limit { input: Box<Logical>, n: u64 },
    /// Cache-resident materialized subplan (see [`crate::cache`]): the
    /// encoded `RecordBatch` a scan→filter→agg frontier produced on an
    /// earlier execution. Lowered to [`OpSpec::Fragment`].
    Fragment { data: Arc<Vec<u8>> },
}

impl Logical {
    // ------------------------------------------------ builder methods

    pub fn scan(table: impl Into<String>, cols: &[&str]) -> Logical {
        Logical::Scan {
            table: table.into(),
            cols: cols.iter().map(|s| s.to_string()).collect(),
            pred: None,
        }
    }

    /// Scan with a pushed-down predicate (enables row-group pruning;
    /// the filter itself still runs, exactly once, below).
    pub fn scan_where(table: impl Into<String>, cols: &[&str], pred: Pred) -> Logical {
        Logical::Scan {
            table: table.into(),
            cols: cols.iter().map(|s| s.to_string()).collect(),
            pred: Some(pred),
        }
    }

    pub fn filter(self, pred: Pred) -> Logical {
        Logical::Filter { input: Box::new(self), pred }
    }

    pub fn project(self, cols: &[&str]) -> Logical {
        Logical::Project {
            input: Box::new(self),
            cols: cols.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn aggregate(self, group_by: impl Into<String>, aggs: Vec<AggSpec>) -> Logical {
        Logical::Aggregate { input: Box::new(self), group_by: group_by.into(), aggs }
    }

    /// `self` is the build (left) side.
    pub fn join(
        self,
        right: Logical,
        left_on: impl Into<String>,
        right_on: impl Into<String>,
        lip: bool,
    ) -> Logical {
        Logical::Join {
            left: Box::new(self),
            right: Box::new(right),
            left_on: left_on.into(),
            right_on: right_on.into(),
            lip,
        }
    }

    pub fn sort(self, by: impl Into<String>, desc: bool) -> Logical {
        Logical::Sort { input: Box::new(self), by: by.into(), desc }
    }

    pub fn limit(self, n: u64) -> Logical {
        Logical::Limit { input: Box::new(self), n }
    }

    // ------------------------------------- serving-layer tree surgery

    /// Tables this query reads, sorted + deduped (cache invalidation
    /// tracks per-table datasource versions against this set).
    pub fn tables(&self) -> Vec<String> {
        fn walk(q: &Logical, out: &mut Vec<String>) {
            match q {
                Logical::Scan { table, .. } => out.push(table.clone()),
                Logical::Fragment { .. } => {}
                Logical::Filter { input, .. }
                | Logical::Project { input, .. }
                | Logical::Aggregate { input, .. }
                | Logical::Sort { input, .. }
                | Logical::Limit { input, .. } => walk(input, out),
                Logical::Join { left, right, .. } => {
                    walk(left, out);
                    walk(right, out);
                }
            }
        }
        let mut out = Vec::new();
        walk(self, &mut out);
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Outermost scan→filter→agg frontiers: every `Aggregate`-rooted
    /// subtree whose input is a pure Scan/Filter/Project pipeline. These
    /// are the materialization points of the fragment cache — the
    /// pre-aggregated "cube" later drilldowns re-slice without
    /// re-scanning.
    pub fn fragment_frontiers(&self) -> Vec<&Logical> {
        fn pipeline(q: &Logical) -> bool {
            match q {
                Logical::Scan { .. } => true,
                Logical::Filter { input, .. } | Logical::Project { input, .. } => {
                    pipeline(input)
                }
                _ => false,
            }
        }
        fn walk<'a>(q: &'a Logical, out: &mut Vec<&'a Logical>) {
            if let Logical::Aggregate { input, .. } = q {
                if pipeline(input) {
                    out.push(q);
                    return;
                }
            }
            match q {
                Logical::Scan { .. } | Logical::Fragment { .. } => {}
                Logical::Filter { input, .. }
                | Logical::Project { input, .. }
                | Logical::Aggregate { input, .. }
                | Logical::Sort { input, .. }
                | Logical::Limit { input, .. } => walk(input, out),
                Logical::Join { left, right, .. } => {
                    walk(left, out);
                    walk(right, out);
                }
            }
        }
        let mut out = Vec::new();
        walk(self, &mut out);
        out
    }

    /// Rewrite: replace every subtree structurally equal to `target`
    /// with a [`Logical::Fragment`] leaf over `data`.
    pub fn substitute(&self, target: &Logical, data: &Arc<Vec<u8>>) -> Logical {
        if self == target {
            return Logical::Fragment { data: data.clone() };
        }
        match self {
            Logical::Scan { .. } | Logical::Fragment { .. } => self.clone(),
            Logical::Filter { input, pred } => Logical::Filter {
                input: Box::new(input.substitute(target, data)),
                pred: pred.clone(),
            },
            Logical::Project { input, cols } => Logical::Project {
                input: Box::new(input.substitute(target, data)),
                cols: cols.clone(),
            },
            Logical::Aggregate { input, group_by, aggs } => Logical::Aggregate {
                input: Box::new(input.substitute(target, data)),
                group_by: group_by.clone(),
                aggs: aggs.clone(),
            },
            Logical::Join { left, right, left_on, right_on, lip } => Logical::Join {
                left: Box::new(left.substitute(target, data)),
                right: Box::new(right.substitute(target, data)),
                left_on: left_on.clone(),
                right_on: right_on.clone(),
                lip: *lip,
            },
            Logical::Sort { input, by, desc } => Logical::Sort {
                input: Box::new(input.substitute(target, data)),
                by: by.clone(),
                desc: *desc,
            },
            Logical::Limit { input, n } => Logical::Limit {
                input: Box::new(input.substitute(target, data)),
                n: *n,
            },
        }
    }
}

/// The planner.
pub struct Planner {
    /// Skip exchanges entirely on single-worker clusters (they would
    /// be pure overhead; the paper's single-GPU config does the same).
    pub num_workers: usize,
    /// Enable Lookahead Information Passing on joins that ask for it.
    pub lip_enabled: bool,
}

impl Planner {
    pub fn new(num_workers: usize) -> Planner {
        Planner { num_workers, lip_enabled: true }
    }

    /// Lower a logical tree to the physical DAG.
    pub fn plan(&self, logical: &Logical) -> Result<PhysicalPlan> {
        let mut plan = PhysicalPlan::new();
        self.lower(logical, &mut plan)?;
        plan.validate()?;
        Ok(plan)
    }

    fn lower(&self, node: &Logical, plan: &mut PhysicalPlan) -> Result<usize> {
        Ok(match node {
            Logical::Scan { table, cols, pred } => plan.add(
                OpSpec::Scan {
                    table: table.clone(),
                    cols: cols.clone(),
                    pred: pred.clone(),
                },
                vec![],
            ),
            Logical::Filter { input, pred } => {
                let i = self.lower(input, plan)?;
                plan.add(OpSpec::Filter { pred: pred.clone() }, vec![i])
            }
            Logical::Project { input, cols } => {
                let i = self.lower(input, plan)?;
                plan.add(OpSpec::Project { cols: cols.clone() }, vec![i])
            }
            Logical::Aggregate { input, group_by, aggs } => {
                let mut i = self.lower(input, plan)?;
                if self.num_workers > 1 {
                    i = plan.add(
                        OpSpec::Exchange {
                            key: group_by.clone(),
                            role: ExchangeRole::Shuffle,
                        },
                        vec![i],
                    );
                }
                plan.add(
                    OpSpec::HashAgg { group_by: group_by.clone(), aggs: aggs.clone() },
                    vec![i],
                )
            }
            Logical::Join { left, right, left_on, right_on, lip } => {
                let mut l = self.lower(left, plan)?;
                let mut r = self.lower(right, plan)?;
                if self.num_workers > 1 {
                    // the paper's paired Adaptive Exchanges (§3.2): the
                    // build side may broadcast when small, in which case
                    // its probe partner passes through locally.
                    l = plan.add(
                        OpSpec::Exchange {
                            key: left_on.clone(),
                            role: ExchangeRole::Build,
                        },
                        vec![l],
                    );
                    r = plan.add(
                        OpSpec::Exchange {
                            key: right_on.clone(),
                            role: ExchangeRole::Probe { partner: l },
                        },
                        vec![r],
                    );
                }
                plan.add(
                    OpSpec::HashJoin {
                        left_on: left_on.clone(),
                        right_on: right_on.clone(),
                        lip: *lip && self.lip_enabled,
                    },
                    vec![l, r],
                )
            }
            Logical::Sort { input, by, desc } => {
                let i = self.lower(input, plan)?;
                plan.add(OpSpec::Sort { by: by.clone(), desc: *desc }, vec![i])
            }
            Logical::Limit { input, n } => {
                let i = self.lower(input, plan)?;
                plan.add(OpSpec::Limit { n: *n }, vec![i])
            }
            Logical::Fragment { data } => {
                plan.add(OpSpec::Fragment { data: data.clone() }, vec![])
            }
        })
    }
}

/// Gather-merge spec: how the Client combines per-worker root outputs.
#[derive(Clone, Debug, PartialEq)]
pub enum GatherMode {
    /// Plain concatenation.
    Concat,
    /// Re-sort the concatenation (root is a Sort).
    Sort { by: String, desc: bool },
    /// Re-sort then truncate (Sort under Limit).
    SortLimit { by: String, desc: bool, n: u64 },
    /// Truncate only (root is a Limit).
    Limit { n: u64 },
}

/// Derive the gather mode from a physical plan's root.
pub fn gather_mode(plan: &PhysicalPlan) -> GatherMode {
    let nodes = &plan.nodes;
    match nodes.last().map(|n| &n.spec) {
        Some(OpSpec::Sort { by, desc }) => GatherMode::Sort { by: by.clone(), desc: *desc },
        Some(OpSpec::Limit { n }) => {
            // Limit over Sort -> SortLimit
            let input = &nodes[nodes[nodes.len() - 1].inputs[0]];
            if let OpSpec::Sort { by, desc } = &input.spec {
                GatherMode::SortLimit { by: by.clone(), desc: *desc, n: *n }
            } else {
                GatherMode::Limit { n: *n }
            }
        }
        _ => GatherMode::Concat,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::plan::AggFn;

    fn q() -> Logical {
        Logical::scan("orders", &["o_orderkey", "o_totalprice"])
            .join(
                Logical::scan("lineitem", &["l_orderkey", "l_quantity"])
                    .filter(Pred::RangeI64 { col: "l_quantity".into(), lo: 0, hi: 25 }),
                "o_orderkey",
                "l_orderkey",
                true,
            )
            .aggregate("o_orderkey", vec![AggSpec::new(AggFn::Sum, "l_quantity")])
            .sort("sum_l_quantity", true)
            .limit(10)
    }

    #[test]
    fn multiworker_plan_inserts_exchanges() {
        let plan = Planner::new(4).plan(&q()).unwrap();
        let exchanges = plan
            .nodes
            .iter()
            .filter(|n| matches!(n.spec, OpSpec::Exchange { .. }))
            .count();
        assert_eq!(exchanges, 3, "2 join sides + 1 agg:\n{}", plan.render());
    }

    #[test]
    fn single_worker_plan_has_no_exchanges() {
        let plan = Planner::new(1).plan(&q()).unwrap();
        assert!(
            !plan.nodes.iter().any(|n| matches!(n.spec, OpSpec::Exchange { .. })),
            "{}",
            plan.render()
        );
    }

    #[test]
    fn lip_flag_respects_planner_switch() {
        let mut p = Planner::new(2);
        p.lip_enabled = false;
        let plan = p.plan(&q()).unwrap();
        let lip_on = plan.nodes.iter().any(
            |n| matches!(n.spec, OpSpec::HashJoin { lip: true, .. }),
        );
        assert!(!lip_on);
    }

    #[test]
    fn gather_modes() {
        let plan = Planner::new(2).plan(&q()).unwrap();
        assert_eq!(
            gather_mode(&plan),
            GatherMode::SortLimit { by: "sum_l_quantity".into(), desc: true, n: 10 }
        );
        let plain = Planner::new(2)
            .plan(&Logical::scan("t", &["a"]))
            .unwrap();
        assert_eq!(gather_mode(&plain), GatherMode::Concat);
    }

    #[test]
    fn fragment_frontier_extraction_and_substitution() {
        // q()'s aggregate sits on a join — not a pure pipeline — so it
        // has no frontier.
        assert!(q().fragment_frontiers().is_empty());
        let drill = Logical::scan("t", &["a", "b"])
            .filter(Pred::RangeI64 { col: "b".into(), lo: 0, hi: 10 })
            .aggregate("a", vec![AggSpec::new(AggFn::Sum, "b")])
            .sort("a", false)
            .limit(3);
        let fr = drill.fragment_frontiers();
        assert_eq!(fr.len(), 1);
        assert!(matches!(fr[0], Logical::Aggregate { .. }));
        assert_eq!(drill.tables(), vec!["t".to_string()]);
        let target = fr[0].clone();
        let data = Arc::new(vec![9u8]);
        let rewritten = drill.substitute(&target, &data);
        assert!(rewritten.fragment_frontiers().is_empty());
        let plan = Planner::new(2).plan(&rewritten).unwrap();
        assert!(
            plan.nodes.iter().any(|n| matches!(n.spec, OpSpec::Fragment { .. })),
            "{}",
            plan.render()
        );
        assert!(!plan.nodes.iter().any(|n| matches!(n.spec, OpSpec::Scan { .. })));
    }

    #[test]
    fn plans_validate_and_roundtrip() {
        for w in [1, 2, 8] {
            let plan = Planner::new(w).plan(&q()).unwrap();
            let buf = plan.encode();
            assert_eq!(PhysicalPlan::decode(&buf).unwrap(), plan);
        }
    }
}
