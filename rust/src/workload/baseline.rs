//! Photon-like CPU baseline engine (DESIGN.md substitution #3).
//!
//! A deliberately classical vectorized engine over the same files: one
//! thread, fully sequential volcano-with-materialization execution —
//! scan completes before filter starts, build completes before probe,
//! no pre-loading, no device, no overlap of I/O with compute. It pays
//! the same modeled object-store costs as Theseus but cannot hide them,
//! which is precisely the contrast the paper's Fig. 6 draws (Photon is
//! a well-engineered CPU engine; Theseus wins on movement overlap and
//! accelerator throughput, not on better relational algebra).
//!
//! Results are bit-comparable with the distributed engine's (same agg
//! naming, same f64 accumulation, same sort), which the integration
//! tests exploit: every suite query must produce identical output from
//! both engines.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::exec::operators::sort::sort_batch;
use crate::exec::plan::{AggFn, AggSpec, Pred};
use crate::planner::Logical;
use crate::storage::datasource::{Datasource, GenericDatasource};
use crate::storage::format::FileReader;
use crate::storage::object_store::ObjectStore;
use crate::types::{Column, ColumnData, DType, RecordBatch};
use crate::{Error, Result};

pub struct CpuEngine {
    store: Arc<dyn ObjectStore>,
    ds: GenericDatasource,
}

/// Result + timing.
pub struct BaselineResult {
    pub batch: RecordBatch,
    pub elapsed: Duration,
}

impl CpuEngine {
    pub fn new(store: Arc<dyn ObjectStore>) -> CpuEngine {
        CpuEngine { ds: GenericDatasource::new(store.clone()), store }
    }

    pub fn run(&self, q: &Logical) -> Result<BaselineResult> {
        let start = Instant::now();
        let batch = self.exec(q)?;
        Ok(BaselineResult { batch, elapsed: start.elapsed() })
    }

    fn exec(&self, q: &Logical) -> Result<RecordBatch> {
        match q {
            Logical::Scan { table, cols, pred } => self.scan(table, cols, pred.as_ref()),
            Logical::Filter { input, pred } => {
                let b = self.exec(input)?;
                let mask = host_mask(&b, pred)?;
                b.compact(&mask)
            }
            Logical::Project { input, cols } => {
                let b = self.exec(input)?;
                let names: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
                b.project(&names)
            }
            Logical::Aggregate { input, group_by, aggs } => {
                let b = self.exec(input)?;
                aggregate(&b, group_by, aggs)
            }
            Logical::Join { left, right, left_on, right_on, .. } => {
                // build fully materializes before probe starts
                let build = self.exec(left)?;
                let probe = self.exec(right)?;
                join(&build, &probe, left_on, right_on)
            }
            Logical::Sort { input, by, desc } => {
                let b = self.exec(input)?;
                if b.is_empty() {
                    Ok(b)
                } else {
                    sort_batch(&b, by, *desc)
                }
            }
            Logical::Limit { input, n } => {
                let b = self.exec(input)?;
                let take = (*n as usize).min(b.rows());
                b.slice(0, take)
            }
        }
    }

    fn scan(&self, table: &str, cols: &[String], pred: Option<&Pred>) -> Result<RecordBatch> {
        let keys = self.store.list(&format!("{table}/"))?;
        if keys.is_empty() {
            return Err(Error::Plan(format!("table '{table}' has no files")));
        }
        let mut parts = Vec::new();
        for key in keys {
            let footer = self.ds.footer(&key)?;
            let col_idx: Vec<usize> = cols
                .iter()
                .map(|c| footer.schema.index_of(c))
                .collect::<Result<_>>()?;
            let reader = FileReader { footer: (*footer).clone() };
            for g in 0..footer.row_groups.len() {
                if let Some(p) = pred {
                    if prunable(&footer, g, p) {
                        continue;
                    }
                }
                // sequential, blocking reads: the baseline's defining
                // property
                let pages = self.ds.fetch_group(&key, &footer, g, &col_idx)?;
                let cows: Vec<_> = pages.iter().map(|p| p.contiguous()).collect();
                let refs: Vec<&[u8]> = cows.iter().map(|c| c.as_ref()).collect();
                parts.push(reader.decode_group(g, &col_idx, &refs)?);
            }
        }
        RecordBatch::concat(&parts)
    }
}

fn prunable(footer: &crate::storage::format::FileFooter, g: usize, pred: &Pred) -> bool {
    pred.conjuncts().iter().any(|c| match c {
        Pred::RangeI64 { col, lo, hi } => footer
            .schema
            .index_of(col)
            .map(|ci| footer.prune_i64(g, ci, *lo, *hi))
            .unwrap_or(false),
        Pred::EqI64 { col, val } => footer
            .schema
            .index_of(col)
            .map(|ci| footer.prune_i64(g, ci, *val, *val + 1))
            .unwrap_or(false),
        _ => false,
    })
}

/// Host predicate evaluation (scalar).
pub fn host_mask(batch: &RecordBatch, pred: &Pred) -> Result<Vec<i32>> {
    let rows = batch.rows();
    let mut mask = vec![1i32; rows];
    fn apply(batch: &RecordBatch, pred: &Pred, mask: &mut [i32]) -> Result<()> {
        match pred {
            Pred::RangeI64 { col, lo, hi } => {
                let v = batch.column(col)?.data.as_i64()?;
                for (i, m) in mask.iter_mut().enumerate() {
                    if !(v[i] >= *lo && v[i] < *hi) {
                        *m = 0;
                    }
                }
            }
            Pred::RangeF32 { col, lo, hi } => {
                let v = batch.column(col)?.data.as_f32()?;
                for (i, m) in mask.iter_mut().enumerate() {
                    if !(v[i] >= *lo && v[i] < *hi) {
                        *m = 0;
                    }
                }
            }
            Pred::EqI64 { col, val } => {
                let v = batch.column(col)?.data.as_i64()?;
                for (i, m) in mask.iter_mut().enumerate() {
                    if v[i] != *val {
                        *m = 0;
                    }
                }
            }
            Pred::And(a, b) => {
                apply(batch, a, mask)?;
                apply(batch, b, mask)?;
            }
        }
        Ok(())
    }
    apply(batch, pred, &mut mask)?;
    Ok(mask)
}

/// Hash inner join, build = left.
pub fn join(
    build: &RecordBatch,
    probe: &RecordBatch,
    left_on: &str,
    right_on: &str,
) -> Result<RecordBatch> {
    let bkeys = build.column(left_on)?.data.as_i64()?;
    let pkeys = probe.column(right_on)?.data.as_i64()?;
    let mut index: HashMap<i64, Vec<u32>> = HashMap::with_capacity(bkeys.len());
    for (i, &k) in bkeys.iter().enumerate() {
        index.entry(k).or_default().push(i as u32);
    }
    let mut pi = Vec::new();
    let mut bi = Vec::new();
    for (i, k) in pkeys.iter().enumerate() {
        if let Some(rows) = index.get(k) {
            for &b in rows {
                pi.push(i as u32);
                bi.push(b);
            }
        }
    }
    let p = probe.take(&pi)?;
    let b = build.take(&bi)?;
    let mut columns = p.columns;
    for c in b.columns {
        if columns.iter().any(|e| e.name == c.name) {
            continue;
        }
        columns.push(c);
    }
    RecordBatch::new(columns)
}

/// Exact hash aggregation matching the distributed engine's output
/// schema (key asc, f64 agg columns named `<fn>_<col>`).
pub fn aggregate(batch: &RecordBatch, group_by: &str, aggs: &[AggSpec]) -> Result<RecordBatch> {
    #[derive(Clone, Copy)]
    struct St {
        sum: f64,
        count: i64,
        min: f64,
        max: f64,
    }
    let keys = batch.column(group_by)?.data.as_i64()?;
    let vals: Vec<Vec<f64>> = aggs
        .iter()
        .map(|a| {
            let c = batch.column(&a.col)?;
            Ok(match &c.data {
                ColumnData::I64(v) => v.iter().map(|&x| x as f64).collect(),
                ColumnData::F32(v) => v.iter().map(|&x| x as f64).collect(),
                ColumnData::F64(v) => v.clone(),
            })
        })
        .collect::<Result<_>>()?;
    let mut table: HashMap<i64, Vec<St>> = HashMap::new();
    for (row, &k) in keys.iter().enumerate() {
        let states = table.entry(k).or_insert_with(|| {
            vec![St { sum: 0.0, count: 0, min: f64::INFINITY, max: f64::NEG_INFINITY }; aggs.len()]
        });
        for (ai, v) in vals.iter().enumerate() {
            let x = v[row];
            let st = &mut states[ai];
            st.sum += x;
            st.count += 1;
            st.min = st.min.min(x);
            st.max = st.max.max(x);
        }
    }
    let mut gk: Vec<i64> = table.keys().copied().collect();
    gk.sort_unstable();
    let mut columns = vec![Column::new(
        group_by.to_string(),
        DType::Int64,
        ColumnData::I64(gk.clone()),
    )];
    for (ai, spec) in aggs.iter().enumerate() {
        let data: Vec<f64> = gk
            .iter()
            .map(|k| {
                let st = table[k][ai];
                match spec.func {
                    AggFn::Sum => st.sum,
                    AggFn::Count => st.count as f64,
                    AggFn::Min => st.min,
                    AggFn::Max => st.max,
                }
            })
            .collect();
        columns.push(Column::new(spec.name.clone(), DType::Float64, ColumnData::F64(data)));
    }
    RecordBatch::new(columns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimContext;
    use crate::storage::object_store::SimObjectStore;
    use crate::workload::queries::tpch_suite;
    use crate::workload::tpch::TpchGen;

    fn tiny_store() -> Arc<SimObjectStore> {
        let store = SimObjectStore::in_memory(&SimContext::test());
        let mut g = TpchGen::new(0.0005);
        g.row_group_rows = 512;
        g.rows_per_file = 2048;
        let dynstore: Arc<dyn ObjectStore> = store.clone();
        g.write_all(&dynstore).unwrap();
        store
    }

    #[test]
    fn baseline_runs_entire_tpch_suite() {
        let store = tiny_store();
        let engine = CpuEngine::new(store);
        for q in tpch_suite() {
            let r = engine.run(&q.logical());
            assert!(r.is_ok(), "{} failed: {:?}", q.id, r.err());
            let r = r.unwrap();
            assert!(r.batch.num_columns() > 0, "{} empty schema", q.id);
        }
    }

    #[test]
    fn aggregate_matches_hand_computation() {
        let b = RecordBatch::new(vec![
            Column::i64("g", vec![1, 2, 1, 2, 1]),
            Column::f64("v", vec![1.0, 2.0, 3.0, 4.0, 5.0]),
        ])
        .unwrap();
        let out = aggregate(
            &b,
            "g",
            &[AggSpec::new(AggFn::Sum, "v"), AggSpec::new(AggFn::Min, "v")],
        )
        .unwrap();
        assert_eq!(out.column("g").unwrap().data.as_i64().unwrap(), &[1, 2]);
        assert_eq!(out.column("sum_v").unwrap().data.as_f64().unwrap(), &[9.0, 6.0]);
        assert_eq!(out.column("min_v").unwrap().data.as_f64().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn join_matches_nested_loop_reference() {
        let build = RecordBatch::new(vec![
            Column::i64("k", vec![1, 2, 2]),
            Column::i64("b", vec![10, 20, 21]),
        ])
        .unwrap();
        let probe = RecordBatch::new(vec![
            Column::i64("pk", vec![2, 3, 1, 2]),
            Column::i64("p", vec![100, 101, 102, 103]),
        ])
        .unwrap();
        let out = join(&build, &probe, "k", "pk").unwrap();
        // probe row 0 (k=2) matches 2 build rows; row 2 matches 1; row 3 matches 2
        assert_eq!(out.rows(), 5);
        let p = out.column("p").unwrap().data.as_i64().unwrap().to_vec();
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![100, 100, 102, 103, 103]);
    }
}
