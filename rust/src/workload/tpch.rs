//! TPC-H table generator (DESIGN.md substitution #1): the eight tables
//! with the columns our query suite touches, written as THS columnar
//! files to an object store.
//!
//! Faithful to dbgen in the ways the engine cares about: key
//! relationships (lineitem.l_orderkey -> orders, orders.o_custkey ->
//! customer, ...), value distributions (uniform quantities/discounts,
//! date ranges, skew knob for adversarial tests), multiple files per
//! table with ~equal row groups (the paper: "row groups are dimensioned
//! to be approximately 128 MiB" — scaled down here), zstd-compressed
//! pages.
//!
//! `sf = 1.0` matches dbgen cardinalities (6M lineitem). Benches use
//! fractional scale factors; relative table proportions are preserved.
//!
//! Precision note: `l_extendedprice` is generated as f32 so the device
//! pre-aggregation stage is exercised end-to-end; the other monetary
//! columns are scale-2 decimals on i64, aggregated exactly on the host
//! path (DESIGN.md §Substitutions on the paper's 128-bit decimals).

use std::sync::Arc;

use crate::storage::compression::Codec;
use crate::storage::format::FileWriter;
use crate::storage::object_store::ObjectStore;
use crate::types::{Column, ColumnData, DType, Field, RecordBatch, Schema};
use crate::util::rng::Rng;
use crate::Result;

/// Dates as days since 1970-01-01; TPC-H covers 1992-01-01..1998-12-31.
pub const DATE_LO: i64 = 8036; // 1992-01-01
pub const DATE_HI: i64 = 10592; // 1998-12-31

pub const RETURNFLAGS: [&str; 3] = ["A", "N", "R"];
pub const LINESTATUS: [&str; 2] = ["F", "O"];
pub const SEGMENTS: [&str; 5] = ["AUTOMOBILE", "BUILDING", "FURNITURE", "HOUSEHOLD", "MACHINERY"];
pub const PRIORITIES: [&str; 5] = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"];
pub const BRANDS: usize = 25;
pub const NATIONS: i64 = 25;
pub const REGIONS: i64 = 5;

/// The generator.
pub struct TpchGen {
    pub sf: f64,
    pub seed: u64,
    /// Rows per row group in written files.
    pub row_group_rows: usize,
    /// Target rows per file (several row groups each).
    pub rows_per_file: usize,
    pub codec: Codec,
    /// Zipf skew on lineitem order keys (0 = uniform, dbgen-like).
    pub skew: f64,
}

impl TpchGen {
    pub fn new(sf: f64) -> TpchGen {
        TpchGen {
            sf,
            seed: 42,
            row_group_rows: 4096,
            rows_per_file: 16384,
            codec: Codec::Zstd { level: 1 },
            skew: 0.0,
        }
    }

    // dbgen cardinalities at SF=1
    pub fn lineitem_rows(&self) -> usize {
        (6_000_000.0 * self.sf) as usize
    }

    pub fn orders_rows(&self) -> usize {
        (1_500_000.0 * self.sf) as usize
    }

    pub fn customer_rows(&self) -> usize {
        (150_000.0 * self.sf) as usize
    }

    pub fn part_rows(&self) -> usize {
        (200_000.0 * self.sf) as usize
    }

    pub fn supplier_rows(&self) -> usize {
        ((10_000.0 * self.sf) as usize).max(10)
    }

    pub fn partsupp_rows(&self) -> usize {
        (800_000.0 * self.sf) as usize
    }

    /// Generate and write every table. Returns total bytes written.
    pub fn write_all(&self, store: &Arc<dyn ObjectStore>) -> Result<u64> {
        let mut total = 0u64;
        total += self.write_lineitem(store)?;
        total += self.write_orders(store)?;
        total += self.write_customer(store)?;
        total += self.write_part(store)?;
        total += self.write_supplier(store)?;
        total += self.write_partsupp(store)?;
        total += self.write_nation_region(store)?;
        Ok(total)
    }

    fn write_table(
        &self,
        store: &Arc<dyn ObjectStore>,
        name: &str,
        schema: Schema,
        rows: usize,
        mut gen_batch: impl FnMut(usize, usize) -> RecordBatch,
    ) -> Result<u64> {
        let mut written = 0u64;
        let rows_per_file = self.rows_per_file.max(self.row_group_rows);
        let files = rows.div_ceil(rows_per_file).max(1);
        let mut off = 0usize;
        for f in 0..files {
            let n = rows_per_file.min(rows - off);
            let mut w = FileWriter::new(schema.clone(), self.codec, self.row_group_rows);
            if n > 0 {
                w.write(gen_batch(off, n))?;
            }
            let bytes = w.finish()?;
            written += bytes.len() as u64;
            store.put(&format!("{name}/part-{f}.ths"), &bytes)?;
            off += n;
        }
        Ok(written)
    }

    pub fn lineitem_schema() -> Schema {
        Schema::new(vec![
            Field::new("l_orderkey", DType::Int64),
            Field::new("l_partkey", DType::Int64),
            Field::new("l_suppkey", DType::Int64),
            Field::new("l_quantity", DType::Decimal),
            Field::new("l_extendedprice", DType::Float32),
            Field::new("l_discount", DType::Decimal),
            Field::new("l_tax", DType::Decimal),
            Field::dict("l_returnflag", RETURNFLAGS.iter().map(|s| s.to_string()).collect()),
            Field::dict("l_linestatus", LINESTATUS.iter().map(|s| s.to_string()).collect()),
            Field::new("l_shipdate", DType::Date),
            Field::new("l_commitdate", DType::Date),
            Field::new("l_receiptdate", DType::Date),
        ])
    }

    fn write_lineitem(&self, store: &Arc<dyn ObjectStore>) -> Result<u64> {
        let rows = self.lineitem_rows();
        let orders = self.orders_rows().max(1) as i64;
        let parts = self.part_rows().max(1) as i64;
        let supps = self.supplier_rows().max(1) as i64;
        let seed = self.seed;
        let skew = self.skew;
        self.write_table(store, "lineitem", Self::lineitem_schema(), rows, move |off, n| {
            let mut rng = Rng::new(seed ^ 0x11ee ^ off as u64);
            let okeys: Vec<i64> = (0..n)
                .map(|_| {
                    if skew > 0.0 {
                        rng.gen_zipf(orders as u64, skew) as i64
                    } else {
                        rng.gen_i64(0, orders - 1)
                    }
                })
                .collect();
            RecordBatch::new(vec![
                Column::i64("l_orderkey", okeys),
                Column::i64("l_partkey", (0..n).map(|_| rng.gen_i64(0, parts - 1)).collect()),
                Column::i64("l_suppkey", (0..n).map(|_| rng.gen_i64(0, supps - 1)).collect()),
                Column::decimal("l_quantity", (0..n).map(|_| rng.gen_i64(1, 50) * 100).collect()),
                Column::f32(
                    "l_extendedprice",
                    (0..n).map(|_| rng.gen_f32(900.0, 105_000.0)).collect(),
                ),
                Column::decimal("l_discount", (0..n).map(|_| rng.gen_i64(0, 10)).collect()),
                Column::decimal("l_tax", (0..n).map(|_| rng.gen_i64(0, 8)).collect()),
                Column::dict("l_returnflag", (0..n).map(|_| rng.gen_i64(0, 2)).collect()),
                Column::dict("l_linestatus", (0..n).map(|_| rng.gen_i64(0, 1)).collect()),
                Column::date("l_shipdate", (0..n).map(|_| rng.gen_i64(DATE_LO, DATE_HI)).collect()),
                Column::date("l_commitdate", (0..n).map(|_| rng.gen_i64(DATE_LO, DATE_HI)).collect()),
                Column::date("l_receiptdate", (0..n).map(|_| rng.gen_i64(DATE_LO, DATE_HI)).collect()),
            ])
            .expect("lineitem batch")
        })
    }

    pub fn orders_schema() -> Schema {
        Schema::new(vec![
            Field::new("o_orderkey", DType::Int64),
            Field::new("o_custkey", DType::Int64),
            Field::new("o_totalprice", DType::Decimal),
            Field::new("o_orderdate", DType::Date),
            Field::dict("o_orderpriority", PRIORITIES.iter().map(|s| s.to_string()).collect()),
        ])
    }

    fn write_orders(&self, store: &Arc<dyn ObjectStore>) -> Result<u64> {
        let rows = self.orders_rows();
        let custs = self.customer_rows().max(1) as i64;
        let seed = self.seed;
        self.write_table(store, "orders", Self::orders_schema(), rows, move |off, n| {
            let mut rng = Rng::new(seed ^ 0x0a0a ^ off as u64);
            RecordBatch::new(vec![
                // sequential primary key: files cover disjoint ranges,
                // which also exercises row-group pruning on o_orderkey
                Column::i64("o_orderkey", (off as i64..(off + n) as i64).collect()),
                Column::i64("o_custkey", (0..n).map(|_| rng.gen_i64(0, custs - 1)).collect()),
                Column::decimal(
                    "o_totalprice",
                    (0..n).map(|_| rng.gen_i64(1_000_00, 500_000_00)).collect(),
                ),
                Column::date("o_orderdate", (0..n).map(|_| rng.gen_i64(DATE_LO, DATE_HI)).collect()),
                Column::dict("o_orderpriority", (0..n).map(|_| rng.gen_i64(0, 4)).collect()),
            ])
            .expect("orders batch")
        })
    }

    pub fn customer_schema() -> Schema {
        Schema::new(vec![
            Field::new("c_custkey", DType::Int64),
            Field::new("c_nationkey", DType::Int64),
            Field::new("c_acctbal", DType::Decimal),
            Field::dict("c_mktsegment", SEGMENTS.iter().map(|s| s.to_string()).collect()),
        ])
    }

    fn write_customer(&self, store: &Arc<dyn ObjectStore>) -> Result<u64> {
        let rows = self.customer_rows();
        let seed = self.seed;
        self.write_table(store, "customer", Self::customer_schema(), rows, move |off, n| {
            let mut rng = Rng::new(seed ^ 0xc0c0 ^ off as u64);
            RecordBatch::new(vec![
                Column::i64("c_custkey", (off as i64..(off + n) as i64).collect()),
                Column::i64("c_nationkey", (0..n).map(|_| rng.gen_i64(0, NATIONS - 1)).collect()),
                Column::decimal(
                    "c_acctbal",
                    (0..n).map(|_| rng.gen_i64(-999_99, 9_999_99)).collect(),
                ),
                Column::dict("c_mktsegment", (0..n).map(|_| rng.gen_i64(0, 4)).collect()),
            ])
            .expect("customer batch")
        })
    }

    pub fn part_schema() -> Schema {
        Schema::new(vec![
            Field::new("p_partkey", DType::Int64),
            Field::new("p_size", DType::Int64),
            Field::new("p_retailprice", DType::Decimal),
            Field::dict(
                "p_brand",
                (0..BRANDS).map(|i| format!("Brand#{}{}", i / 5 + 1, i % 5 + 1)).collect(),
            ),
        ])
    }

    fn write_part(&self, store: &Arc<dyn ObjectStore>) -> Result<u64> {
        let rows = self.part_rows();
        let seed = self.seed;
        self.write_table(store, "part", Self::part_schema(), rows, move |off, n| {
            let mut rng = Rng::new(seed ^ 0x9a97 ^ off as u64);
            RecordBatch::new(vec![
                Column::i64("p_partkey", (off as i64..(off + n) as i64).collect()),
                Column::i64("p_size", (0..n).map(|_| rng.gen_i64(1, 50)).collect()),
                Column::decimal(
                    "p_retailprice",
                    (0..n).map(|_| rng.gen_i64(900_00, 2_000_00)).collect(),
                ),
                Column::dict("p_brand", (0..n).map(|_| rng.gen_i64(0, BRANDS as i64 - 1)).collect()),
            ])
            .expect("part batch")
        })
    }

    pub fn supplier_schema() -> Schema {
        Schema::new(vec![
            Field::new("s_suppkey", DType::Int64),
            Field::new("s_nationkey", DType::Int64),
            Field::new("s_acctbal", DType::Decimal),
        ])
    }

    fn write_supplier(&self, store: &Arc<dyn ObjectStore>) -> Result<u64> {
        let rows = self.supplier_rows();
        let seed = self.seed;
        self.write_table(store, "supplier", Self::supplier_schema(), rows, move |off, n| {
            let mut rng = Rng::new(seed ^ 0x5u64 ^ off as u64);
            RecordBatch::new(vec![
                Column::i64("s_suppkey", (off as i64..(off + n) as i64).collect()),
                Column::i64("s_nationkey", (0..n).map(|_| rng.gen_i64(0, NATIONS - 1)).collect()),
                Column::decimal(
                    "s_acctbal",
                    (0..n).map(|_| rng.gen_i64(-999_99, 9_999_99)).collect(),
                ),
            ])
            .expect("supplier batch")
        })
    }

    pub fn partsupp_schema() -> Schema {
        Schema::new(vec![
            Field::new("ps_partkey", DType::Int64),
            Field::new("ps_suppkey", DType::Int64),
            Field::new("ps_availqty", DType::Int64),
            Field::new("ps_supplycost", DType::Decimal),
        ])
    }

    fn write_partsupp(&self, store: &Arc<dyn ObjectStore>) -> Result<u64> {
        let rows = self.partsupp_rows();
        let parts = self.part_rows().max(1) as i64;
        let supps = self.supplier_rows().max(1) as i64;
        let seed = self.seed;
        self.write_table(store, "partsupp", Self::partsupp_schema(), rows, move |off, n| {
            let mut rng = Rng::new(seed ^ 0x9599 ^ off as u64);
            RecordBatch::new(vec![
                Column::i64("ps_partkey", (0..n).map(|_| rng.gen_i64(0, parts - 1)).collect()),
                Column::i64("ps_suppkey", (0..n).map(|_| rng.gen_i64(0, supps - 1)).collect()),
                Column::i64("ps_availqty", (0..n).map(|_| rng.gen_i64(1, 9999)).collect()),
                Column::decimal(
                    "ps_supplycost",
                    (0..n).map(|_| rng.gen_i64(1_00, 1_000_00)).collect(),
                ),
            ])
            .expect("partsupp batch")
        })
    }

    fn write_nation_region(&self, store: &Arc<dyn ObjectStore>) -> Result<u64> {
        let mut rng = Rng::new(self.seed ^ 0x7a7a);
        let nation_schema = Schema::new(vec![
            Field::new("n_nationkey", DType::Int64),
            Field::new("n_regionkey", DType::Int64),
        ]);
        let nation = RecordBatch::new(vec![
            Column::i64("n_nationkey", (0..NATIONS).collect()),
            Column::i64("n_regionkey", (0..NATIONS).map(|_| rng.gen_i64(0, REGIONS - 1)).collect()),
        ])?;
        let mut w = FileWriter::new(nation_schema, Codec::None, 32);
        w.write(nation)?;
        let nbytes = w.finish()?;
        store.put("nation/part-0.ths", &nbytes)?;

        let region_schema = Schema::new(vec![Field::new("r_regionkey", DType::Int64)]);
        let region = RecordBatch::new(vec![Column::i64("r_regionkey", (0..REGIONS).collect())])?;
        let mut w = FileWriter::new(region_schema, Codec::None, 8);
        w.write(region)?;
        let rbytes = w.finish()?;
        store.put("region/part-0.ths", &rbytes)?;
        Ok((nbytes.len() + rbytes.len()) as u64)
    }
}

/// Uncompressed logical bytes of a generated dataset (the "scale
/// factor" the benches report against, analogous to the paper's TB
/// counts).
pub fn logical_bytes(gen: &TpchGen) -> u64 {
    let li = gen.lineitem_rows() as u64 * TpchGen::lineitem_schema().row_width() as u64;
    let or = gen.orders_rows() as u64 * TpchGen::orders_schema().row_width() as u64;
    let cu = gen.customer_rows() as u64 * TpchGen::customer_schema().row_width() as u64;
    let pa = gen.part_rows() as u64 * TpchGen::part_schema().row_width() as u64;
    let su = gen.supplier_rows() as u64 * TpchGen::supplier_schema().row_width() as u64;
    let ps = gen.partsupp_rows() as u64 * TpchGen::partsupp_schema().row_width() as u64;
    li + or + cu + pa + su + ps
}

/// Decimal column helper for assertions: scaled i64 -> f64.
pub fn dec_to_f64(c: &ColumnData) -> Vec<f64> {
    match c {
        ColumnData::I64(v) => v.iter().map(|&x| x as f64 / 100.0).collect(),
        ColumnData::F32(v) => v.iter().map(|&x| x as f64).collect(),
        ColumnData::F64(v) => v.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimContext;
    use crate::storage::datasource::{Datasource, GenericDatasource};
    use crate::storage::object_store::SimObjectStore;

    fn tiny_store() -> (Arc<SimObjectStore>, TpchGen) {
        let store = SimObjectStore::in_memory(&SimContext::test());
        let mut g = TpchGen::new(0.001); // 6k lineitem
        g.row_group_rows = 512;
        g.rows_per_file = 2048;
        let dynstore: Arc<dyn ObjectStore> = store.clone();
        g.write_all(&dynstore).unwrap();
        (store, g)
    }

    #[test]
    fn all_tables_written_with_expected_rows() {
        let (store, g) = tiny_store();
        let ds = GenericDatasource::new(store.clone());
        for (table, want) in [
            ("lineitem", g.lineitem_rows()),
            ("orders", g.orders_rows()),
            ("customer", g.customer_rows()),
            ("part", g.part_rows()),
            ("supplier", g.supplier_rows()),
            ("partsupp", g.partsupp_rows()),
            ("nation", NATIONS as usize),
            ("region", REGIONS as usize),
        ] {
            let keys = store.list(&format!("{table}/")).unwrap();
            assert!(!keys.is_empty(), "{table} missing");
            let rows: u64 = keys
                .iter()
                .map(|k| ds.footer(k).unwrap().total_rows())
                .sum();
            assert_eq!(rows as usize, want, "{table}");
        }
    }

    #[test]
    fn foreign_keys_within_range() {
        let (store, g) = tiny_store();
        let ds = GenericDatasource::new(store.clone());
        let keys = store.list("lineitem/").unwrap();
        let f = ds.footer(&keys[0]).unwrap();
        let pages = ds.fetch_group(&keys[0], &f, 0, &[0, 1, 2]).unwrap();
        let reader = crate::storage::format::FileReader { footer: (*f).clone() };
        let cows: Vec<_> = pages.iter().map(|p| p.contiguous()).collect();
        let refs: Vec<&[u8]> = cows.iter().map(|c| c.as_ref()).collect();
        let b = reader.decode_group(0, &[0, 1, 2], &refs).unwrap();
        let ok = b.column("l_orderkey").unwrap().data.as_i64().unwrap();
        assert!(ok.iter().all(|&k| k >= 0 && (k as usize) < g.orders_rows()));
        let pk = b.column("l_partkey").unwrap().data.as_i64().unwrap();
        assert!(pk.iter().all(|&k| k >= 0 && (k as usize) < g.part_rows()));
    }

    #[test]
    fn generation_is_deterministic() {
        let a = {
            let (store, _) = tiny_store();
            store.list("").unwrap().len()
        };
        let (store1, _) = tiny_store();
        let (store2, _) = tiny_store();
        let k = "lineitem/part-0.ths";
        let l1 = store1.head(k).unwrap();
        let l2 = store2.head(k).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(
            store1.get_range(k, 0, l1.min(4096)).unwrap(),
            store2.get_range(k, 0, l2.min(4096)).unwrap()
        );
        assert!(a > 6);
    }

    #[test]
    fn skew_changes_key_distribution() {
        let store = SimObjectStore::in_memory(&SimContext::test());
        let mut g = TpchGen::new(0.001);
        g.skew = 0.7;
        g.row_group_rows = 512;
        let dynstore: Arc<dyn ObjectStore> = store.clone();
        g.write_all(&dynstore).unwrap();
        let ds = GenericDatasource::new(store.clone());
        let keys = store.list("lineitem/").unwrap();
        let f = ds.footer(&keys[0]).unwrap();
        let pages = ds.fetch_group(&keys[0], &f, 0, &[0]).unwrap();
        let reader = crate::storage::format::FileReader { footer: (*f).clone() };
        let b = reader
            .decode_group(0, &[0], &[pages[0].contiguous().as_ref()])
            .unwrap();
        let ok = b.column("l_orderkey").unwrap().data.as_i64().unwrap();
        let low = ok.iter().filter(|&&k| (k as usize) < g.orders_rows() / 10).count();
        assert!(
            low * 2 > ok.len(),
            "zipf skew should concentrate keys: {low}/{}",
            ok.len()
        );
    }

    #[test]
    fn logical_bytes_scale_with_sf() {
        let a = logical_bytes(&TpchGen::new(0.001));
        let b = logical_bytes(&TpchGen::new(0.002));
        assert!(b > a + a / 2);
    }
}
