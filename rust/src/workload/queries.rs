//! Query suites: TPC-H-derived and TPC-DS-lite-derived logical queries
//! over the generated tables. These are the workloads every bench runs
//! "sequentially" (§4), scaled-down analogs of the queries the paper's
//! evaluation executes.
//!
//! Derivation notes: our plan algebra covers scan/filter/project/
//! join/group-by/sort/limit on single-key groupings; each query keeps
//! its TPC original's *shape* (which tables, how many joins, selectivity
//! knobs, agg fan-in) so the data-movement profile — what Theseus
//! optimizes — is preserved.

use crate::exec::plan::{AggFn, AggSpec, Pred};
use crate::planner::Logical;
use crate::workload::tpch::{DATE_HI, DATE_LO};

/// One suite entry.
pub struct QueryDef {
    pub id: &'static str,
    /// TPC query this derives from.
    pub derived_from: &'static str,
    pub joins: usize,
    pub build: fn() -> Logical,
}

impl QueryDef {
    pub fn logical(&self) -> Logical {
        (self.build)()
    }
}

fn mid_date(frac: f64) -> i64 {
    DATE_LO + ((DATE_HI - DATE_LO) as f64 * frac) as i64
}

// ---------------------------------------------------------------- TPC-H

fn q1() -> Logical {
    // pricing summary: heavy scan + low-cardinality agg
    Logical::scan_where(
        "lineitem",
        &["l_returnflag", "l_quantity", "l_extendedprice", "l_shipdate"],
        Pred::RangeI64 { col: "l_shipdate".into(), lo: DATE_LO, hi: mid_date(0.9) },
    )
    .filter(Pred::RangeI64 { col: "l_shipdate".into(), lo: DATE_LO, hi: mid_date(0.9) })
    .aggregate(
        "l_returnflag",
        vec![
            AggSpec::new(AggFn::Sum, "l_quantity"),
            AggSpec::new(AggFn::Sum, "l_extendedprice"),
            AggSpec::new(AggFn::Count, "l_quantity"),
        ],
    )
    .sort("l_returnflag", false)
}

fn q3() -> Logical {
    // shipping priority: 2 joins, selective filters, top-10
    let customer = Logical::scan("customer", &["c_custkey", "c_mktsegment"])
        .filter(Pred::EqI64 { col: "c_mktsegment".into(), val: 1 });
    let orders = Logical::scan_where(
        "orders",
        &["o_orderkey", "o_custkey", "o_orderdate"],
        Pred::RangeI64 { col: "o_orderdate".into(), lo: DATE_LO, hi: mid_date(0.5) },
    )
    .filter(Pred::RangeI64 { col: "o_orderdate".into(), lo: DATE_LO, hi: mid_date(0.5) });
    let lineitem = Logical::scan("lineitem", &["l_orderkey", "l_extendedprice", "l_shipdate"])
        .filter(Pred::RangeI64 {
            col: "l_shipdate".into(),
            lo: mid_date(0.5),
            hi: DATE_HI + 1,
        });
    customer
        .join(orders, "c_custkey", "o_custkey", true)
        .join(lineitem, "o_orderkey", "l_orderkey", true)
        .aggregate("o_orderkey", vec![AggSpec::new(AggFn::Sum, "l_extendedprice")])
        .sort("sum_l_extendedprice", true)
        .limit(10)
}

fn q5() -> Logical {
    // local supplier volume: 3-join chain ending in a small-dim agg
    let nation = Logical::scan("nation", &["n_nationkey", "n_regionkey"])
        .filter(Pred::EqI64 { col: "n_regionkey".into(), val: 2 });
    let customer = Logical::scan("customer", &["c_custkey", "c_nationkey"]);
    let orders = Logical::scan("orders", &["o_orderkey", "o_custkey"]);
    let lineitem = Logical::scan("lineitem", &["l_orderkey", "l_extendedprice"]);
    nation
        .join(customer, "n_nationkey", "c_nationkey", true)
        .join(orders, "c_custkey", "o_custkey", true)
        .join(lineitem, "o_orderkey", "l_orderkey", true)
        .aggregate("n_nationkey", vec![AggSpec::new(AggFn::Sum, "l_extendedprice")])
        .sort("sum_l_extendedprice", true)
}

fn q6() -> Logical {
    // forecasting revenue: pure filter + tiny agg (no joins)
    Logical::scan_where(
        "lineitem",
        &["l_linestatus", "l_extendedprice", "l_discount", "l_quantity", "l_shipdate"],
        Pred::RangeI64 { col: "l_shipdate".into(), lo: mid_date(0.2), hi: mid_date(0.4) },
    )
    .filter(
        Pred::RangeI64 { col: "l_shipdate".into(), lo: mid_date(0.2), hi: mid_date(0.4) }
            .and(Pred::RangeI64 { col: "l_discount".into(), lo: 5, hi: 8 })
            .and(Pred::RangeI64 { col: "l_quantity".into(), lo: 0, hi: 2400 }),
    )
    .aggregate("l_linestatus", vec![AggSpec::new(AggFn::Sum, "l_extendedprice")])
}

fn q12() -> Logical {
    // shipping modes: 1 join + priority agg
    let orders = Logical::scan("orders", &["o_orderkey", "o_orderpriority"]);
    let lineitem = Logical::scan_where(
        "lineitem",
        &["l_orderkey", "l_receiptdate"],
        Pred::RangeI64 { col: "l_receiptdate".into(), lo: mid_date(0.3), hi: mid_date(0.45) },
    )
    .filter(Pred::RangeI64 {
        col: "l_receiptdate".into(),
        lo: mid_date(0.3),
        hi: mid_date(0.45),
    });
    orders
        .join(lineitem, "o_orderkey", "l_orderkey", true)
        .aggregate("o_orderpriority", vec![AggSpec::new(AggFn::Count, "l_orderkey")])
        .sort("o_orderpriority", false)
}

fn q14() -> Logical {
    // promotion effect: part ⋈ lineitem by partkey
    let part = Logical::scan("part", &["p_partkey", "p_brand"]);
    let lineitem = Logical::scan_where(
        "lineitem",
        &["l_partkey", "l_extendedprice", "l_shipdate"],
        Pred::RangeI64 { col: "l_shipdate".into(), lo: mid_date(0.6), hi: mid_date(0.7) },
    )
    .filter(Pred::RangeI64 { col: "l_shipdate".into(), lo: mid_date(0.6), hi: mid_date(0.7) });
    part.join(lineitem, "p_partkey", "l_partkey", true)
        .aggregate("p_brand", vec![AggSpec::new(AggFn::Sum, "l_extendedprice")])
        .sort("sum_l_extendedprice", true)
        .limit(10)
}

fn q18() -> Logical {
    // large-volume customers: big-big join + top-100
    let orders = Logical::scan("orders", &["o_orderkey", "o_custkey"]);
    let lineitem = Logical::scan("lineitem", &["l_orderkey", "l_quantity"]);
    orders
        .join(lineitem, "o_orderkey", "l_orderkey", true)
        .aggregate("o_custkey", vec![AggSpec::new(AggFn::Sum, "l_quantity")])
        .sort("sum_l_quantity", true)
        .limit(100)
}

fn q19() -> Logical {
    // discounted revenue: selective part filter drives LIP
    let part = Logical::scan("part", &["p_partkey", "p_brand", "p_size"])
        .filter(
            Pred::EqI64 { col: "p_brand".into(), val: 12 }
                .and(Pred::RangeI64 { col: "p_size".into(), lo: 1, hi: 11 }),
        );
    let lineitem =
        Logical::scan("lineitem", &["l_partkey", "l_extendedprice", "l_quantity"]);
    part.join(lineitem, "p_partkey", "l_partkey", true)
        .aggregate("p_brand", vec![AggSpec::new(AggFn::Sum, "l_extendedprice")])
}

/// The TPC-H-derived suite (run sequentially, as in §4).
pub fn tpch_suite() -> Vec<QueryDef> {
    vec![
        QueryDef { id: "q1", derived_from: "TPC-H Q1", joins: 0, build: q1 },
        QueryDef { id: "q3", derived_from: "TPC-H Q3", joins: 2, build: q3 },
        QueryDef { id: "q5", derived_from: "TPC-H Q5", joins: 3, build: q5 },
        QueryDef { id: "q6", derived_from: "TPC-H Q6", joins: 0, build: q6 },
        QueryDef { id: "q12", derived_from: "TPC-H Q12", joins: 1, build: q12 },
        QueryDef { id: "q14", derived_from: "TPC-H Q14", joins: 1, build: q14 },
        QueryDef { id: "q18", derived_from: "TPC-H Q18", joins: 1, build: q18 },
        QueryDef { id: "q19", derived_from: "TPC-H Q19", joins: 1, build: q19 },
    ]
}

// --------------------------------------------------------------- TPC-DS

fn d1() -> Logical {
    let dates = Logical::scan("date_dim", &["d_date_sk", "d_year", "d_moy"])
        .filter(Pred::EqI64 { col: "d_year".into(), val: 2000 });
    let sales = Logical::scan("store_sales", &["ss_sold_date_sk", "ss_sales_price"]);
    dates
        .join(sales, "d_date_sk", "ss_sold_date_sk", true)
        .aggregate("d_moy", vec![AggSpec::new(AggFn::Sum, "ss_sales_price")])
        .sort("d_moy", false)
}

fn d2() -> Logical {
    let items = Logical::scan("item", &["i_item_sk", "i_category_sk"]);
    let sales = Logical::scan("store_sales", &["ss_item_sk", "ss_sales_price"]);
    items
        .join(sales, "i_item_sk", "ss_item_sk", true)
        .aggregate("i_category_sk", vec![
            AggSpec::new(AggFn::Sum, "ss_sales_price"),
            AggSpec::new(AggFn::Count, "ss_sales_price"),
        ])
        .sort("sum_ss_sales_price", true)
}

fn d3() -> Logical {
    let stores = Logical::scan("store", &["st_store_sk", "st_state_sk"]);
    let sales = Logical::scan("store_sales", &["ss_store_sk", "ss_net_profit"]);
    stores
        .join(sales, "st_store_sk", "ss_store_sk", true)
        .aggregate("st_state_sk", vec![AggSpec::new(AggFn::Sum, "ss_net_profit")])
        .sort("st_state_sk", false)
}

fn d4() -> Logical {
    let items = Logical::scan("item", &["i_item_sk", "i_category_sk", "i_current_price"])
        .filter(Pred::RangeI64 { col: "i_current_price".into(), lo: 100_00, hi: 200_00 });
    let sales = Logical::scan("store_sales", &["ss_item_sk", "ss_quantity", "ss_sales_price"])
        .filter(Pred::RangeI64 { col: "ss_quantity".into(), lo: 1, hi: 50 });
    items
        .join(sales, "i_item_sk", "ss_item_sk", true)
        .aggregate("i_category_sk", vec![AggSpec::new(AggFn::Sum, "ss_sales_price")])
        .sort("sum_ss_sales_price", true)
        .limit(5)
}

fn d5() -> Logical {
    // two dimension joins against the fact table
    let dates = Logical::scan("date_dim", &["d_date_sk", "d_year"])
        .filter(Pred::RangeI64 { col: "d_year".into(), lo: 1999, hi: 2002 });
    let sales = Logical::scan("store_sales", &["ss_sold_date_sk", "ss_item_sk", "ss_sales_price"]);
    let items = Logical::scan("item", &["i_item_sk", "i_category_sk"]);
    items
        .join(
            dates.join(sales, "d_date_sk", "ss_sold_date_sk", true),
            "i_item_sk",
            "ss_item_sk",
            true,
        )
        .aggregate("i_category_sk", vec![AggSpec::new(AggFn::Sum, "ss_sales_price")])
        .sort("i_category_sk", false)
}

fn d6() -> Logical {
    Logical::scan("store_sales", &["ss_item_sk", "ss_quantity"])
        .filter(Pred::RangeI64 { col: "ss_quantity".into(), lo: 80, hi: 101 })
        .aggregate("ss_item_sk", vec![AggSpec::new(AggFn::Count, "ss_quantity")])
        .sort("count_ss_quantity", true)
        .limit(25)
}

/// The TPC-DS-lite suite.
pub fn tpcds_lite_suite() -> Vec<QueryDef> {
    vec![
        QueryDef { id: "d1", derived_from: "TPC-DS Q3-shape", joins: 1, build: d1 },
        QueryDef { id: "d2", derived_from: "TPC-DS Q42-shape", joins: 1, build: d2 },
        QueryDef { id: "d3", derived_from: "TPC-DS Q7-shape", joins: 1, build: d3 },
        QueryDef { id: "d4", derived_from: "TPC-DS Q19-shape", joins: 1, build: d4 },
        QueryDef { id: "d5", derived_from: "TPC-DS Q72-shape", joins: 2, build: d5 },
        QueryDef { id: "d6", derived_from: "TPC-DS Q96-shape", joins: 0, build: d6 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::Planner;

    #[test]
    fn all_queries_plan_cleanly() {
        for workers in [1, 4] {
            let p = Planner::new(workers);
            for q in tpch_suite().iter().chain(tpcds_lite_suite().iter()) {
                let plan = p.plan(&q.logical());
                assert!(plan.is_ok(), "{} failed to plan: {:?}", q.id, plan.err());
                let plan = plan.unwrap();
                assert!(plan.len() >= 2, "{} too trivial", q.id);
            }
        }
    }

    #[test]
    fn suites_have_expected_sizes() {
        assert_eq!(tpch_suite().len(), 8);
        assert_eq!(tpcds_lite_suite().len(), 6);
    }

    #[test]
    fn join_counts_match_plan_structure() {
        let p = Planner::new(1);
        for q in tpch_suite() {
            let plan = p.plan(&q.logical()).unwrap();
            let joins = plan
                .nodes
                .iter()
                .filter(|n| matches!(n.spec, crate::exec::plan::OpSpec::HashJoin { .. }))
                .count();
            assert_eq!(joins, q.joins, "{}", q.id);
        }
    }
}
