//! Workloads: TPC-H / TPC-DS-lite data generation, the query suites
//! the benches run, and the Photon-like CPU baseline engine
//! (DESIGN.md substitutions #1 and #3).

pub mod baseline;
pub mod queries;
pub mod serving;
pub mod tpcds;
pub mod tpch;

pub use baseline::CpuEngine;
pub use queries::{tpcds_lite_suite, tpch_suite, QueryDef};
pub use serving::{serving_mix, ServingQuery};
pub use tpch::TpchGen;
