//! TPC-DS-lite: a star-schema subset (DESIGN.md substitution #1) —
//! one `store_sales` fact table with three dimensions (`date_dim`,
//! `item`, `store`). TPC-DS's defining workload property relative to
//! TPC-H is many dimension joins against one wide fact table with
//! skewed keys; this subset preserves exactly that shape for the Fig-5
//! scaling suite.

use std::sync::Arc;

use crate::storage::compression::Codec;
use crate::storage::format::FileWriter;
use crate::storage::object_store::ObjectStore;
use crate::types::{Column, DType, Field, RecordBatch, Schema};
use crate::util::rng::Rng;
use crate::Result;

pub struct TpcdsGen {
    pub sf: f64,
    pub seed: u64,
    pub row_group_rows: usize,
    pub rows_per_file: usize,
    pub codec: Codec,
}

impl TpcdsGen {
    pub fn new(sf: f64) -> TpcdsGen {
        TpcdsGen {
            sf,
            seed: 4242,
            row_group_rows: 4096,
            rows_per_file: 16384,
            codec: Codec::Zstd { level: 1 },
        }
    }

    pub fn store_sales_rows(&self) -> usize {
        (2_880_000.0 * self.sf) as usize
    }

    pub fn item_rows(&self) -> usize {
        ((18_000.0 * self.sf) as usize).max(100)
    }

    pub fn store_rows(&self) -> usize {
        ((12.0 * self.sf.max(1.0)) as usize).max(6)
    }

    pub fn date_rows(&self) -> usize {
        2556 // 7 years of days, fixed like the real date_dim
    }

    pub fn store_sales_schema() -> Schema {
        Schema::new(vec![
            Field::new("ss_sold_date_sk", DType::Int64),
            Field::new("ss_item_sk", DType::Int64),
            Field::new("ss_store_sk", DType::Int64),
            Field::new("ss_quantity", DType::Int64),
            Field::new("ss_sales_price", DType::Float32),
            Field::new("ss_net_profit", DType::Decimal),
        ])
    }

    pub fn write_all(&self, store: &Arc<dyn ObjectStore>) -> Result<u64> {
        let mut total = 0u64;
        // fact
        let rows = self.store_sales_rows();
        let items = self.item_rows() as i64;
        let stores = self.store_rows() as i64;
        let dates = self.date_rows() as i64;
        let seed = self.seed;
        let rows_per_file = self.rows_per_file.max(self.row_group_rows);
        let files = rows.div_ceil(rows_per_file).max(1);
        let mut off = 0usize;
        for f in 0..files {
            let n = rows_per_file.min(rows - off);
            let mut rng = Rng::new(seed ^ 0x55 ^ off as u64);
            let mut w =
                FileWriter::new(Self::store_sales_schema(), self.codec, self.row_group_rows);
            if n > 0 {
                w.write(RecordBatch::new(vec![
                    Column::i64(
                        "ss_sold_date_sk",
                        (0..n).map(|_| rng.gen_i64(0, dates - 1)).collect(),
                    ),
                    // item keys are zipf-skewed — the TPC-DS hallmark
                    Column::i64(
                        "ss_item_sk",
                        (0..n).map(|_| rng.gen_zipf(items as u64, 0.5) as i64).collect(),
                    ),
                    Column::i64(
                        "ss_store_sk",
                        (0..n).map(|_| rng.gen_i64(0, stores - 1)).collect(),
                    ),
                    Column::i64("ss_quantity", (0..n).map(|_| rng.gen_i64(1, 100)).collect()),
                    Column::f32(
                        "ss_sales_price",
                        (0..n).map(|_| rng.gen_f32(1.0, 300.0)).collect(),
                    ),
                    Column::decimal(
                        "ss_net_profit",
                        (0..n).map(|_| rng.gen_i64(-10_000_00, 20_000_00)).collect(),
                    ),
                ])?)?;
            }
            let bytes = w.finish()?;
            total += bytes.len() as u64;
            store.put(&format!("store_sales/part-{f}.ths"), &bytes)?;
            off += n;
        }

        // dimensions (single file each)
        let mut rng = Rng::new(self.seed ^ 0xd1);
        let date_schema = Schema::new(vec![
            Field::new("d_date_sk", DType::Int64),
            Field::new("d_year", DType::Int64),
            Field::new("d_moy", DType::Int64),
        ]);
        let n = self.date_rows();
        let mut w = FileWriter::new(date_schema, Codec::None, 1024);
        w.write(RecordBatch::new(vec![
            Column::i64("d_date_sk", (0..n as i64).collect()),
            Column::i64("d_year", (0..n).map(|i| 1998 + (i / 365) as i64).collect()),
            Column::i64("d_moy", (0..n).map(|i| ((i / 30) % 12 + 1) as i64).collect()),
        ])?)?;
        let bytes = w.finish()?;
        total += bytes.len() as u64;
        store.put("date_dim/part-0.ths", &bytes)?;

        let item_schema = Schema::new(vec![
            Field::new("i_item_sk", DType::Int64),
            Field::new("i_category_sk", DType::Int64),
            Field::new("i_current_price", DType::Decimal),
        ]);
        let n = self.item_rows();
        let mut w = FileWriter::new(item_schema, self.codec, self.row_group_rows);
        w.write(RecordBatch::new(vec![
            Column::i64("i_item_sk", (0..n as i64).collect()),
            Column::i64("i_category_sk", (0..n).map(|_| rng.gen_i64(0, 9)).collect()),
            Column::decimal(
                "i_current_price",
                (0..n).map(|_| rng.gen_i64(1_00, 300_00)).collect(),
            ),
        ])?)?;
        let bytes = w.finish()?;
        total += bytes.len() as u64;
        store.put("item/part-0.ths", &bytes)?;

        let store_schema = Schema::new(vec![
            Field::new("st_store_sk", DType::Int64),
            Field::new("st_state_sk", DType::Int64),
        ]);
        let n = self.store_rows();
        let mut w = FileWriter::new(store_schema, Codec::None, 64);
        w.write(RecordBatch::new(vec![
            Column::i64("st_store_sk", (0..n as i64).collect()),
            Column::i64("st_state_sk", (0..n).map(|_| rng.gen_i64(0, 4)).collect()),
        ])?)?;
        let bytes = w.finish()?;
        total += bytes.len() as u64;
        store.put("store/part-0.ths", &bytes)?;
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimContext;
    use crate::storage::datasource::{Datasource, GenericDatasource};
    use crate::storage::object_store::SimObjectStore;

    #[test]
    fn star_schema_written() {
        let store = SimObjectStore::in_memory(&SimContext::test());
        let mut g = TpcdsGen::new(0.001);
        g.row_group_rows = 512;
        let dynstore: Arc<dyn ObjectStore> = store.clone();
        let bytes = g.write_all(&dynstore).unwrap();
        assert!(bytes > 0);
        let ds = GenericDatasource::new(store.clone());
        for (t, want) in [
            ("store_sales", g.store_sales_rows()),
            ("date_dim", g.date_rows()),
            ("item", g.item_rows()),
            ("store", g.store_rows()),
        ] {
            let keys = store.list(&format!("{t}/")).unwrap();
            let rows: u64 = keys.iter().map(|k| ds.footer(k).unwrap().total_rows()).sum();
            assert_eq!(rows as usize, want, "{t}");
        }
    }
}
