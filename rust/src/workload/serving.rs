//! Repeat-heavy serving mix: the dashboard/drilldown traffic shape the
//! serving cache (PR 7) is built for.
//!
//! Interactive analytics traffic is nothing like the sequential TPC
//! suites: the same handful of dashboard panels refresh over and over,
//! users re-issue semantically identical queries that differ only in
//! authoring order (predicate conjuncts, column lists), and drilldowns
//! re-slice one pre-aggregated frontier with different sorts and
//! limits. This module generates that mix deterministically so the
//! serving-cache bench (micro bench #8) and tests can measure:
//!
//! - **exact repeats** — the same dashboard panel every round (result
//!   cache should serve every round after the first with zero cluster
//!   tasks);
//! - **equivalent rewrites** — every other round the revenue panel
//!   arrives with its filter conjuncts and scan columns permuted; the
//!   canonical plan key must map it onto the original's entry;
//! - **drilldowns** — per-round variations over one shared
//!   scan→filter→aggregate frontier, differing only above the
//!   aggregate (sort direction, limit); the fragment cache should
//!   serve the frontier so only the cheap re-slice executes.
//!
//! Queries run against the TPC-H-lite tables ([`crate::workload::tpch`])
//! so benches reuse the same generated data.

use crate::exec::plan::{AggFn, AggSpec, Pred};
use crate::planner::Logical;
use crate::workload::tpch::{DATE_HI, DATE_LO};

/// One request in the serving stream.
pub struct ServingQuery {
    /// Stable id: `<kind>@<round>` plus a variant suffix.
    pub id: String,
    /// Zero-based round this request belongs to.
    pub round: usize,
    /// Traffic class: `"dashboard"`, `"dashboard-rewrite"`, or
    /// `"drilldown"`.
    pub kind: &'static str,
    pub query: Logical,
}

fn date(frac: f64) -> i64 {
    DATE_LO + ((DATE_HI - DATE_LO) as f64 * frac) as i64
}

/// Revenue panel: filter + low-cardinality agg over lineitem.
fn revenue_panel() -> Logical {
    Logical::scan("lineitem", &["l_returnflag", "l_extendedprice", "l_shipdate", "l_discount"])
        .filter(
            Pred::RangeI64 { col: "l_shipdate".into(), lo: DATE_LO, hi: date(0.8) }
                .and(Pred::RangeI64 { col: "l_discount".into(), lo: 0, hi: 8 }),
        )
        .aggregate("l_returnflag", vec![AggSpec::new(AggFn::Sum, "l_extendedprice")])
        .sort("l_returnflag", false)
}

/// The revenue panel as a client with different authoring habits sends
/// it: conjuncts flipped, scan columns shuffled. Canonically identical
/// to [`revenue_panel`] (both normalizations apply below an Aggregate),
/// so it must land on the same result-cache entry.
fn revenue_panel_rewrite() -> Logical {
    Logical::scan("lineitem", &["l_discount", "l_shipdate", "l_extendedprice", "l_returnflag"])
        .filter(
            Pred::RangeI64 { col: "l_discount".into(), lo: 0, hi: 8 }
                .and(Pred::RangeI64 { col: "l_shipdate".into(), lo: DATE_LO, hi: date(0.8) }),
        )
        .aggregate("l_returnflag", vec![AggSpec::new(AggFn::Sum, "l_extendedprice")])
        .sort("l_returnflag", false)
}

/// Orders panel: priority histogram.
fn orders_panel() -> Logical {
    Logical::scan("orders", &["o_orderpriority", "o_orderkey"])
        .aggregate("o_orderpriority", vec![AggSpec::new(AggFn::Count, "o_orderkey")])
        .sort("o_orderpriority", false)
}

/// The shared drilldown frontier: per-partkey quantity cube. Every
/// drilldown re-slices this aggregate, so it is the subtree the
/// fragment cache materializes once.
fn drill_frontier() -> Logical {
    Logical::scan("lineitem", &["l_partkey", "l_quantity", "l_shipdate"])
        .filter(Pred::RangeI64 { col: "l_shipdate".into(), lo: date(0.2), hi: date(0.9) })
        .aggregate("l_partkey", vec![AggSpec::new(AggFn::Sum, "l_quantity")])
}

/// A drilldown over the shared frontier: top/bottom-k by the summed
/// measure. Only the sort direction and limit vary — the aggregate
/// subtree is byte-identical across all drilldowns.
fn drilldown(desc: bool, k: usize) -> Logical {
    drill_frontier().sort("sum_l_quantity", desc).limit(k)
}

/// Generate `rounds` rounds of serving traffic. Round 0 is all cold;
/// every later round repeats the dashboard panels exactly, adds the
/// rewrite variant on odd rounds, and issues two fresh drilldowns that
/// share the cached frontier.
pub fn serving_mix(rounds: usize) -> Vec<ServingQuery> {
    let mut out = Vec::new();
    for round in 0..rounds {
        out.push(ServingQuery {
            id: format!("revenue@{round}"),
            round,
            kind: "dashboard",
            query: revenue_panel(),
        });
        out.push(ServingQuery {
            id: format!("orders@{round}"),
            round,
            kind: "dashboard",
            query: orders_panel(),
        });
        if round % 2 == 1 {
            out.push(ServingQuery {
                id: format!("revenue-rw@{round}"),
                round,
                kind: "dashboard-rewrite",
                query: revenue_panel_rewrite(),
            });
        }
        // two drilldowns per round; the (desc, k) pair cycles so later
        // rounds occasionally repeat an earlier drilldown exactly
        for (v, (desc, k)) in
            [(true, 5 + round % 3), (false, 10 + round % 2)].into_iter().enumerate()
        {
            out.push(ServingQuery {
                id: format!("drill{v}@{round}"),
                round,
                kind: "drilldown",
                query: drilldown(desc, k),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{canonicalize, fingerprint};
    use crate::planner::Planner;

    #[test]
    fn mix_shape_per_round() {
        let mix = serving_mix(4);
        // rounds 0,2: 4 queries; rounds 1,3: 5 (rewrite variant)
        assert_eq!(mix.len(), 4 + 5 + 4 + 5);
        assert!(mix.iter().all(|q| q.round < 4));
        assert_eq!(mix.iter().filter(|q| q.kind == "dashboard-rewrite").count(), 2);
    }

    #[test]
    fn rewrite_variant_is_canonically_identical() {
        let a = fingerprint(&canonicalize(&revenue_panel()));
        let b = fingerprint(&canonicalize(&revenue_panel_rewrite()));
        assert_eq!(a, b, "rewrite must map onto the original's cache key");
        // ...but not textually identical pre-canonicalization
        assert_ne!(fingerprint(&revenue_panel()), fingerprint(&revenue_panel_rewrite()));
    }

    #[test]
    fn drilldowns_share_one_fragment_frontier() {
        let a = drilldown(true, 5);
        let b = drilldown(false, 10);
        let fa = a.fragment_frontiers();
        let fb = b.fragment_frontiers();
        assert_eq!(fa.len(), 1);
        assert_eq!(fb.len(), 1);
        assert_eq!(
            fingerprint(&canonicalize(fa[0])),
            fingerprint(&canonicalize(fb[0])),
            "drilldowns must hit the same cached fragment"
        );
    }

    #[test]
    fn serving_mix_plans_cleanly() {
        let p = Planner::new(2);
        for q in serving_mix(2) {
            assert!(p.plan(&q.query).is_ok(), "{} failed to plan", q.id);
        }
    }
}
