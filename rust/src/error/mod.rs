//! Crate-wide error type.
//!
//! Every subsystem funnels into [`Error`]; [`Error::is_retryable`]
//! distinguishes the paper's OOM-retry path (§3.3.2: "Compute tasks that
//! run out of memory can be retried ... and be divided up") from hard
//! failures.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for all Theseus subsystems.
#[derive(Debug, Error)]
pub enum Error {
    /// Device (simulated GPU) memory could not satisfy an allocation or
    /// reservation. The Compute Executor retries or splits the task.
    #[error("device memory exhausted: requested {requested} bytes (capacity {capacity}, in use {in_use})")]
    DeviceOom {
        requested: usize,
        capacity: usize,
        in_use: usize,
    },

    /// Pinned host pool exhausted (distinct from device OOM: spilling to
    /// disk, not splitting, is the remedy).
    #[error("pinned host pool exhausted: requested {requested} buffers, {available} free")]
    PinnedExhausted { requested: usize, available: usize },

    /// Memory reservation could not be granted within the deadline.
    #[error("memory reservation timed out after {waited_ms} ms for {requested} bytes on {tier}")]
    ReservationTimeout {
        requested: usize,
        tier: &'static str,
        waited_ms: u64,
    },

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("file format error: {0}")]
    Format(String),

    #[error("plan error: {0}")]
    Plan(String),

    #[error("network error: {0}")]
    Network(String),

    #[error("object store error: {0}")]
    ObjectStore(String),

    #[error("xla/pjrt error: {0}")]
    Xla(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("executor shut down")]
    Shutdown,

    #[error("query cancelled: {0}")]
    Cancelled(String),

    /// A worker's query-driver thread panicked. Scoped to the query
    /// that was running: the cluster itself survives and keeps serving
    /// other sessions.
    #[error("worker {worker_id} panicked during query {query_id}: {detail}")]
    WorkerPanic {
        worker_id: usize,
        query_id: u64,
        detail: String,
    },

    /// A transient fault at a named plane boundary (injected by
    /// [`crate::fault`] or classified from a real I/O failure). The
    /// recovery ladder — op-level retry, spill-write failover, lane
    /// send-retry, query-level re-run — treats these as recoverable;
    /// everything else fails the query.
    #[error("transient fault at {site}: {detail}")]
    Transient { site: &'static str, detail: String },

    #[error("{0}")]
    Internal(String),
}

impl Error {
    /// True if the Compute Executor should retry (possibly after
    /// splitting the task) rather than fail the query. Transient
    /// plane faults are retryable too — at the query level via the
    /// gateway's `query_retry_limit` re-run loop.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            Error::DeviceOom { .. }
                | Error::PinnedExhausted { .. }
                | Error::ReservationTimeout { .. }
        ) || self.is_transient()
    }

    /// Transient-vs-permanent classifier (the taxonomy FAULTS.md
    /// documents): [`Error::Transient`] wrappers are transient by
    /// construction; raw I/O errors are transient when their kind is
    /// one the OS can plausibly clear on retry (interrupted syscall,
    /// timeout, reset/aborted connection, broken pipe, would-block).
    /// Everything else — format, plan, config, OOM, panic — is
    /// permanent at the *plane* level (OOM has its own retry ladder
    /// via [`Error::is_retryable`]).
    pub fn is_transient(&self) -> bool {
        match self {
            Error::Transient { .. } => true,
            Error::Io(e) => matches!(
                e.kind(),
                std::io::ErrorKind::Interrupted
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::BrokenPipe
                    | std::io::ErrorKind::WouldBlock
            ),
            _ => false,
        }
    }

    pub fn internal(msg: impl Into<String>) -> Self {
        Error::Internal(msg.into())
    }
}

impl From<crate::runtime::pjrt_shim::Error> for Error {
    fn from(e: crate::runtime::pjrt_shim::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oom_is_retryable() {
        let e = Error::DeviceOom { requested: 1, capacity: 0, in_use: 0 };
        assert!(e.is_retryable());
        assert!(!Error::Format("x".into()).is_retryable());
    }

    #[test]
    fn transient_classification() {
        let t = Error::Transient { site: "storage_get", detail: "injected".into() };
        assert!(t.is_transient());
        assert!(t.is_retryable(), "transient implies retryable at the query level");
        for kind in [
            std::io::ErrorKind::Interrupted,
            std::io::ErrorKind::TimedOut,
            std::io::ErrorKind::ConnectionReset,
            std::io::ErrorKind::ConnectionAborted,
            std::io::ErrorKind::BrokenPipe,
            std::io::ErrorKind::WouldBlock,
        ] {
            assert!(
                Error::Io(std::io::Error::new(kind, "x")).is_transient(),
                "{kind:?} must classify transient"
            );
        }
        // permanent: corrupt data, missing files, logic errors
        assert!(!Error::Io(std::io::Error::new(std::io::ErrorKind::NotFound, "x"))
            .is_transient());
        assert!(!Error::Format("bad".into()).is_transient());
        assert!(!Error::internal("bug").is_transient());
        let p = Error::WorkerPanic { worker_id: 0, query_id: 1, detail: "d".into() };
        assert!(!p.is_transient() && !p.is_retryable());
        // OOM stays retryable (its own ladder) without being transient
        let oom = Error::DeviceOom { requested: 1, capacity: 0, in_use: 0 };
        assert!(oom.is_retryable() && !oom.is_transient());
    }

    #[test]
    fn display_includes_sizes() {
        let e = Error::DeviceOom { requested: 42, capacity: 100, in_use: 99 };
        let s = e.to_string();
        assert!(s.contains("42") && s.contains("100") && s.contains("99"));
    }
}
