//! Crate-wide error type.
//!
//! Every subsystem funnels into [`Error`]; [`Error::is_retryable`]
//! distinguishes the paper's OOM-retry path (§3.3.2: "Compute tasks that
//! run out of memory can be retried ... and be divided up") from hard
//! failures.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for all Theseus subsystems.
#[derive(Debug, Error)]
pub enum Error {
    /// Device (simulated GPU) memory could not satisfy an allocation or
    /// reservation. The Compute Executor retries or splits the task.
    #[error("device memory exhausted: requested {requested} bytes (capacity {capacity}, in use {in_use})")]
    DeviceOom {
        requested: usize,
        capacity: usize,
        in_use: usize,
    },

    /// Pinned host pool exhausted (distinct from device OOM: spilling to
    /// disk, not splitting, is the remedy).
    #[error("pinned host pool exhausted: requested {requested} buffers, {available} free")]
    PinnedExhausted { requested: usize, available: usize },

    /// Memory reservation could not be granted within the deadline.
    #[error("memory reservation timed out after {waited_ms} ms for {requested} bytes on {tier}")]
    ReservationTimeout {
        requested: usize,
        tier: &'static str,
        waited_ms: u64,
    },

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("file format error: {0}")]
    Format(String),

    #[error("plan error: {0}")]
    Plan(String),

    #[error("network error: {0}")]
    Network(String),

    #[error("object store error: {0}")]
    ObjectStore(String),

    #[error("xla/pjrt error: {0}")]
    Xla(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("executor shut down")]
    Shutdown,

    #[error("query cancelled: {0}")]
    Cancelled(String),

    /// A worker's query-driver thread panicked. Scoped to the query
    /// that was running: the cluster itself survives and keeps serving
    /// other sessions.
    #[error("worker {worker_id} panicked during query {query_id}: {detail}")]
    WorkerPanic {
        worker_id: usize,
        query_id: u64,
        detail: String,
    },

    #[error("{0}")]
    Internal(String),
}

impl Error {
    /// True if the Compute Executor should retry (possibly after
    /// splitting the task) rather than fail the query.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            Error::DeviceOom { .. }
                | Error::PinnedExhausted { .. }
                | Error::ReservationTimeout { .. }
        )
    }

    pub fn internal(msg: impl Into<String>) -> Self {
        Error::Internal(msg.into())
    }
}

impl From<crate::runtime::pjrt_shim::Error> for Error {
    fn from(e: crate::runtime::pjrt_shim::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oom_is_retryable() {
        let e = Error::DeviceOom { requested: 1, capacity: 0, in_use: 0 };
        assert!(e.is_retryable());
        assert!(!Error::Format("x".into()).is_retryable());
    }

    #[test]
    fn display_includes_sizes() {
        let e = Error::DeviceOom { requested: 42, capacity: 100, in_use: 99 };
        let s = e.to_string();
        assert!(s.contains("42") && s.contains("100") && s.contains("99"));
    }
}
