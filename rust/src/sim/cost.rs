//! Cloud cost model for the Figure-6 / Table-1 reproduction.
//!
//! Instance rates are the paper-era AWS on-demand prices implied by
//! Table 1: g6.4xlarge (Theseus) ≈ $1.3234/h, r7gd.12xlarge (Photon
//! comparator) ≈ $3.2664/h — chosen so the table's cluster totals
//! ($10.59/h for 8 nodes, $9.80/h for 3 nodes, ...) reproduce exactly.

/// One instance type.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InstanceSpec {
    pub name: &'static str,
    pub vcpus: u32,
    pub mem_gib: u32,
    pub gpu_mem_gib: u32,
    pub usd_per_hour: f64,
}

/// g6.4xlarge: 16 vCPU, 64 GiB, one L4 (24 GiB), 25 Gb/s.
pub const G6_4XLARGE: InstanceSpec = InstanceSpec {
    name: "g6.4xlarge",
    vcpus: 16,
    mem_gib: 64,
    gpu_mem_gib: 24,
    usd_per_hour: 1.3234,
};

/// r7gd.12xlarge: 48 vCPU, 384 GiB, no GPU, 22.5 Gb/s.
pub const R7GD_12XLARGE: InstanceSpec = InstanceSpec {
    name: "r7gd.12xlarge",
    vcpus: 48,
    mem_gib: 384,
    gpu_mem_gib: 0,
    usd_per_hour: 3.2664,
};

/// A rented cluster.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub instance: InstanceSpec,
    pub nodes: u32,
}

impl CostModel {
    pub fn new(instance: InstanceSpec, nodes: u32) -> Self {
        CostModel { instance, nodes }
    }

    /// Cluster $/hour (Table 1 "Cost" column).
    pub fn usd_per_hour(&self) -> f64 {
        self.instance.usd_per_hour * self.nodes as f64
    }

    /// Total memory (GPU + host) in GiB (Table 1 "Memory" column).
    pub fn total_memory_gib(&self) -> u64 {
        (self.instance.mem_gib as u64 + self.instance.gpu_mem_gib as u64)
            * self.nodes as u64
    }

    /// Dollars for a run of `secs` seconds.
    pub fn usd_for_run(&self, secs: f64) -> f64 {
        self.usd_per_hour() * secs / 3600.0
    }

    /// Performance normalized against cost: queries-per-dollar style
    /// metric the paper's "X faster at cost parity" derives from.
    /// Returns (other_runtime * other_cost_rate) / (self_runtime *
    /// self_cost_rate) — >1 means `self` wins at cost parity.
    pub fn speedup_at_cost_parity(
        &self,
        self_secs: f64,
        other: &CostModel,
        other_secs: f64,
    ) -> f64 {
        (other_secs * other.usd_per_hour()) / (self_secs * self.usd_per_hour())
    }
}

/// The paper's Table-1 cluster pairs (Theseus nodes, Photon nodes).
pub const TABLE1_PAIRS: [(u32, u32); 3] = [(8, 3), (16, 6), (32, 12)];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_costs_reproduce() {
        // Paper Table 1: 8 nodes -> 10.59 $/h; 16 -> 21.17; 32 -> 42.34.
        for (nodes, want) in [(8u32, 10.59f64), (16, 21.17), (32, 42.34)] {
            let c = CostModel::new(G6_4XLARGE, nodes);
            assert!((c.usd_per_hour() - want).abs() < 0.01, "{nodes}: {}", c.usd_per_hour());
        }
        // Photon: 3 -> 9.80; 6 -> 19.60; 12 -> 39.19 (.8/h rounding in paper).
        for (nodes, want) in [(3u32, 9.80f64), (6, 19.60), (12, 39.20)] {
            let c = CostModel::new(R7GD_12XLARGE, nodes);
            assert!((c.usd_per_hour() - want).abs() < 0.015, "{nodes}: {}", c.usd_per_hour());
        }
    }

    #[test]
    fn table1_memory_reproduces() {
        // Theseus 8 nodes: 704 GiB; Photon 3 nodes: 1152 GiB.
        assert_eq!(CostModel::new(G6_4XLARGE, 8).total_memory_gib(), 704);
        assert_eq!(CostModel::new(R7GD_12XLARGE, 3).total_memory_gib(), 1152);
        // Paper: "the Databricks clusters have a 63% higher memory capacity"
        let t = CostModel::new(G6_4XLARGE, 32).total_memory_gib() as f64;
        let p = CostModel::new(R7GD_12XLARGE, 12).total_memory_gib() as f64;
        assert!((p / t - 1.63).abs() < 0.02, "{}", p / t);
    }

    #[test]
    fn cost_parity_speedup() {
        let a = CostModel::new(G6_4XLARGE, 8);
        let b = CostModel::new(R7GD_12XLARGE, 3);
        // equal runtimes, near-equal rates -> ratio near 1
        let s = a.speedup_at_cost_parity(100.0, &b, 100.0);
        assert!((s - 9.80 / 10.59).abs() < 0.01);
        // self twice as fast -> roughly 2x at parity
        let s = a.speedup_at_cost_parity(50.0, &b, 100.0);
        assert!(s > 1.8 && s < 2.0);
    }
}
