//! Hardware simulation: calibrated latency/bandwidth models standing in
//! for the paper's testbed (DESIGN.md §Hardware-Adaptation).
//!
//! Everything the coordinator does is *real* (threads, queues, memcpy,
//! PJRT executions, spill files); only the raw device/wire speeds are
//! modeled. Each hardware resource is a [`Throttle`] — a shared link
//! that serializes modeled occupancy, so concurrent transfers contend
//! exactly as they would on a PCIe lane, a NIC, or an S3 connection.
//!
//! [`HwProfile`] encodes the paper's two testbeds:
//!  * `on_prem()` — DGX-class node: A100s on PCIe4/NVLink, 200 Gb/s IB
//!    (config D/E enable "RDMA": higher bw, lower per-message cost),
//!    WEKA-like storage.
//!  * `cloud()`   — g6.4xlarge: one L4, 25 Gb/s NIC, S3-like object
//!    store (high per-request latency, per-connection bandwidth caps).
//!
//! `time_scale` uniformly scales every modeled sleep so benches can
//! compress hours of modeled I/O into seconds without changing ratios.

pub mod cost;
pub mod throttle;

pub use cost::CostModel;
pub use throttle::Throttle;

use std::sync::Arc;

/// Bytes-per-second convenience constructors.
pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;

/// One modeled interconnect or storage endpoint.
#[derive(Clone, Debug)]
pub struct LinkSpec {
    /// Fixed per-operation latency (request setup, kernel launch, ...).
    pub latency_us: u64,
    /// Sustained bandwidth in bytes/second.
    pub bytes_per_sec: u64,
}

impl LinkSpec {
    pub const fn new(latency_us: u64, bytes_per_sec: u64) -> Self {
        LinkSpec { latency_us, bytes_per_sec }
    }
}

/// The modeled hardware of one worker node + its shared fabric.
#[derive(Clone, Debug)]
pub struct HwProfile {
    pub name: &'static str,
    /// Host <-> device transfers (PCIe; pinned vs pageable modeled by a
    /// bandwidth multiplier in `memory::pinned`).
    pub pcie: LinkSpec,
    /// Worker <-> worker fabric, TCP mode (IPoIB on-prem, VPC in cloud).
    pub net_tcp: LinkSpec,
    /// Worker <-> worker fabric, RDMA mode (GPUDirect; on-prem only).
    pub net_rdma: Option<LinkSpec>,
    /// Object-store / distributed-FS reads, per connection.
    pub storage: LinkSpec,
    /// Max concurrent storage connections per worker.
    pub storage_conns: usize,
    /// Pageable-host copy penalty: pinned-pool transfers run at full
    /// `pcie` bandwidth, pageable at `pcie / pageable_penalty`
    /// (CUDA best-practices §10: pageable copies stage through an
    /// internal pinned buffer at roughly half throughput).
    pub pageable_penalty: f64,
    /// Device compute throughput proxy (bytes of column data processed
    /// per second per stream) — used only to pace the modeled portion of
    /// compute tasks that the PJRT CPU path under-costs.
    pub device_compute: LinkSpec,
}

impl HwProfile {
    /// DGX-A100-like on-prem node on 200 Gb/s InfiniBand + WEKA (§4).
    pub fn on_prem() -> Self {
        HwProfile {
            name: "on-prem",
            pcie: LinkSpec::new(10, 24 * GIB),
            // IPoIB TCP: high bandwidth but per-message software cost.
            net_tcp: LinkSpec::new(60, 6 * GIB),
            // GPUDirect RDMA: near-wire 200 Gb/s, tiny launch cost.
            net_rdma: Some(LinkSpec::new(8, 22 * GIB)),
            // WEKA + GDS: parallel high-throughput reads.
            storage: LinkSpec::new(200, 2 * GIB),
            storage_conns: 8,
            pageable_penalty: 2.2,
            device_compute: LinkSpec::new(15, 40 * GIB),
        }
    }

    /// AWS g6.4xlarge-like cloud node (one L4, 25 Gb/s NIC, S3).
    pub fn cloud() -> Self {
        HwProfile {
            name: "cloud",
            pcie: LinkSpec::new(12, 12 * GIB),
            net_tcp: LinkSpec::new(80, 2 * GIB + GIB / 2), // ~25 Gb/s usable minus overhead
            net_rdma: None,
            // S3: ~15 ms first byte, ~90 MB/s per connection.
            storage: LinkSpec::new(15_000, 90 * MIB),
            storage_conns: 16,
            pageable_penalty: 2.2,
            device_compute: LinkSpec::new(25, 12 * GIB),
        }
    }

    /// Tiny profile for unit tests: negligible latencies so tests run
    /// fast but the code paths (throttles, pools) are exercised.
    pub fn test() -> Self {
        HwProfile {
            name: "test",
            pcie: LinkSpec::new(0, 64 * GIB),
            net_tcp: LinkSpec::new(0, 64 * GIB),
            net_rdma: Some(LinkSpec::new(0, 64 * GIB)),
            storage: LinkSpec::new(0, 64 * GIB),
            storage_conns: 4,
            pageable_penalty: 2.0,
            device_compute: LinkSpec::new(0, 64 * GIB),
        }
    }
}

/// Shared simulation context: profile + global time scale.
#[derive(Clone)]
pub struct SimContext {
    pub profile: Arc<HwProfile>,
    /// Multiplier on every modeled sleep (1.0 = model faithfully;
    /// 0.0 = disable modeled delays, pure functional mode).
    pub time_scale: f64,
}

impl SimContext {
    pub fn new(profile: HwProfile, time_scale: f64) -> Self {
        SimContext { profile: Arc::new(profile), time_scale }
    }

    pub fn test() -> Self {
        SimContext::new(HwProfile::test(), 0.0)
    }

    /// Build the shared throttle for a link spec under this context.
    pub fn throttle(&self, spec: &LinkSpec) -> Throttle {
        Throttle::new(spec.clone(), self.time_scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_have_sane_orderings() {
        let op = HwProfile::on_prem();
        let cl = HwProfile::cloud();
        // RDMA beats TCP on-prem; storage latency is worse in the cloud.
        assert!(op.net_rdma.as_ref().unwrap().bytes_per_sec > op.net_tcp.bytes_per_sec);
        assert!(cl.storage.latency_us > op.storage.latency_us * 10);
        assert!(cl.net_rdma.is_none());
    }

    #[test]
    fn test_context_is_instant() {
        let ctx = SimContext::test();
        assert_eq!(ctx.time_scale, 0.0);
    }
}
