//! [`Throttle`] — a shared, contended link with modeled latency and
//! bandwidth.
//!
//! Semantics: a transfer of `n` bytes occupies the link for
//! `latency + n / bandwidth` of *modeled* time. Occupancy is serialized
//! through an internal horizon (`free_at`): a transfer starts at
//! `max(now, free_at)` and pushes the horizon forward, then the calling
//! thread sleeps until its modeled completion (scaled by `time_scale`).
//! This reproduces queueing on PCIe lanes, NICs, and per-connection
//! object-store bandwidth without a discrete-event core, while letting
//! real threads really overlap work on *other* resources — which is the
//! entire point of the paper's executor design (Insight A).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::LinkSpec;

#[derive(Clone)]
pub struct Throttle {
    inner: Arc<Inner>,
}

struct Inner {
    spec: LinkSpec,
    time_scale: f64,
    epoch: Instant,
    /// Modeled time (ns since epoch) at which the link becomes free.
    free_at_ns: AtomicU64,
    /// Total modeled busy nanoseconds (utilization metric).
    busy_ns: AtomicU64,
    /// Total bytes carried.
    bytes: AtomicU64,
    /// Number of operations.
    ops: AtomicU64,
}

impl Throttle {
    pub fn new(spec: LinkSpec, time_scale: f64) -> Self {
        Throttle {
            inner: Arc::new(Inner {
                spec,
                time_scale,
                epoch: Instant::now(),
                free_at_ns: AtomicU64::new(0),
                busy_ns: AtomicU64::new(0),
                bytes: AtomicU64::new(0),
                ops: AtomicU64::new(0),
            }),
        }
    }

    /// Modeled duration for an `n`-byte operation.
    pub fn model_duration(&self, n: usize) -> Duration {
        let s = &self.inner.spec;
        let xfer_ns = if s.bytes_per_sec == 0 {
            0
        } else {
            (n as u128 * 1_000_000_000u128 / s.bytes_per_sec as u128) as u64
        };
        Duration::from_nanos(s.latency_us * 1_000 + xfer_ns)
    }

    /// Occupy the link for an `n`-byte operation: reserves modeled
    /// occupancy and sleeps (scaled) until the modeled completion.
    /// Returns the modeled duration charged.
    pub fn acquire(&self, n: usize) -> Duration {
        let d = self.model_duration(n);
        let d_ns = d.as_nanos() as u64;
        let inner = &self.inner;
        inner.busy_ns.fetch_add(d_ns, Ordering::Relaxed);
        inner.bytes.fetch_add(n as u64, Ordering::Relaxed);
        inner.ops.fetch_add(1, Ordering::Relaxed);

        if inner.time_scale <= 0.0 {
            return d;
        }
        let now_ns = inner.epoch.elapsed().as_nanos() as u64;
        // start = max(now, free_at); free_at' = start + d (CAS loop).
        let mut end_ns;
        loop {
            let free = inner.free_at_ns.load(Ordering::Acquire);
            let start = free.max(now_ns);
            end_ns = start + d_ns;
            if inner
                .free_at_ns
                .compare_exchange(free, end_ns, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                break;
            }
        }
        // Sleep the scaled remainder of modeled time.
        let wait_ns = (end_ns.saturating_sub(now_ns)) as f64 * inner.time_scale;
        if wait_ns >= 1_000.0 {
            std::thread::sleep(Duration::from_nanos(wait_ns as u64));
        }
        d
    }

    /// Total modeled busy time on this link.
    pub fn busy(&self) -> Duration {
        Duration::from_nanos(self.inner.busy_ns.load(Ordering::Relaxed))
    }

    pub fn bytes_carried(&self) -> u64 {
        self.inner.bytes.load(Ordering::Relaxed)
    }

    pub fn ops(&self) -> u64 {
        self.inner.ops.load(Ordering::Relaxed)
    }

    pub fn spec(&self) -> &LinkSpec {
        &self.inner.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MIB;

    #[test]
    fn model_duration_latency_plus_transfer() {
        let t = Throttle::new(LinkSpec::new(1_000, 100 * MIB), 0.0);
        let d = t.model_duration(100 * MIB as usize);
        // 1 ms latency + 1 s transfer
        assert!((d.as_secs_f64() - 1.001).abs() < 1e-6, "{d:?}");
    }

    #[test]
    fn zero_scale_never_sleeps() {
        let t = Throttle::new(LinkSpec::new(1_000_000, 1), 0.0);
        let start = Instant::now();
        t.acquire(1_000_000);
        assert!(start.elapsed() < Duration::from_millis(50));
        assert_eq!(t.ops(), 1);
        assert_eq!(t.bytes_carried(), 1_000_000);
    }

    #[test]
    fn busy_accumulates() {
        let t = Throttle::new(LinkSpec::new(10, 1024 * 1024 * 1024), 0.0);
        for _ in 0..10 {
            t.acquire(1024);
        }
        assert!(t.busy() >= Duration::from_micros(100));
        assert_eq!(t.ops(), 10);
    }

    #[test]
    fn scaled_sleep_respects_contention() {
        // two sequential acquires on a slow link must take ~2x one.
        let t = Throttle::new(LinkSpec::new(0, 10 * MIB), 0.5);
        let start = Instant::now();
        t.acquire(MIB as usize); // modeled 100ms -> 50ms real
        t.acquire(MIB as usize);
        let e = start.elapsed();
        assert!(e >= Duration::from_millis(80), "{e:?}");
    }

    #[test]
    fn concurrent_acquires_queue_on_horizon() {
        let t = Throttle::new(LinkSpec::new(0, 10 * MIB), 0.2);
        let start = Instant::now();
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || {
                    t.acquire(MIB as usize); // modeled 100 ms each
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // 4 * 100 ms modeled, serialized on the link, scaled by 0.2
        // -> ≥ 60 ms real allowing scheduling slop.
        let e = start.elapsed();
        assert!(e >= Duration::from_millis(60), "{e:?}");
    }
}
