//! Tiny argument parser for the `theseus` launcher binary.
//!
//! Grammar: `theseus <command> [--flag value]... [--switch]...`
//! No external dependency; flags are declared by the caller.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `switch_names` lists flags
    /// that take no value.
    pub fn parse<I, S>(raw: I, switch_names: &[&str]) -> Result<Args>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut it = raw.into_iter().map(Into::into).peekable();
        let command = it.next().unwrap_or_default();
        let mut args = Args { command, ..Default::default() };
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&name) {
                    args.switches.push(name.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        Error::Config(format!("flag --{name} needs a value"))
                    })?;
                    args.flags.insert(name.to_string(), v);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Config(format!("--{name}: {e}"))),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Config(format!("--{name}: {e}"))),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_command_flags_switches_positional() {
        let a = Args::parse(
            vec!["query", "--workers", "4", "--verbose", "q1", "--scale=0.1"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.command, "query");
        assert_eq!(a.flag("workers"), Some("4"));
        assert_eq!(a.flag("scale"), Some("0.1"));
        assert!(a.switch("verbose"));
        assert_eq!(a.positional(), &["q1".to_string()]);
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(vec!["x", "--n", "12", "--f", "2.5"], &[]).unwrap();
        assert_eq!(a.flag_usize("n", 0).unwrap(), 12);
        assert_eq!(a.flag_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.flag_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.flag_or("missing", "d"), "d");
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["x", "--n"], &[]).is_err());
    }

    #[test]
    fn bad_typed_value_is_error() {
        let a = Args::parse(vec!["x", "--n", "abc"], &[]).unwrap();
        assert!(a.flag_usize("n", 0).is_err());
    }

    #[test]
    fn empty_args_give_empty_command() {
        let a = Args::parse(Vec::<String>::new(), &[]).unwrap();
        assert_eq!(a.command, "");
    }
}
