//! `theseus` — cluster launcher and query driver.
//!
//! ```text
//! theseus datagen  --benchmark tpch --sf 0.01 --dir /tmp/tpch
//! theseus query    --benchmark tpch --sf 0.005 --query q3 --workers 4
//! theseus suite    --benchmark tpch --sf 0.005 --workers 4 --preset E
//! theseus explain  --benchmark tpch --query q5 --workers 4
//! theseus baseline --benchmark tpch --sf 0.005 --query q3
//! theseus info
//! ```
//!
//! Data can live in-memory (default: generated per run) or on disk via
//! `--dir`. `--preset A..I` selects the Figure-4 configurations;
//! individual knobs are settable with `--config file.toml`.

use std::sync::Arc;
use std::time::Duration;

use theseus::cli::Args;
use theseus::cluster::{Cluster, Gateway};
use theseus::config::WorkerConfig;
use theseus::planner::Planner;
use theseus::runtime::KernelRegistry;
use theseus::sim::SimContext;
use theseus::storage::object_store::{ObjectStore, SimObjectStore};
use theseus::util::human_bytes;
use theseus::workload::tpcds::TpcdsGen;
use theseus::workload::{tpcds_lite_suite, tpch_suite, CpuEngine, QueryDef, TpchGen};
use theseus::{Error, Result};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        eprintln!("{}", USAGE);
        std::process::exit(2);
    }
    match run(raw) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

const USAGE: &str = "usage: theseus <datagen|query|suite|explain|info|baseline> \
[--benchmark tpch|tpcds] [--sf F] [--query ID] [--workers N] [--preset A..I] \
[--config file.toml] [--dir PATH] [--no-aot] [--lip off]";

fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, &["no-aot", "verbose"])?;
    match args.command.as_str() {
        "datagen" => datagen(&args),
        "query" => query(&args),
        "suite" => suite(&args),
        "explain" => explain(&args),
        "baseline" => baseline(&args),
        "info" => info(),
        other => Err(Error::Config(format!("unknown command '{other}'\n{USAGE}"))),
    }
}

fn config_from(args: &Args) -> Result<WorkerConfig> {
    let mut cfg = match args.flag("preset") {
        Some(p) => WorkerConfig::preset(p.chars().next().unwrap_or('?'))?,
        None => WorkerConfig::default(),
    };
    if let Some(path) = args.flag("config") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{path}: {e}")))?;
        cfg.apply(&theseus::config::TomlLite::parse(&text)?)?;
    }
    cfg.num_workers = args.flag_usize("workers", cfg.num_workers)?;
    cfg.time_scale = args.flag_f64("time-scale", cfg.time_scale)?;
    Ok(cfg)
}

fn store_from(args: &Args, cfg: &WorkerConfig) -> Result<Arc<SimObjectStore>> {
    let sim = SimContext::new(cfg.profile.clone(), cfg.time_scale);
    Ok(match args.flag("dir") {
        Some(d) => SimObjectStore::at_dir(d, &sim),
        None => SimObjectStore::in_memory(&sim),
    })
}

fn generate(args: &Args, store: &Arc<dyn ObjectStore>) -> Result<()> {
    let sf = args.flag_f64("sf", 0.001)?;
    match args.flag_or("benchmark", "tpch") {
        "tpch" => {
            let bytes = TpchGen::new(sf).write_all(store)?;
            println!("tpch sf={sf}: wrote {}", human_bytes(bytes as usize));
        }
        "tpcds" => {
            let bytes = TpcdsGen::new(sf).write_all(store)?;
            println!("tpcds sf={sf}: wrote {}", human_bytes(bytes as usize));
        }
        other => return Err(Error::Config(format!("unknown benchmark '{other}'"))),
    }
    Ok(())
}

fn suite_for(args: &Args) -> Result<Vec<QueryDef>> {
    Ok(match args.flag_or("benchmark", "tpch") {
        "tpch" => tpch_suite(),
        "tpcds" => tpcds_lite_suite(),
        other => return Err(Error::Config(format!("unknown benchmark '{other}'"))),
    })
}

fn find_query(args: &Args) -> Result<QueryDef> {
    let id = args
        .flag("query")
        .ok_or_else(|| Error::Config("--query required".into()))?;
    suite_for(args)?
        .into_iter()
        .find(|q| q.id == id)
        .ok_or_else(|| Error::Config(format!("no query '{id}' in suite")))
}

fn registry(args: &Args) -> Option<KernelRegistry> {
    if args.switch("no-aot") {
        return None;
    }
    match KernelRegistry::shared() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("warning: AOT registry unavailable ({e}); using host fallbacks");
            None
        }
    }
}

fn datagen(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let store = store_from(args, &cfg)?;
    if args.flag("dir").is_none() {
        return Err(Error::Config(
            "datagen without --dir writes to memory and is lost; pass --dir".into(),
        ));
    }
    generate(args, &(store as Arc<dyn ObjectStore>))
}

fn query(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let store: Arc<dyn ObjectStore> = store_from(args, &cfg)?;
    if args.flag("dir").is_none() {
        generate(args, &store)?;
    }
    let q = find_query(args)?;
    let reg = registry(args);
    let cluster = Cluster::launch(cfg, store, reg)?;
    let mut gw = Gateway::new(cluster);
    if args.flag("lip") == Some("off") {
        gw.planner.lip_enabled = false;
    }
    let r = gw.submit(&q.logical())?;
    println!(
        "{}: {} rows in {:?} ({} spills, {} wire)",
        q.id,
        r.batch.rows(),
        r.elapsed,
        r.total_spills(),
        human_bytes(r.total_wire_bytes() as usize),
    );
    print_batch(&r.batch, 10);
    Ok(())
}

fn suite(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let store: Arc<dyn ObjectStore> = store_from(args, &cfg)?;
    if args.flag("dir").is_none() {
        generate(args, &store)?;
    }
    let reg = registry(args);
    let cluster = Cluster::launch(cfg, store, reg)?;
    let gw = Gateway::new(cluster);
    let mut total = Duration::ZERO;
    println!("{:<6} {:>8} {:>12} {:>8} {:>12}", "query", "rows", "time", "spills", "wire");
    for q in suite_for(args)? {
        let r = gw.submit(&q.logical())?;
        total += r.elapsed;
        println!(
            "{:<6} {:>8} {:>12?} {:>8} {:>12}",
            q.id,
            r.batch.rows(),
            r.elapsed,
            r.total_spills(),
            human_bytes(r.total_wire_bytes() as usize),
        );
    }
    println!("total: {total:?}");
    Ok(())
}

fn explain(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let q = find_query(args)?;
    let plan = Planner::new(cfg.num_workers).plan(&q.logical())?;
    println!("-- {} (derived from {}) --", q.id, q.derived_from);
    print!("{}", plan.render());
    Ok(())
}

fn baseline(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let store: Arc<dyn ObjectStore> = store_from(args, &cfg)?;
    if args.flag("dir").is_none() {
        generate(args, &store)?;
    }
    let engine = CpuEngine::new(store);
    let q = find_query(args)?;
    let r = engine.run(&q.logical())?;
    println!("{} (cpu baseline): {} rows in {:?}", q.id, r.batch.rows(), r.elapsed);
    print_batch(&r.batch, 10);
    Ok(())
}

fn info() -> Result<()> {
    println!(
        "theseus {} — distributed accelerator-native query engine",
        env!("CARGO_PKG_VERSION")
    );
    match theseus::runtime::Manifest::discover() {
        Ok(m) => {
            println!(
                "artifacts: {} stages (batch_rows={}, parts={}, buckets={}, bloom_bits={})",
                m.stages.len(),
                m.batch_rows,
                m.num_parts,
                m.num_buckets,
                m.bloom_bits
            );
            for s in m.stages.values() {
                println!("  {}: {} in, {} out", s.name, s.inputs.len(), s.outputs.len());
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

fn print_batch(batch: &theseus::types::RecordBatch, limit: usize) {
    if batch.is_empty() {
        println!("(empty result)");
        return;
    }
    let names: Vec<&str> = batch.columns.iter().map(|c| c.name.as_str()).collect();
    println!("{}", names.join("\t"));
    for row in 0..batch.rows().min(limit) {
        let cells: Vec<String> = batch
            .columns
            .iter()
            .map(|c| match &c.data {
                theseus::types::ColumnData::I64(v) => v[row].to_string(),
                theseus::types::ColumnData::F32(v) => format!("{:.2}", v[row]),
                theseus::types::ColumnData::F64(v) => format!("{:.2}", v[row]),
            })
            .collect();
        println!("{}", cells.join("\t"));
    }
    if batch.rows() > limit {
        println!("... ({} rows total)", batch.rows());
    }
}
