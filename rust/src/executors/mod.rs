//! The asynchronous control mechanisms of a Theseus worker (§3.3):
//! Compute, Data-Movement, Pre-load, and Network Executors.
//!
//! "Each worker process instantiates four executors ... All executors
//! have a number of configurable CPU threads on which they execute
//! their tasks in parallel. Submitted tasks are executed
//! asynchronously."
//!
//! The paper's Memory Executor (§3.3.2) and the promotion half of its
//! Pre-loading Executor (§3.3.3) are realized here as one
//! **Data-Movement Executor** ([`movement`]): both directions of tier
//! traffic are a single prioritized queue of movement tasks driven by a
//! shared [`crate::memory::PressureEvent`] — §3.3's "specialized
//! asynchronous control mechanisms" made literal. Spills start on the
//! event (threshold crossing, failed allocation, blocked reservation),
//! not on a polling tick, and victim/beneficiary selection is computed
//! once per wake against the Compute Executor's queue priorities for
//! *both* demotion and promotion.
//!
//! The executors *cooperate* rather than compete (Insight B):
//! * the Pre-load Executor inspects the Compute Executor's queue and
//!   stages byte ranges for queued scan tasks without ever blocking
//!   them;
//! * the Data-Movement Executor inspects the same queue to avoid
//!   spilling batches a near-term task needs (§3.3.2 "to avoid
//!   spilling data for which compute tasks are close to being
//!   executed") and to promote the inputs of imminent tasks (§3.3.3
//!   Compute-Task Pre-loading), and it answers the Memory Governor's
//!   reservation pressure;
//! * the Network Executor drains the operators' transmission buffer at
//!   its own rate, with backpressure bounded by the buffer.

pub mod compute;
pub mod movement;
pub mod network;
pub mod preload;

pub use compute::ComputeExecutor;
pub use movement::{DataMovementExecutor, Direction, HolderRegistry, MovementConfig, MovementTask};
pub use network::{NetworkExecutor, Outbox, Router};
pub use preload::PreloadExecutor;
