//! The four asynchronous control mechanisms of a Theseus worker
//! (§3.3): Compute, Memory, Pre-load, and Network Executors.
//!
//! "Each worker process instantiates four executors ... All executors
//! have a number of configurable CPU threads on which they execute
//! their tasks in parallel. Submitted tasks are executed
//! asynchronously."
//!
//! The executors *cooperate* rather than compete (Insight B):
//! * the Pre-load Executor inspects the Compute Executor's queue and
//!   stages data for queued tasks without ever blocking them;
//! * the Memory Executor inspects the same queue to avoid spilling
//!   batches a near-term task needs, and serves the reservation
//!   pressure callbacks of the governor;
//! * the Network Executor drains the operators' transmission buffer at
//!   its own rate, with backpressure bounded by the buffer.

pub mod compute;
pub mod memory;
pub mod network;
pub mod preload;

pub use compute::ComputeExecutor;
pub use memory::MemoryExecutor;
pub use network::{NetworkExecutor, Outbox, Router};
pub use preload::PreloadExecutor;
