//! Network Executor (§3.3.5): drains the transmission buffer, optionally
//! compresses, sends; receives frames and routes them to the registered
//! per-channel holders.
//!
//! "To send data to other workers, tasks utilize the Network Executor.
//! This involves pushing batches of data along with destination
//! information to a Batch Holder, which the Network Executor then pulls
//! from to send the message."
//!
//! Compression "trades computational resources and increased latency
//! for higher network throughput" — the Fig-4 B/E ablation: worth it on
//! the TCP fabric, counterproductive once RDMA raises wire bandwidth.
//!
//! Data movement (§3.4): outbound batches popped from a Batch Holder's
//! pinned slot keep their slab across the outbox and onto the wire
//! (vectored send, no reassembly); heap-encoded batches are staged
//! through the same bounce pool at frame-build time. Inbound payloads
//! arrive slab-backed from the TCP reader and are handed to the
//! destination holder's host tier as-is — one pool, end to end.
//!
//! Compression is slab-native in both directions: a codec-enabled send
//! compresses the outbound chunks *straight into* a `SlabWriter`
//! ([`Codec::compress_chunks_into`] — no compress-to-`Vec`-then-copy
//! double hop), and a compressed receive decompresses the payload's
//! slab chunks straight into a fresh slab
//! ([`Codec::decompress_slices_into`] via the router's bounce pool),
//! which the destination holder then adopts without copying. Either
//! side falls back to the heap when the pool is dry — counted by the
//! `codec.heap_fallback_bytes` gauge — so exhaustion degrades
//! throughput, never correctness.
//!
//! ## Credit-based backpressure (§3.3: movement decisions from observed
//! state)
//!
//! A slow receiver throttles its senders instead of letting frames pile
//! up: each sender starts with `exchange_initial_credits` data-frame
//! credits per destination ([`Outbox::enable_credits`]); popping a data
//! frame for a destination consumes one, and a destination at zero
//! credit is *skipped* by the sender lanes — later frames for that
//! destination (including Finish) hold their FIFO position behind the
//! blocked frame, while other destinations on the same lane proceed.
//! The receiving side returns credits as the consumer actually drains
//! delivered batches: [`ChannelRx`] keeps per-source delivered/granted
//! books, the receiver thread turns newly drained batches into
//! [`FrameKind::Credit`] frames (`net.credits_granted_total`), and the
//! sender applies them via the router's credit sink
//! ([`Outbox::grant_credits`]). Credit, Finish, Estimate and Control
//! frames are exempt from the accounting, so control flow never
//! deadlocks behind data flow. Stalls are visible on
//! `exchange.credit_stall_total`; a close with credit-blocked frames
//! still queued discards them *counted and logged*
//! (`net.close_unsent_total`) so the drain completes instead of
//! hanging. The sender lanes also publish per-destination depth and
//! send-latency signals ([`Outbox::queued_for`],
//! [`Outbox::send_latency_ns`]) — the exchange's adaptive flush
//! controller samples both.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::sync::{ranks, OrderedCondvar, OrderedMutex};
use std::time::Duration;

use crate::metrics::Metrics;

use crate::memory::{BatchHolder, PinnedPool, SlabSlice, SlabWriter, StagedBytes};
use crate::network::frame::Payload;
use crate::network::{Endpoint, Frame, FrameKind};
use crate::storage::compression::{Codec, PRELUDE_LEN};
use crate::types::RecordBatch;
use crate::{Error, Result};

/// One outbound message.
pub enum Outbound {
    /// Encoded batch for (dst, channel) — slab-backed when it came off
    /// a pinned holder slot.
    Data { dst: usize, channel: u32, encoded: StagedBytes },
    /// End-of-stream for (dst, channel).
    Finish { dst: usize, channel: u32 },
    /// Size estimate broadcast (§3.2).
    Estimate { dst: usize, channel: u32, bytes: u64 },
}

impl Outbound {
    fn dst(&self) -> usize {
        match self {
            Outbound::Data { dst, .. }
            | Outbound::Finish { dst, .. }
            | Outbound::Estimate { dst, .. } => *dst,
        }
    }
}

/// Bounded transmission buffer operators push into (the paper's
/// Network-Executor-side Batch Holder). Bounded => backpressure: a full
/// buffer blocks the pushing compute task, pacing producers to the
/// fabric's rate.
pub struct Outbox {
    q: OrderedMutex<VecDeque<Outbound>>,
    not_full: OrderedCondvar,
    not_empty: OrderedCondvar,
    capacity: usize,
    closed: AtomicBool,
    pushed: AtomicU64,
    /// Messages popped by sender lanes but not yet fully sent (still
    /// compressing or on the socket). Incremented under the queue lock
    /// at pop time, so an emptiness check can never race past a message
    /// that left the queue but hasn't hit the wire.
    in_flight: AtomicUsize,
    /// Per-destination credit windows (None until
    /// [`Outbox::enable_credits`] — gating off, the default for tests
    /// and benches with no credit-granting receiver). Locked *after*
    /// `q` when both are held.
    credits: OrderedMutex<CreditState>,
    /// Per-destination EWMA of `endpoint.send` wall time, fed by the
    /// sender lanes — one of the two congestion signals the exchange's
    /// adaptive flush controller samples.
    send_latency: OrderedMutex<HashMap<usize, u64>>,
    /// Credit-blocked data frames discarded by a close (the drain must
    /// complete, but dropped data must be loud).
    close_unsent: AtomicU64,
    metrics: OnceLock<Arc<Metrics>>,
}

/// Remaining data-frame credits per destination. `window == None`
/// disables gating entirely.
#[derive(Default)]
struct CreditState {
    window: Option<u64>,
    by_dst: HashMap<usize, u64>,
}

impl CreditState {
    fn remaining(&mut self, dst: usize) -> Option<u64> {
        let w = self.window?;
        Some(*self.by_dst.entry(dst).or_insert(w))
    }

    fn exhausted(&mut self, dst: usize) -> bool {
        self.remaining(dst) == Some(0)
    }

    fn consume(&mut self, dst: usize) {
        if let Some(w) = self.window {
            let c = self.by_dst.entry(dst).or_insert(w);
            *c = c.saturating_sub(1);
        }
    }

    fn grant(&mut self, dst: usize, amount: u64) {
        if let Some(w) = self.window {
            let c = self.by_dst.entry(dst).or_insert(w);
            // the receiver never grants more than it drained, so this
            // cap only defends against a buggy or malicious peer
            *c = (*c + amount).min(w);
        }
    }
}

impl Outbox {
    pub fn new(capacity: usize) -> Outbox {
        Outbox {
            q: OrderedMutex::new(ranks::OUTBOX_Q, "outbox.q", VecDeque::new()),
            not_full: OrderedCondvar::new(),
            not_empty: OrderedCondvar::new(),
            capacity: capacity.max(1),
            closed: AtomicBool::new(false),
            pushed: AtomicU64::new(0),
            in_flight: AtomicUsize::new(0),
            credits: OrderedMutex::new(
                ranks::OUTBOX_CREDITS,
                "outbox.credits",
                CreditState::default(),
            ),
            send_latency: OrderedMutex::new(
                ranks::OUTBOX_SEND_LATENCY,
                "outbox.send_latency",
                HashMap::new(),
            ),
            close_unsent: AtomicU64::new(0),
            metrics: OnceLock::new(),
        }
    }

    /// Turn on credit-based backpressure with `window` startup credits
    /// per destination (`exchange_initial_credits`). Off by default so
    /// an outbox with no credit-granting receiver wired (unit tests,
    /// benches) never stalls.
    pub fn enable_credits(&self, window: usize) {
        self.credits.lock().window = Some(window.max(1) as u64);
    }

    /// Install the worker's metrics registry
    /// (`exchange.credit_stall_total`, `net.close_unsent_total`).
    pub fn install_metrics(&self, metrics: Arc<Metrics>) {
        let _ = self.metrics.set(metrics);
    }

    /// Return `amount` data-frame credits for `dst` (the receiver
    /// drained that many delivered batches) and wake any lane stalled
    /// on them.
    pub fn grant_credits(&self, dst: usize, amount: u64) {
        self.credits.lock().grant(dst, amount);
        // Serialize with a lane mid-scan: holding `q` while notifying
        // means the lane is either before its credit read (sees the
        // grant) or already parked (gets the wakeup) — never between.
        // (The credits guard above is a statement temporary, so `q` is
        // acquired with nothing held — no 230-before-220 inversion.)
        let q = self.q.lock();
        self.not_empty.notify_all(&q);
    }

    /// Remaining credits for `dst` (`None` = gating disabled).
    pub fn credits_remaining(&self, dst: usize) -> Option<u64> {
        self.credits.lock().remaining(dst)
    }

    /// Queued (not yet popped) messages addressed to `dst` — the depth
    /// signal for the adaptive flush controller.
    pub fn queued_for(&self, dst: usize) -> usize {
        self.q.lock().iter().filter(|m| m.dst() == dst).count()
    }

    /// Sender lanes record how long `endpoint.send` took per
    /// destination; kept as an EWMA (α = 1/4).
    fn note_send_latency(&self, dst: usize, ns: u64) {
        let mut lat = self.send_latency.lock();
        let e = lat.entry(dst).or_insert(ns);
        *e = (*e * 3 + ns) / 4;
    }

    /// Smoothed wire latency toward `dst` in nanoseconds (None before
    /// the first send) — the second controller signal.
    pub fn send_latency_ns(&self, dst: usize) -> Option<u64> {
        self.send_latency.lock().get(&dst).copied()
    }

    /// Credit-blocked data frames discarded because the outbox closed
    /// while they were unsendable.
    pub fn close_unsent(&self) -> u64 {
        self.close_unsent.load(Ordering::Relaxed)
    }

    /// Queue a batch for a peer (blocks when the buffer is full).
    /// Heap-encodes; the shuffle hot path uses
    /// [`Outbox::send_batch_pooled`] instead.
    pub fn send_batch(&self, dst: usize, channel: u32, batch: &RecordBatch) -> Result<()> {
        self.push(Outbound::Data { dst, channel, encoded: StagedBytes::Heap(batch.encode()) })
    }

    /// Queue a batch encoded *straight into the pinned bounce pool*
    /// (§3.4): the wire then sends the very slab the encode landed in,
    /// vectored, with no heap bounce `Vec` — the copy
    /// `StagedBytes::Heap(batch.encode())` used to pay for every
    /// shuffled byte. A dry or absent pool degrades to the heap encode
    /// (counted on the pool's `codec.heap_fallback_bytes` gauge).
    /// Returns whether the payload went out slab-backed.
    pub fn send_batch_pooled(
        &self,
        dst: usize,
        channel: u32,
        batch: &RecordBatch,
        pool: Option<&PinnedPool>,
    ) -> Result<bool> {
        let encoded = stage_encoded(batch, pool);
        let pinned = encoded.is_pinned();
        self.push(Outbound::Data { dst, channel, encoded })?;
        Ok(pinned)
    }

    /// Queue pre-encoded batch bytes (slab-backed bytes popped from a
    /// holder ride through unchanged).
    pub fn send_encoded(
        &self,
        dst: usize,
        channel: u32,
        encoded: impl Into<StagedBytes>,
    ) -> Result<()> {
        self.push(Outbound::Data { dst, channel, encoded: encoded.into() })
    }

    pub fn send_finish(&self, dst: usize, channel: u32) -> Result<()> {
        self.push(Outbound::Finish { dst, channel })
    }

    pub fn send_estimate(&self, dst: usize, channel: u32, bytes: u64) -> Result<()> {
        self.push(Outbound::Estimate { dst, channel, bytes })
    }

    fn push(&self, m: Outbound) -> Result<()> {
        let mut q = self.q.lock();
        while q.len() >= self.capacity {
            if self.closed.load(Ordering::Relaxed) {
                return Err(Error::Shutdown);
            }
            let (guard, _) = self.not_full.wait_timeout(q, Duration::from_millis(50));
            q = guard;
        }
        if self.closed.load(Ordering::Relaxed) {
            return Err(Error::Shutdown);
        }
        q.push_back(m);
        self.pushed.fetch_add(1, Ordering::Relaxed);
        self.not_empty.notify_one(&q);
        Ok(())
    }

    /// Pop the next message for a destination handled by `lane`
    /// (`dst % lanes == lane` keeps per-destination FIFO order with
    /// multiple sender threads).
    ///
    /// Credit gating happens here: a data frame whose destination is
    /// out of credits is skipped, and — to preserve per-destination
    /// FIFO order — *every* later frame for that destination (Finish
    /// included) is held behind it; frames for other destinations on
    /// the lane proceed. After [`Outbox::close`], blocked data frames
    /// are discarded (counted on `net.close_unsent_total` and
    /// warn-logged) instead of wedging the drain forever.
    ///
    /// Public because it *is* the lane protocol: anything standing in
    /// for a sender lane (the executor's threads, tests, benches)
    /// drains the outbox through this one gate.
    pub fn pop_for_lane(&self, lane: usize, lanes: usize, timeout: Duration) -> Option<Outbound> {
        let deadline = std::time::Instant::now() + timeout;
        let mut q = self.q.lock();
        loop {
            let closed = self.closed.load(Ordering::Relaxed);
            let mut blocked_dsts: HashSet<usize> = HashSet::new();
            let mut pos = None;
            {
                // q (220) -> credits (230): the declared nesting order
                let mut credits = self.credits.lock();
                let mut i = 0;
                while i < q.len() {
                    let m = &q[i];
                    let dst = m.dst();
                    if dst % lanes != lane || blocked_dsts.contains(&dst) {
                        i += 1;
                        continue;
                    }
                    let gated =
                        matches!(m, Outbound::Data { .. }) && credits.exhausted(dst);
                    if gated && closed {
                        // close releases the lane: the frame is
                        // unsendable and the drain must finish — drop
                        // it, loudly
                        q.remove(i);
                        let n = self.close_unsent.fetch_add(1, Ordering::Relaxed) + 1;
                        if let Some(m) = self.metrics.get() {
                            m.counter("net.close_unsent_total").inc();
                        }
                        log::warn!(
                            "outbox closed with credit-blocked data frame for \
                             worker {dst} still queued; discarded ({n} total)"
                        );
                        continue; // same index now holds the next frame
                    }
                    if gated {
                        blocked_dsts.insert(dst);
                        i += 1;
                        continue;
                    }
                    if matches!(m, Outbound::Data { .. }) {
                        credits.consume(dst);
                    }
                    pos = Some(i);
                    break;
                }
            }
            if !blocked_dsts.is_empty() {
                if let Some(m) = self.metrics.get() {
                    m.counter("exchange.credit_stall_total").inc();
                }
            }
            if let Some(pos) = pos {
                let m = q.remove(pos).unwrap();
                // count before releasing the lock: is_idle() holds the
                // same lock, so it sees either the queued message or
                // the in-flight count — never the gap between them
                self.in_flight.fetch_add(1, Ordering::SeqCst);
                self.not_full.notify_one(&q);
                return Some(m);
            }
            let now = std::time::Instant::now();
            if now >= deadline || closed {
                // blocked frames may have been dropped above — anyone
                // waiting on capacity or idleness should re-check
                if closed {
                    self.not_full.notify_all(&q);
                }
                return None;
            }
            let (guard, _) = self.not_empty.wait_timeout(q, deadline - now);
            q = guard;
        }
    }

    /// A sender lane finished (or failed) the message it popped.
    fn done_sending(&self) {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
    }

    /// Messages popped by lanes and still being compressed/sent.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Nothing queued *and* nothing in flight inside a sender lane —
    /// the condition `flush` waits for. An empty queue alone is not
    /// enough: a popped message may still be compressing or mid-send.
    pub fn is_idle(&self) -> bool {
        let q = self.q.lock();
        q.is_empty() && self.in_flight.load(Ordering::SeqCst) == 0
    }

    pub fn len(&self) -> usize {
        self.q.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }

    pub fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        // Notify while holding `q`: a lane between its closed-flag read
        // and its park would otherwise miss this wakeup for a full
        // timeout chunk.
        let q = self.q.lock();
        self.not_empty.notify_all(&q);
        self.not_full.notify_all(&q);
    }
}

/// Encode `batch` for the wire, slab-native when `pool` has room: the
/// exact [`RecordBatch::encoded_len`] is reserved up front
/// (all-or-nothing), then [`RecordBatch::encode_into`] streams the
/// bytes into pinned buffers. A dry or absent pool falls back to the
/// heap encode — identical bytes, counted as a codec-style heap
/// fallback so pool-dry shuffle operation stays visible on the
/// `codec.heap_fallback_bytes` gauge.
///
/// The reservation is *pressure-quiet*
/// ([`SlabWriter::with_capacity_quiet`]): a failed reserve must not
/// raise host pressure, because the coalescing exchange flushes on
/// that very pressure epoch — a shuffle send that raised on every
/// dry-pool flush would re-arm its own flush trigger and collapse
/// coalescing into tiny heap frames for the whole dry period.
pub fn stage_encoded(batch: &RecordBatch, pool: Option<&PinnedPool>) -> StagedBytes {
    if let Some(pool) = pool {
        let len = batch.encoded_len();
        match SlabWriter::with_capacity_quiet(pool, len) {
            Ok(mut w) => {
                // reserved up front: cannot run dry mid-write
                batch.encode_into(&mut w).expect("reserved slab write");
                let slab = w.finish();
                debug_assert_eq!(slab.len(), len, "encoded_len must be exact");
                return StagedBytes::Pinned(SlabSlice::whole(slab));
            }
            Err(_) => pool.note_codec_fallback(len),
        }
    }
    StagedBytes::Heap(batch.encode())
}

/// Receiving side of one exchange channel.
pub struct ChannelRx {
    /// Incoming batches land here (host tier — the receive path never
    /// competes with compute for device memory).
    pub holder: BatchHolder,
    /// Workers that sent Finish.
    finishes: AtomicUsize,
    /// Size estimates received so far (sender worker -> bytes).
    estimates: Mutex<HashMap<usize, u64>>,
    expected_senders: usize,
    /// Per-source delivered/granted books for credit-based
    /// backpressure: credits are returned only as the consumer actually
    /// drains the holder, never ahead of it.
    credit: Mutex<CreditBook>,
}

/// Receiver-side flow-control ledger: how many wire data frames each
/// source delivered into the holder, and how many credits were already
/// returned to it.
#[derive(Default)]
struct CreditBook {
    delivered: HashMap<usize, u64>,
    granted: HashMap<usize, u64>,
}

impl ChannelRx {
    pub fn new(holder: BatchHolder, expected_senders: usize) -> ChannelRx {
        ChannelRx {
            holder,
            finishes: AtomicUsize::new(0),
            estimates: Mutex::new(HashMap::new()),
            expected_senders,
            credit: Mutex::new(CreditBook::default()),
        }
    }

    /// The router delivered one wire data frame from `src` into the
    /// holder.
    fn note_delivered(&self, src: usize) {
        *self.credit.lock().unwrap().delivered.entry(src).or_insert(0) += 1;
    }

    /// Credits newly earned since the last call: delivered batches that
    /// have since left the holder (the consumer popped them) and were
    /// not yet acknowledged. Returns `(src, amount)` pairs.
    ///
    /// The drain count is inferred from the holder's own stats —
    /// `delivered − still_in_holder` — so batches pushed into the same
    /// holder by a *local* (non-wire) path can only delay grants, never
    /// inflate them: per-source grants are additionally capped by that
    /// source's unacknowledged deliveries, so a sender's credit never
    /// exceeds its startup window.
    fn take_grants(&self) -> Vec<(usize, u64)> {
        let stats = self.holder.stats();
        let in_holder =
            (stats.device_batches + stats.host_batches + stats.disk_batches) as u64;
        let mut book = self.credit.lock().unwrap();
        let delivered_total: u64 = book.delivered.values().sum();
        let granted_total: u64 = book.granted.values().sum();
        let drained = delivered_total.saturating_sub(in_holder);
        let mut budget = drained.saturating_sub(granted_total);
        if budget == 0 {
            return Vec::new();
        }
        let mut srcs: Vec<usize> = book.delivered.keys().copied().collect();
        srcs.sort_unstable(); // deterministic distribution order
        let mut out = Vec::new();
        for src in srcs {
            if budget == 0 {
                break;
            }
            let delivered = book.delivered[&src];
            let granted = book.granted.entry(src).or_insert(0);
            let give = (delivered - *granted).min(budget);
            if give > 0 {
                *granted += give;
                budget -= give;
                out.push((src, give));
            }
        }
        out
    }

    /// All senders finished (the holder has been marked finished too).
    pub fn all_finished(&self) -> bool {
        self.finishes.load(Ordering::Acquire) >= self.expected_senders
    }

    pub fn finishes(&self) -> usize {
        self.finishes.load(Ordering::Acquire)
    }

    /// Estimates received: (count, total bytes).
    pub fn estimates(&self) -> (usize, u64) {
        let e = self.estimates.lock().unwrap();
        (e.len(), e.values().sum())
    }

    pub fn expected_senders(&self) -> usize {
        self.expected_senders
    }
}

/// Channel registry: frames are routed by their `channel` id.
///
/// Workers build their query DAGs at slightly different times, so a
/// fast peer's estimate/data frames can arrive *before* this worker has
/// registered the channel. Such early frames are buffered (bounded) and
/// delivered on registration — without this, a racing exchange pair
/// deadlocks waiting for an estimate that was dropped.
pub struct Router {
    channels: RwLock<HashMap<u32, Arc<ChannelRx>>>,
    /// Early frames for channels not yet registered.
    pending: OrderedMutex<HashMap<u32, Vec<Frame>>>,
    /// Control frames (plan distribution, lifecycle) for the cluster.
    control: OrderedMutex<VecDeque<Frame>>,
    control_ready: OrderedCondvar,
    dropped: AtomicU64,
    /// §3.4 bounce pool: compressed payloads decompress straight into
    /// it (installed at worker bring-up; `None` decompresses to heap).
    bounce: RwLock<Option<PinnedPool>>,
    /// Where inbound [`FrameKind::Credit`] grants land: the local
    /// outbox, whose lanes are the ones a peer's credits unblock.
    credit_sink: RwLock<Option<Arc<Outbox>>>,
    metrics: OnceLock<Arc<Metrics>>,
}

/// Max buffered early frames per channel (beyond this something is
/// wrong — a dead downstream — and frames are counted dropped).
const MAX_PENDING_PER_CHANNEL: usize = 4096;

impl Default for Router {
    fn default() -> Router {
        Router {
            channels: RwLock::new(HashMap::new()),
            pending: OrderedMutex::new(
                ranks::ROUTER_PENDING,
                "router.pending",
                HashMap::new(),
            ),
            control: OrderedMutex::new(
                ranks::ROUTER_CONTROL,
                "router.control",
                VecDeque::new(),
            ),
            control_ready: OrderedCondvar::new(),
            dropped: AtomicU64::new(0),
            bounce: RwLock::new(None),
            credit_sink: RwLock::new(None),
            metrics: OnceLock::new(),
        }
    }
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn register(&self, channel: u32, rx: Arc<ChannelRx>) {
        self.channels.write().unwrap().insert(channel, rx);
        // deliver any frames that raced ahead of registration
        let early = self.pending.lock().remove(&channel);
        if let Some(frames) = early {
            for f in frames {
                if let Err(e) = self.route(f) {
                    log::warn!("replaying early frame on channel {channel}: {e}");
                }
            }
        }
    }

    /// Hand the router the worker's pinned pool so compressed payloads
    /// decompress straight into it (§3.4: one pool, end to end).
    pub fn install_bounce_pool(&self, pool: PinnedPool) {
        *self.bounce.write().unwrap() = Some(pool);
    }

    /// Install the outbox whose per-destination credit windows inbound
    /// [`FrameKind::Credit`] frames replenish (done by
    /// [`NetworkExecutor::start`]). Without a sink, credit frames are
    /// acknowledged and dropped — gating stays off, nothing deadlocks.
    pub fn install_credit_sink(&self, outbox: Arc<Outbox>) {
        *self.credit_sink.write().unwrap() = Some(outbox);
    }

    /// Install the worker's metrics registry
    /// (`net.credits_granted_total`).
    pub fn install_metrics(&self, metrics: Arc<Metrics>) {
        let _ = self.metrics.set(metrics);
    }

    /// Credits newly earned across all registered channels, as
    /// `(src_worker, channel, amount)` — the receiver thread turns each
    /// into a [`Frame::credit`] back to its sender. Counted on
    /// `net.credits_granted_total`.
    pub fn take_grants(&self) -> Vec<(usize, u32, u64)> {
        let channels: Vec<(u32, Arc<ChannelRx>)> = self
            .channels
            .read()
            .unwrap()
            .iter()
            .map(|(c, rx)| (*c, rx.clone()))
            .collect();
        let mut out = Vec::new();
        for (channel, rx) in channels {
            for (src, amount) in rx.take_grants() {
                out.push((src, channel, amount));
            }
        }
        if !out.is_empty() {
            if let Some(m) = self.metrics.get() {
                m.counter("net.credits_granted_total")
                    .add(out.iter().map(|(_, _, a)| *a).sum());
            }
        }
        out
    }

    pub fn unregister(&self, channel: u32) {
        self.channels.write().unwrap().remove(&channel);
        // Buffered early frames for the channel die here — that is data
        // loss, so it must move the `dropped` gauge (and say so), not
        // vanish silently.
        if let Some(frames) = self.pending.lock().remove(&channel) {
            if !frames.is_empty() {
                self.dropped.fetch_add(frames.len() as u64, Ordering::Relaxed);
                log::warn!(
                    "unregister channel {channel}: dropped {} buffered early frame(s)",
                    frames.len()
                );
            }
        }
    }

    pub fn channel(&self, channel: u32) -> Option<Arc<ChannelRx>> {
        self.channels.read().unwrap().get(&channel).cloned()
    }

    /// Frames that arrived for unregistered channels (bug indicator).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Deliver one inbound frame.
    pub fn route(&self, frame: Frame) -> Result<()> {
        match frame.kind {
            FrameKind::Control => {
                // notify while the queue lock is held: recv_control
                // re-checks emptiness under this lock, so an unlocked
                // notify could land between its check and its park
                let mut q = self.control.lock();
                q.push_back(frame);
                self.control_ready.notify_one(&q);
                Ok(())
            }
            // needs no registered channel: a grant for a drained (even
            // already-unregistered) exchange must still reach the
            // outbox, or its lanes stay blocked
            FrameKind::Credit => {
                let amount = frame.credit_amount()?;
                if let Some(sink) = self.credit_sink.read().unwrap().as_ref() {
                    sink.grant_credits(frame.src, amount);
                }
                Ok(())
            }
            kind => {
                let rx = match self.channel(frame.channel) {
                    Some(rx) => rx,
                    None => {
                        // early frame: buffer until the DAG registers
                        // the channel (bounded)
                        let mut pending = self.pending.lock();
                        let q = pending.entry(frame.channel).or_default();
                        if q.len() < MAX_PENDING_PER_CHANNEL {
                            q.push(frame);
                        } else {
                            self.dropped.fetch_add(1, Ordering::Relaxed);
                        }
                        return Ok(());
                    }
                };
                match kind {
                    FrameKind::Data => {
                        let pool = self.bounce.read().unwrap().clone();
                        let decoded = unframe_payload(frame.payload, pool.as_ref())?;
                        rx.holder.push_host_bytes(decoded)?;
                        // only a delivered frame earns a future credit
                        rx.note_delivered(frame.src);
                        Ok(())
                    }
                    FrameKind::Finish => {
                        let n = rx.finishes.fetch_add(1, Ordering::AcqRel) + 1;
                        if n >= rx.expected_senders {
                            rx.holder.finish();
                        }
                        Ok(())
                    }
                    FrameKind::SizeEstimate => {
                        let bytes = frame.estimate_bytes()?;
                        rx.estimates.lock().unwrap().insert(frame.src, bytes);
                        Ok(())
                    }
                    FrameKind::Control | FrameKind::Credit => unreachable!(),
                }
            }
        }
    }

    /// Next control frame, if any.
    pub fn recv_control(&self, timeout: Duration) -> Option<Frame> {
        let deadline = std::time::Instant::now() + timeout;
        let mut q = self.control.lock();
        loop {
            if let Some(f) = q.pop_front() {
                return Some(f);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.control_ready.wait_timeout(q, deadline - now);
            q = guard;
        }
    }
}

/// Frame an outbound batch's bytes as a wire payload.
///
/// * No compression + slab-backed bytes: the payload *is* the holder's
///   slab plus a 9-byte heap prelude — zero copies on this hop, and the
///   transport sends it vectored.
/// * No compression + heap bytes: staged once into the bounce pool (the
///   copy the old `encode()` path paid anyway, now into pinned memory);
///   heap framing when the pool is dry or absent.
/// * Real codec: the compressor streams the slab chunks straight into
///   a `SlabWriter` — one staged copy, no intermediate heap `Vec`.
///   Pool-resident input makes that an intra-pool transform, which the
///   writer keeps out of `bounce_bytes` (the bytes were counted when
///   they entered the pool); a dry pool falls back to a heap-compressed
///   payload and moves `codec.heap_fallback_bytes`.
fn build_data_payload(
    encoded: StagedBytes,
    codec: Codec,
    bounce: Option<&PinnedPool>,
) -> Payload {
    match codec {
        Codec::None => {
            let prelude = Codec::None.prelude(encoded.len()).to_vec();
            match encoded {
                StagedBytes::Pinned(body) => Payload::pinned(prelude, body),
                StagedBytes::Heap(v) => {
                    let staged = bounce.and_then(|pool| {
                        let mut w = SlabWriter::with_capacity(pool, v.len()).ok()?;
                        w.write_bytes(&v).ok()?;
                        Some(w.finish())
                    });
                    match staged {
                        Some(slab) => Payload::pinned(prelude, SlabSlice::whole(slab)),
                        None => {
                            let mut framed = prelude;
                            framed.extend_from_slice(&v);
                            Payload::Heap(framed)
                        }
                    }
                }
            }
        }
        codec => {
            let chunks = encoded.chunks();
            if let Some(pool) = bounce {
                let mut w = SlabWriter::new(pool).count_bounce(!encoded.is_pinned());
                match codec.compress_chunks_into(&chunks, &mut w) {
                    Ok(_) => {
                        return Payload::pinned(Vec::new(), SlabSlice::whole(w.finish()))
                    }
                    // pool ran dry mid-compress (surfaces as the slab
                    // writer's OutOfMemory io error): discard the
                    // partial slab (buffers return on drop) and redo on
                    // heap. Heap compression is infallible, so any
                    // *other* error still degrades to a correct
                    // payload — but loudly, it isn't pool pressure.
                    Err(e) => {
                        let dry = matches!(
                            &e,
                            Error::Io(io) if io.kind() == std::io::ErrorKind::OutOfMemory
                        ) || matches!(&e, Error::PinnedExhausted { .. });
                        if !dry {
                            log::warn!("slab compression failed ({e}); heap fallback");
                        }
                        pool.note_codec_fallback(encoded.len());
                    }
                }
            }
            Payload::Heap(codec.compress_chunks(&chunks))
        }
    }
}

/// Strip the codec framing off a received data payload, preserving the
/// slab backing wherever possible: uncompressed slab payloads hand the
/// very buffers the socket read into (or, on the in-proc fabric, the
/// buffers the *sender's* holder held) to the destination holder;
/// compressed payloads decompress from their slab chunks straight into
/// a fresh slab from `bounce` ([`Codec::decompress_slices_into`]),
/// falling back to the heap — counted — when the pool is dry or absent.
fn unframe_payload(payload: Payload, bounce: Option<&PinnedPool>) -> Result<StagedBytes> {
    match payload {
        Payload::Heap(mut v) => {
            let (codec, orig) = Codec::parse_prelude(&v)?;
            if matches!(codec, Codec::None) {
                if v.len() - PRELUDE_LEN != orig {
                    return Err(Error::Format(format!(
                        "payload length mismatch: {} vs {orig}",
                        v.len() - PRELUDE_LEN
                    )));
                }
                v.drain(..PRELUDE_LEN); // in-place shift, no realloc
                return Ok(StagedBytes::Heap(v));
            }
            // heap payload (pool was dry at wire-read time, or sender
            // fell back): decompressing is a fresh staging copy
            decompress_staged(&[v.as_slice()], orig, false, bounce)
        }
        Payload::Pinned { prelude, body } => {
            if prelude.len() == PRELUDE_LEN {
                // sender-built frame: the prelude never entered the slab
                let (codec, orig) = Codec::parse_prelude(&prelude)?;
                if matches!(codec, Codec::None) && body.len() == orig {
                    return Ok(StagedBytes::Pinned(body)); // zero-copy handover
                }
                let body_chunks = body.chunks();
                let mut chunks: Vec<&[u8]> = Vec::with_capacity(1 + body_chunks.len());
                chunks.push(prelude.as_slice());
                chunks.extend(body_chunks);
                return decompress_staged(&chunks, orig, true, bounce);
            }
            if prelude.is_empty() {
                // receive path: the whole framed payload is in the slab
                if body.len() < PRELUDE_LEN {
                    return Err(Error::Format("payload too short".into()));
                }
                let head = body.slice(0, PRELUDE_LEN).to_vec();
                let (codec, orig) = Codec::parse_prelude(&head)?;
                if matches!(codec, Codec::None) && body.len() - PRELUDE_LEN == orig {
                    // slice the prelude off — the batch bytes stay pinned
                    return Ok(StagedBytes::Pinned(body.slice(PRELUDE_LEN, orig)));
                }
                return decompress_staged(&body.chunks(), orig, true, bounce);
            }
            Err(Error::Network(format!(
                "malformed pinned payload: {}-byte prelude",
                prelude.len()
            )))
        }
    }
}

/// Decompress a framed payload (as vectored chunks claiming `orig`
/// output bytes) into the bounce pool, heap-falling-back when the pool
/// is dry or absent. `input_pinned` tells the bounce accounting whether
/// this is an intra-pool transform (wire bytes already staged) or a
/// fresh staging copy.
fn decompress_staged(
    chunks: &[&[u8]],
    orig: usize,
    input_pinned: bool,
    bounce: Option<&PinnedPool>,
) -> Result<StagedBytes> {
    if let Some(pool) = bounce {
        match SlabWriter::with_capacity(pool, orig) {
            Ok(w) => {
                let mut w = w.count_bounce(!input_pinned);
                let claimed = Codec::decompress_slices_into(chunks, &mut w)?;
                if w.len() != claimed {
                    return Err(Error::Format(format!(
                        "decompressed payload length mismatch: {} vs {claimed}",
                        w.len()
                    )));
                }
                return Ok(StagedBytes::Pinned(SlabSlice::whole(w.finish())));
            }
            // dry (or orig over-claims the whole pool): heap below.
            // `orig` is a wire-supplied claim — record the fallback
            // with a pool-bounded value so a corrupt frame's huge
            // claim cannot poison the gauge (the reservation itself is
            // already safe: an over-pool claim is refused without
            // raising pressure).
            Err(Error::PinnedExhausted { .. }) => {
                let pool_cap = pool.buf_size() * pool.total_buffers();
                pool.note_codec_fallback(orig.min(pool_cap));
            }
            Err(e) => return Err(e),
        }
    }
    let input: usize = chunks.iter().map(|c| c.len()).sum();
    // speculative prealloc only — `orig` is an untrusted claim
    let mut out =
        Vec::with_capacity(crate::storage::compression::clamp_prealloc(orig, input));
    let claimed = Codec::decompress_slices_into(chunks, &mut out)?;
    if out.len() != claimed {
        return Err(Error::Format(format!(
            "decompressed payload length mismatch: {} vs {claimed}",
            out.len()
        )));
    }
    Ok(StagedBytes::Heap(out))
}

/// Send attempts per frame before a sender lane escalates peer-down
/// and drops the frame (`net.peer_down_total`). The pre-send fault
/// gate retries transient faults with short deterministic backoff;
/// attempts past the first count on `net.send_retry_total`.
const NET_SEND_ATTEMPTS: usize = 4;

/// The executor: sender lanes + one receiver thread.
pub struct NetworkExecutor {
    outbox: Arc<Outbox>,
    router: Arc<Router>,
    shutdown: Arc<AtomicBool>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    sent_bytes_precompress: Arc<AtomicU64>,
    sent_bytes_wire: Arc<AtomicU64>,
    compress_ns: Arc<AtomicU64>,
    /// Per-query send attribution, keyed by the query-id half of the
    /// channel id (`channel >> 16`): (pre-compress bytes, wire bytes,
    /// compress ns). Metric names are static, so per-qid data lives
    /// here and the query driver reads it out by qid.
    per_query: Arc<Mutex<std::collections::HashMap<u16, (u64, u64, u64)>>>,
}

impl NetworkExecutor {
    /// Start `threads` sender lanes + 1 receiver over `endpoint`.
    /// `bounce` is the worker's pinned pool: outbound frames are staged
    /// (or passed through) slab-backed so the transport can send them
    /// vectored from page-locked memory; `None` (Fig-4 config A) keeps
    /// everything on the heap.
    pub fn start(
        endpoint: Arc<dyn Endpoint>,
        outbox: Arc<Outbox>,
        router: Arc<Router>,
        compression: Option<Codec>,
        bounce: Option<PinnedPool>,
        threads: usize,
    ) -> Arc<NetworkExecutor> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let ex = Arc::new(NetworkExecutor {
            outbox: outbox.clone(),
            router: router.clone(),
            shutdown: shutdown.clone(),
            handles: Mutex::new(Vec::new()),
            sent_bytes_precompress: Arc::new(AtomicU64::new(0)),
            sent_bytes_wire: Arc::new(AtomicU64::new(0)),
            compress_ns: Arc::new(AtomicU64::new(0)),
            per_query: Arc::new(Mutex::new(std::collections::HashMap::new())),
        });
        let lanes = threads.max(1);
        let me = endpoint.worker_id();
        // inbound credit grants unblock this worker's own sender lanes
        router.install_credit_sink(outbox.clone());
        let mut handles = Vec::new();
        for lane in 0..lanes {
            let outbox = outbox.clone();
            let endpoint = endpoint.clone();
            let stop = shutdown.clone();
            let pre = ex.sent_bytes_precompress.clone();
            let wire = ex.sent_bytes_wire.clone();
            let cns = ex.compress_ns.clone();
            let per_query = ex.per_query.clone();
            let bounce = bounce.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("theseus-netsend-{me}-{lane}"))
                    .spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            let m = match outbox.pop_for_lane(
                                lane,
                                lanes,
                                Duration::from_millis(50),
                            ) {
                                Some(m) => m,
                                None => continue,
                            };
                            let frame = match m {
                                Outbound::Data { dst, channel, encoded } => {
                                    let pre_len = encoded.len() as u64;
                                    pre.fetch_add(pre_len, Ordering::Relaxed);
                                    let t0 = std::time::Instant::now();
                                    let payload = build_data_payload(
                                        encoded,
                                        compression.unwrap_or(Codec::None),
                                        bounce.as_ref(),
                                    );
                                    let dt = t0.elapsed().as_nanos() as u64;
                                    cns.fetch_add(dt, Ordering::Relaxed);
                                    wire.fetch_add(payload.len() as u64, Ordering::Relaxed);
                                    {
                                        let mut pq = per_query.lock().unwrap();
                                        let e = pq
                                            .entry((channel >> 16) as u16)
                                            .or_insert((0, 0, 0));
                                        e.0 += pre_len;
                                        e.1 += payload.len() as u64;
                                        e.2 += dt;
                                    }
                                    Frame::data_payload(me, dst, channel, payload)
                                }
                                Outbound::Finish { dst, channel } => {
                                    Frame::finish(me, dst, channel)
                                }
                                Outbound::Estimate { dst, channel, bytes } => {
                                    Frame::size_estimate(me, dst, channel, bytes)
                                }
                            };
                            let dst = frame.dst;
                            let t0 = std::time::Instant::now();
                            // Pre-send fault gate: `endpoint.send`
                            // consumes the frame by value, so transient
                            // send faults must be retried *before* it —
                            // afterwards there is nothing left to send.
                            let mut send_err = None;
                            for attempt in 1..=NET_SEND_ATTEMPTS {
                                match crate::fault::check(crate::fault::FaultSite::NetSend)
                                {
                                    Ok(()) => break,
                                    Err(e) if attempt == NET_SEND_ATTEMPTS => {
                                        send_err = Some(e);
                                    }
                                    Err(e) => {
                                        if let Some(m) = outbox.metrics.get() {
                                            m.counter("net.send_retry_total").inc();
                                            m.counter("retry.attempts_total").inc();
                                        }
                                        log::warn!(
                                            "netsend to {dst} attempt {attempt}: {e}, retrying"
                                        );
                                        std::thread::sleep(crate::fault::backoff(
                                            "net_send", attempt, 1,
                                        ));
                                    }
                                }
                            }
                            match send_err {
                                Some(e) => {
                                    // Peer-down escalation: the frame is
                                    // dropped loudly; the query recovers
                                    // (if at all) at the gateway rung.
                                    if let Some(m) = outbox.metrics.get() {
                                        m.counter("net.peer_down_total").inc();
                                    }
                                    log::error!(
                                        "netsend to {dst}: peer down after \
                                         {NET_SEND_ATTEMPTS} attempts ({e}); frame dropped"
                                    );
                                }
                                None => {
                                    if let Err(e) = endpoint.send(frame) {
                                        if let Some(m) = outbox.metrics.get() {
                                            m.counter("net.peer_down_total").inc();
                                        }
                                        log::warn!("netsend: {e}");
                                    }
                                }
                            }
                            // per-destination wire latency: one of the
                            // two signals the exchange's adaptive flush
                            // controller samples
                            outbox.note_send_latency(dst, t0.elapsed().as_nanos() as u64);
                            // after the send (or its failure) completes:
                            // flush() may now consider this message done
                            outbox.done_sending();
                        }
                    })
                    .expect("spawn netsend"),
            );
        }
        {
            let endpoint = endpoint.clone();
            let router = router.clone();
            let stop = shutdown.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("theseus-netrecv-{me}"))
                    .spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            match endpoint.recv_timeout(Duration::from_millis(50)) {
                                Ok(Some(f)) => {
                                    // Injected receive fault = the frame
                                    // was lost on the dropped connection:
                                    // discard before routing.
                                    if let Err(e) =
                                        crate::fault::check(crate::fault::FaultSite::NetRecv)
                                    {
                                        log::warn!("netrecv: {e}, frame dropped");
                                    } else if let Err(e) = router.route(f) {
                                        log::warn!("netrecv route: {e}");
                                    }
                                }
                                Ok(None) => {}
                                Err(e) => log::warn!("netrecv: {e}"),
                            }
                            // return credits for batches the consumer
                            // drained since the last pass — sent
                            // directly (not via the outbox) so grants
                            // are never themselves credit-gated
                            for (dst, channel, amount) in router.take_grants() {
                                if let Err(e) =
                                    endpoint.send(Frame::credit(me, dst, channel, amount))
                                {
                                    log::warn!("netrecv credit grant: {e}");
                                }
                            }
                        }
                    })
                    .expect("spawn netrecv"),
            );
        }
        *ex.handles.lock().unwrap() = handles;
        ex
    }

    pub fn outbox(&self) -> &Arc<Outbox> {
        &self.outbox
    }

    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// (bytes before compression, bytes on the wire).
    pub fn compression_ratio_inputs(&self) -> (u64, u64) {
        (
            self.sent_bytes_precompress.load(Ordering::Relaxed),
            self.sent_bytes_wire.load(Ordering::Relaxed),
        )
    }

    /// CPU time spent compressing (the resource Fig-4 E reclaims).
    pub fn compress_time(&self) -> Duration {
        Duration::from_nanos(self.compress_ns.load(Ordering::Relaxed))
    }

    /// One query's send-side attribution: (pre-compress bytes, wire
    /// bytes, compress time). `qid16` is the query-id half of the
    /// channel id (`qid % 65536` — the same truncation channel ids
    /// carry on the wire).
    pub fn query_net(&self, qid16: u16) -> (u64, u64, Duration) {
        self.per_query
            .lock()
            .unwrap()
            .get(&qid16)
            .map_or((0, 0, Duration::ZERO), |&(p, w, ns)| {
                (p, w, Duration::from_nanos(ns))
            })
    }

    /// Drop one finished query's send attribution.
    pub fn clear_query(&self, qid16: u16) {
        self.per_query.lock().unwrap().remove(&qid16);
    }

    /// Wait until the outbox drains *and* every popped message has left
    /// the sender lanes (query epilogue), then keep threads running for
    /// the next query. An empty queue alone is not enough — a message
    /// popped by a lane may still be compressing or mid-send, so
    /// returning on emptiness would race callers that read send-side
    /// state (metrics, peers' inboxes) right after flushing.
    pub fn flush(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while !self.outbox.is_idle() {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.outbox.close();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for NetworkExecutor {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.outbox.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TransportKind;
    use crate::memory::batch_holder::MemEnv;
    use crate::network::InprocHub;
    use crate::sim::SimContext;
    use crate::types::Column;

    fn batch(rows: usize) -> RecordBatch {
        RecordBatch::new(vec![Column::i64("k", (0..rows as i64).collect())]).unwrap()
    }

    fn two_workers(
        compression: Option<Codec>,
    ) -> (Vec<Arc<NetworkExecutor>>, Vec<Arc<Router>>) {
        two_workers_with(compression, None)
    }

    fn two_workers_with(
        compression: Option<Codec>,
        bounce: Option<PinnedPool>,
    ) -> (Vec<Arc<NetworkExecutor>>, Vec<Arc<Router>>) {
        let hub = InprocHub::new(2, &SimContext::test(), TransportKind::Tcp);
        let eps = hub.endpoints();
        let mut exes = Vec::new();
        let mut routers = Vec::new();
        for ep in eps {
            let router = Arc::new(Router::new());
            let outbox = Arc::new(Outbox::new(16));
            routers.push(router.clone());
            exes.push(NetworkExecutor::start(
                Arc::new(ep),
                outbox,
                router,
                compression,
                bounce.clone(),
                1,
            ));
        }
        (exes, routers)
    }

    #[test]
    fn batch_crosses_and_lands_in_holder() {
        let (exes, routers) = two_workers(Some(Codec::Zstd { level: 1 }));
        let holder = BatchHolder::new("rx", MemEnv::test(1 << 20));
        routers[1].register(7, Arc::new(ChannelRx::new(holder.clone(), 1)));

        let b = batch(100);
        exes[0].outbox().send_batch(1, 7, &b).unwrap();
        exes[0].outbox().send_finish(1, 7).unwrap();

        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !holder.is_finished() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(holder.is_finished());
        let got = holder.pop_device().unwrap().unwrap();
        assert_eq!(got.batch, b);
        for e in &exes {
            e.stop();
        }
    }

    #[test]
    fn slab_backed_exchange_keeps_bytes_in_the_pool() {
        // Uncompressed exchange over the bounce pool: the send stages
        // (or adopts) a slab, and the receiving holder adopts the slab
        // from the frame — no decompress-copy on the receive path.
        let pool = PinnedPool::new(4 << 10, 64).unwrap();
        let (exes, routers) = two_workers_with(None, Some(pool.clone()));
        let env = crate::memory::batch_holder::MemEnv {
            pinned: Some(pool.clone()),
            ..crate::memory::batch_holder::MemEnv::test(1 << 20)
        };
        let holder = BatchHolder::new("rx", env);
        routers[1].register(7, Arc::new(ChannelRx::new(holder.clone(), 1)));

        let b = batch(500);
        exes[0].outbox().send_batch(1, 7, &b).unwrap();
        exes[0].outbox().send_finish(1, 7).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !holder.is_finished() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(holder.is_finished());
        // send staged once; the receive adopted the same slab: bounce
        // bytes grew by ~one payload, not two
        let staged = pool.bounce_bytes();
        assert!(staged >= b.encode().len() as u64, "send must stage into the pool");
        assert!(
            staged < 2 * b.encode().len() as u64,
            "receive must adopt the slab, not re-copy ({staged} bytes staged)"
        );
        assert_eq!(holder.stats().host_batches, 1, "landed at host tier");
        let got = holder.pop_device().unwrap().unwrap();
        assert_eq!(got.batch, b);
        for e in &exes {
            e.stop();
        }
    }

    #[test]
    fn compressed_exchange_keeps_bytes_in_the_pool() {
        // Codec-enabled exchange over the bounce pool: the send
        // compresses straight into a slab (one staged copy — the
        // compressed bytes), the receive decompresses into a slab as an
        // intra-pool transform (uncounted), and the holder adopts that
        // slab. Net: bounce_bytes moves by at most one (compressed)
        // payload for the whole round trip.
        for codec in [Codec::Zstd { level: 1 }, Codec::Lz4Like] {
            let pool = PinnedPool::new(4 << 10, 64).unwrap();
            let (exes, routers) = two_workers_with(Some(codec), Some(pool.clone()));
            routers[1].install_bounce_pool(pool.clone());
            let env = crate::memory::batch_holder::MemEnv {
                pinned: Some(pool.clone()),
                ..crate::memory::batch_holder::MemEnv::test(1 << 20)
            };
            let holder = BatchHolder::new("rx", env);
            routers[1].register(7, Arc::new(ChannelRx::new(holder.clone(), 1)));

            // compressible batch, well over one pool buffer when decoded
            let b = RecordBatch::new(vec![Column::i64("k", vec![42; 4096])]).unwrap();
            let orig = b.encode().len() as u64;
            exes[0].outbox().send_batch(1, 7, &b).unwrap();
            exes[0].outbox().send_finish(1, 7).unwrap();
            let deadline = std::time::Instant::now() + Duration::from_secs(2);
            while !holder.is_finished() && std::time::Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(5));
            }
            assert!(holder.is_finished(), "{}", codec.name());

            let staged = pool.bounce_bytes();
            assert!(staged > 0, "{}: send must stage into the pool", codec.name());
            assert!(
                staged < orig,
                "{}: only the compressed bytes may count — decompression is an \
                 intra-pool transform, not a second bounce ({staged} vs {orig})",
                codec.name()
            );
            assert_eq!(
                pool.codec_heap_fallback_bytes(),
                0,
                "{}: a roomy pool must not fall back",
                codec.name()
            );
            // the decompressed payload landed pinned and was adopted
            assert_eq!(holder.residency().host_pinned_bytes, orig as usize);
            let got = holder.pop_device().unwrap().unwrap();
            assert_eq!(got.batch, b, "{}", codec.name());
            for e in &exes {
                e.stop();
            }
        }
    }

    #[test]
    fn compressed_exchange_survives_a_dry_pool() {
        // Pool too small for anything: both directions heap-fall-back,
        // the gauge records it, and the bytes still arrive intact.
        let pool = PinnedPool::new(64, 1).unwrap();
        let _hold = pool.try_acquire().unwrap(); // keep it dry
        let (exes, routers) = two_workers_with(Some(Codec::Lz4Like), Some(pool.clone()));
        routers[1].install_bounce_pool(pool.clone());
        let holder = BatchHolder::new("rx", MemEnv::test(1 << 20));
        routers[1].register(3, Arc::new(ChannelRx::new(holder.clone(), 1)));
        let b = batch(300);
        exes[0].outbox().send_batch(1, 3, &b).unwrap();
        exes[0].outbox().send_finish(1, 3).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !holder.is_finished() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(holder.is_finished());
        assert!(
            pool.codec_heap_fallback_bytes() > 0,
            "dry-pool operation must be visible on the gauge"
        );
        assert_eq!(holder.pop_device().unwrap().unwrap().batch, b);
        for e in &exes {
            e.stop();
        }
    }

    #[test]
    fn pooled_batch_send_is_slab_backed_end_to_end() {
        // send_batch_pooled: the encode lands in the pool, the wire
        // carries the slab, and the receiving holder adopts it — zero
        // StagedBytes::Heap anywhere on the path.
        let pool = PinnedPool::new(4 << 10, 64).unwrap();
        let (exes, routers) = two_workers_with(None, Some(pool.clone()));
        let env = crate::memory::batch_holder::MemEnv {
            pinned: Some(pool.clone()),
            ..crate::memory::batch_holder::MemEnv::test(1 << 20)
        };
        let holder = BatchHolder::new("rx", env);
        routers[1].register(5, Arc::new(ChannelRx::new(holder.clone(), 1)));

        let b = batch(700);
        let pinned = exes[0]
            .outbox()
            .send_batch_pooled(1, 5, &b, Some(&pool))
            .unwrap();
        assert!(pinned, "roomy pool must stage the encode in a slab");
        exes[0].outbox().send_finish(1, 5).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !holder.is_finished() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(holder.is_finished());
        assert_eq!(pool.codec_heap_fallback_bytes(), 0);
        assert_eq!(
            holder.residency().host_pinned_bytes,
            b.encoded_len(),
            "receive must adopt the sender's slab"
        );
        assert_eq!(holder.pop_device().unwrap().unwrap().batch, b);
        for e in &exes {
            e.stop();
        }
    }

    #[test]
    fn stage_encoded_heap_fallback_is_counted_and_identical() {
        let b = batch(200);
        // no pool: plain heap encode
        assert_eq!(stage_encoded(&b, None), b.encode());
        // roomy pool: slab-backed, same bytes
        let pool = PinnedPool::new(256, 64).unwrap();
        let staged = stage_encoded(&b, Some(&pool));
        assert!(staged.is_pinned());
        assert_eq!(staged, b.encode());
        drop(staged);
        // dry pool: heap fallback, counted, same bytes — and pressure-
        // neutral: the coalescing exchange flushes on the memory
        // epoch, so a dry-pool shuffle send must not re-arm it
        let dry = PinnedPool::new(64, 1).unwrap();
        let event = crate::memory::PressureEvent::new();
        dry.install_pressure(event.clone());
        let _hold = dry.try_acquire().unwrap();
        let staged = stage_encoded(&b, Some(&dry));
        assert!(!staged.is_pinned());
        assert_eq!(staged, b.encode());
        assert_eq!(dry.codec_heap_fallback_bytes(), b.encoded_len() as u64);
        assert_eq!(
            event.memory_raise_count(),
            0,
            "dry-pool staging fallback must not raise the flush epoch"
        );
    }

    #[test]
    fn finish_requires_all_senders() {
        let (exes, routers) = two_workers(None);
        let holder = BatchHolder::new("rx", MemEnv::test(1 << 20));
        let rx = Arc::new(ChannelRx::new(holder.clone(), 2));
        routers[0].register(3, rx.clone());

        // one finish (from worker 1) is not enough
        exes[1].outbox().send_finish(0, 3).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert!(!rx.all_finished());
        // self-finish completes it
        exes[0].outbox().send_finish(0, 3).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !rx.all_finished() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(rx.all_finished());
        assert!(holder.is_finished());
        for e in &exes {
            e.stop();
        }
    }

    #[test]
    fn estimates_collect() {
        let (exes, routers) = two_workers(None);
        let holder = BatchHolder::new("rx", MemEnv::test(1 << 20));
        let rx = Arc::new(ChannelRx::new(holder, 2));
        routers[1].register(9, rx.clone());
        exes[0].outbox().send_estimate(1, 9, 1000).unwrap();
        exes[1].outbox().send_estimate(1, 9, 2000).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while rx.estimates().0 < 2 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(rx.estimates(), (2, 3000));
        for e in &exes {
            e.stop();
        }
    }

    #[test]
    fn compression_shrinks_wire_bytes() {
        let (exes, routers) = two_workers(Some(Codec::Zstd { level: 1 }));
        let holder = BatchHolder::new("rx", MemEnv::test(1 << 20));
        routers[1].register(1, Arc::new(ChannelRx::new(holder.clone(), 1)));
        // compressible batch
        let b = RecordBatch::new(vec![Column::i64("k", vec![42; 8192])]).unwrap();
        exes[0].outbox().send_batch(1, 1, &b).unwrap();
        // flush returns only once in-flight sends completed, so the
        // metrics are final here — no settling sleep needed
        assert!(exes[0].flush(Duration::from_secs(2)));
        let (pre, wire) = exes[0].compression_ratio_inputs();
        assert!(wire < pre / 4, "compression ineffective: {wire} vs {pre}");
        assert!(exes[0].compress_time() > Duration::ZERO);
        for e in &exes {
            e.stop();
        }
    }

    #[test]
    fn early_frames_buffer_until_registration() {
        // Frames sent before the receiver registers the channel (a
        // worker built its DAG faster) must be delivered afterwards —
        // not dropped — or the exchange pair deadlocks.
        let (exes, routers) = two_workers(None);
        let b = batch(5);
        exes[0].outbox().send_batch(1, 999, &b).unwrap();
        exes[0].outbox().send_estimate(1, 999, 4242).unwrap();
        exes[0].outbox().send_finish(1, 999).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(routers[1].dropped(), 0, "early frames must buffer");

        // late registration: everything replays
        let holder = BatchHolder::new("late", MemEnv::test(1 << 20));
        let rx = Arc::new(ChannelRx::new(holder.clone(), 1));
        routers[1].register(999, rx.clone());
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !rx.all_finished() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(rx.all_finished());
        assert_eq!(rx.estimates(), (1, 4242));
        assert_eq!(holder.pop_device().unwrap().unwrap().batch, b);
        for e in &exes {
            e.stop();
        }
    }

    #[test]
    fn outbox_backpressure_blocks_then_unblocks() {
        let outbox = Arc::new(Outbox::new(2));
        outbox.send_finish(0, 0).unwrap();
        outbox.send_finish(0, 0).unwrap();
        let o2 = outbox.clone();
        let h = std::thread::spawn(move || o2.send_finish(0, 0).is_ok());
        std::thread::sleep(Duration::from_millis(50));
        assert!(!h.is_finished(), "push should block while full");
        outbox.pop_for_lane(0, 1, Duration::from_millis(10)).unwrap();
        assert!(h.join().unwrap());
    }

    #[test]
    fn outbox_idle_tracks_in_flight_sends() {
        // The flush() contract: a popped-but-unsent message keeps the
        // outbox non-idle even though the queue is empty (the race the
        // old emptiness-only flush lost).
        let outbox = Outbox::new(4);
        assert!(outbox.is_idle());
        outbox.send_finish(0, 0).unwrap();
        assert!(!outbox.is_idle(), "queued message");
        let m = outbox.pop_for_lane(0, 1, Duration::from_millis(10)).unwrap();
        assert!(outbox.is_empty(), "queue drained");
        assert_eq!(outbox.in_flight(), 1);
        assert!(!outbox.is_idle(), "popped message is still in flight");
        drop(m);
        outbox.done_sending();
        assert!(outbox.is_idle(), "send completed");
    }

    #[test]
    fn credit_gating_blocks_data_and_holds_fifo() {
        let outbox = Outbox::new(16);
        let metrics = Arc::new(Metrics::default());
        outbox.install_metrics(metrics.clone());
        outbox.enable_credits(2);
        for _ in 0..3 {
            outbox.send_encoded(0, 7, vec![1u8, 2, 3]).unwrap();
        }
        outbox.send_finish(0, 7).unwrap();
        outbox.send_encoded(1, 7, vec![9u8]).unwrap();

        let pop = |ms: u64| outbox.pop_for_lane(0, 1, Duration::from_millis(ms));
        assert!(matches!(pop(10), Some(Outbound::Data { dst: 0, .. })));
        assert!(matches!(pop(10), Some(Outbound::Data { dst: 0, .. })));
        assert_eq!(outbox.credits_remaining(0), Some(0));
        // dst 0 exhausted: its third data frame AND the Finish behind
        // it hold their FIFO position; dst 1 (own window) proceeds
        assert!(matches!(pop(10), Some(Outbound::Data { dst: 1, .. })));
        assert!(pop(10).is_none(), "dst 0 must be fully blocked");
        assert!(metrics.counter_value("exchange.credit_stall_total") > 0);
        outbox.grant_credits(0, 1);
        assert!(matches!(pop(10), Some(Outbound::Data { dst: 0, .. })));
        assert!(matches!(pop(10), Some(Outbound::Finish { dst: 0, .. })));
        assert_eq!(outbox.close_unsent(), 0);
    }

    #[test]
    fn credit_grant_wakes_a_stalled_lane() {
        let outbox = Arc::new(Outbox::new(4));
        outbox.enable_credits(1);
        outbox.send_encoded(3, 0, vec![0u8]).unwrap();
        outbox.send_encoded(3, 0, vec![1u8]).unwrap();
        assert!(outbox.pop_for_lane(0, 1, Duration::from_millis(10)).is_some());
        let o2 = outbox.clone();
        let h = std::thread::spawn(move || o2.pop_for_lane(0, 1, Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(50));
        assert!(!h.is_finished(), "lane must stall at zero credit");
        outbox.grant_credits(3, 1);
        let got = h.join().unwrap();
        assert!(matches!(got, Some(Outbound::Data { dst: 3, .. })));
    }

    #[test]
    fn close_discards_credit_blocked_frames_and_releases_the_lane() {
        // The satellite fix: a close while a lane is credit-blocked
        // must let the drain complete — blocked data frames are
        // discarded loudly, later control frames still go out.
        let outbox = Outbox::new(16);
        let metrics = Arc::new(Metrics::default());
        outbox.install_metrics(metrics.clone());
        outbox.enable_credits(1);
        outbox.send_encoded(0, 1, vec![1u8]).unwrap();
        outbox.send_encoded(0, 1, vec![2u8]).unwrap();
        outbox.send_finish(0, 1).unwrap();
        let pop = |ms: u64| outbox.pop_for_lane(0, 1, Duration::from_millis(ms));
        assert!(matches!(pop(10), Some(Outbound::Data { .. })));
        assert!(pop(10).is_none(), "second frame blocked at zero credit");
        assert_eq!(outbox.len(), 2, "blocked frames stay queued before close");
        outbox.close();
        assert!(
            matches!(pop(10), Some(Outbound::Finish { .. })),
            "close must discard the blocked data frame and surface the Finish"
        );
        assert_eq!(outbox.close_unsent(), 1);
        assert_eq!(metrics.counter_value("net.close_unsent_total"), 1);
        assert!(pop(10).is_none());
        assert!(outbox.is_empty(), "drain completed");
    }

    #[test]
    fn credit_round_trip_throttles_then_completes() {
        // End to end over the in-proc fabric: a window of 1 and a
        // consumer that does not drain bounds delivery at 1 batch; each
        // pop then earns a grant that releases the next frame, and the
        // Finish arrives last.
        let (exes, routers) = two_workers(None);
        exes[0].outbox().enable_credits(1);
        let holder = BatchHolder::new("rx", MemEnv::test(1 << 20));
        let rx = Arc::new(ChannelRx::new(holder.clone(), 1));
        routers[1].register(4, rx.clone());

        let b = batch(50);
        for _ in 0..3 {
            exes[0].outbox().send_batch(1, 4, &b).unwrap();
        }
        exes[0].outbox().send_finish(1, 4).unwrap();

        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(holder.stats().host_batches, 1, "window must bound delivery");
        assert!(!holder.is_finished(), "Finish held behind blocked data");
        assert_eq!(exes[0].outbox().credits_remaining(1), Some(0));

        let mut got = 0;
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while got < 3 && std::time::Instant::now() < deadline {
            match holder.pop_device().unwrap() {
                Some(p) => {
                    assert_eq!(p.batch, b);
                    got += 1;
                }
                None => std::thread::sleep(Duration::from_millis(5)),
            }
        }
        assert_eq!(got, 3, "all batches delivered once credits flow");
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !holder.is_finished() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(holder.is_finished());
        for e in &exes {
            e.stop();
        }
    }

    #[test]
    fn unregister_counts_dropped_early_frames() {
        // Buffered early frames discarded by unregister are data loss
        // and must move the `dropped` gauge.
        let (exes, routers) = two_workers(None);
        exes[0].outbox().send_batch(1, 777, &batch(3)).unwrap();
        exes[0].outbox().send_estimate(1, 777, 99).unwrap();
        assert!(exes[0].flush(Duration::from_secs(2)));
        assert_eq!(routers[1].dropped(), 0, "buffering alone must not count");
        // flush only covers the send side; the receiver thread may not
        // have routed both frames into the pending buffer yet — keep
        // unregistering until both discards are counted (late arrivals
        // re-buffer on the unregistered channel and are counted by the
        // next unregister)
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        routers[1].unregister(777);
        while routers[1].dropped() < 2 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
            routers[1].unregister(777);
        }
        assert_eq!(
            routers[1].dropped(),
            2,
            "unregister must count the buffered frames it discards"
        );
        // idempotent: nothing left to count
        routers[1].unregister(777);
        assert_eq!(routers[1].dropped(), 2);
        for e in &exes {
            e.stop();
        }
    }
}
