//! Memory Executor (§3.3.2): spills Batch-Holder contents to larger
//! memories under pressure, cooperating with — not competing against —
//! the Compute Executor.
//!
//! Two triggers:
//! * **Watermark monitor**: a background thread watches device
//!   utilization; above `spill_watermark` it spills proactively
//!   ("tasked with resolving this situation before it occurs").
//! * **Reservation pressure**: the [`crate::memory::MemoryGovernor`]
//!   invokes [`MemoryExecutor::spill_for`] synchronously when a
//!   reservation cannot be granted.
//!
//! Victim selection inspects the Compute Executor's queue: holders
//! whose operators have high-priority queued tasks are spilled *last*
//! ("to avoid spilling data for which compute tasks are close to being
//! executed").

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::executors::compute::TaskQueue;
use crate::memory::{BatchHolder, DeviceArena};

/// Holders under management, tagged by owning operator.
#[derive(Default)]
pub struct HolderRegistry {
    holders: Mutex<Vec<(usize, BatchHolder)>>,
}

impl HolderRegistry {
    pub fn new() -> Arc<HolderRegistry> {
        Arc::new(HolderRegistry::default())
    }

    pub fn register(&self, op: usize, holder: BatchHolder) {
        self.holders.lock().unwrap().push((op, holder));
    }

    pub fn clear(&self) {
        self.holders.lock().unwrap().clear();
    }

    pub fn snapshot(&self) -> Vec<(usize, BatchHolder)> {
        self.holders.lock().unwrap().clone()
    }

    /// Total device bytes across registered holders.
    pub fn device_bytes(&self) -> usize {
        self.snapshot()
            .iter()
            .map(|(_, h)| h.stats().device_bytes)
            .sum()
    }
}

/// The executor.
pub struct MemoryExecutor {
    registry: Arc<HolderRegistry>,
    arena: DeviceArena,
    queue: Arc<TaskQueue>,
    watermark: f64,
    shutdown: Arc<AtomicBool>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    spills: Arc<AtomicU64>,
    spilled_bytes: Arc<AtomicU64>,
}

impl MemoryExecutor {
    pub fn start(
        registry: Arc<HolderRegistry>,
        arena: DeviceArena,
        queue: Arc<TaskQueue>,
        watermark: f64,
        threads: usize,
    ) -> Arc<MemoryExecutor> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let ex = Arc::new(MemoryExecutor {
            registry,
            arena,
            queue,
            watermark,
            shutdown: shutdown.clone(),
            handle: Mutex::new(None),
            spills: Arc::new(AtomicU64::new(0)),
            spilled_bytes: Arc::new(AtomicU64::new(0)),
        });
        // The watermark monitor; `threads` > 1 adds no value for a
        // polling loop, so one thread monitors and spill_for() runs on
        // caller threads (the paper's "tasks" are both kinds).
        let _ = threads;
        let ex2 = ex.clone();
        let stop = shutdown;
        *ex.handle.lock().unwrap() = Some(
            std::thread::Builder::new()
                .name("theseus-memexec".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        if ex2.arena.utilization() > ex2.watermark {
                            let target = (ex2.arena.capacity() as f64
                                * (ex2.arena.utilization() - ex2.watermark))
                                as usize;
                            ex2.spill_for(target.max(1));
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                })
                .expect("spawn memexec"),
        );
        ex
    }

    /// Spill until ~`bytes` of device memory have been freed (or no
    /// victims remain). Returns bytes actually freed. Reentrant: also
    /// invoked synchronously from reservation pressure callbacks.
    pub fn spill_for(&self, bytes: usize) -> usize {
        let mut freed = 0usize;
        // victims: holders with device bytes, coldest operator first
        // (lowest queued priority; operators with no queued tasks are
        // coldest of all).
        let prios = self.queue.op_priorities();
        let mut victims: Vec<(i64, usize, BatchHolder)> = self
            .registry
            .snapshot()
            .into_iter()
            .filter_map(|(op, h)| {
                let st = h.stats();
                if st.device_bytes == 0 {
                    return None;
                }
                let prio = prios.get(&op).copied().unwrap_or(i64::MIN);
                Some((prio, st.device_bytes, h))
            })
            .collect();
        // coldest first; among equals, fattest first
        victims.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        for (_, _, h) in victims {
            while freed < bytes {
                match h.spill_one() {
                    Ok(0) => break,
                    Ok(n) => {
                        freed += n;
                        self.spills.fetch_add(1, Ordering::Relaxed);
                        self.spilled_bytes.fetch_add(n as u64, Ordering::Relaxed);
                    }
                    Err(e) => {
                        log::warn!("spill failed: {e}");
                        break;
                    }
                }
            }
            if freed >= bytes {
                break;
            }
        }
        freed
    }

    /// Demote host-tier data to disk (pinned-pool pressure).
    pub fn spill_host_for(&self, bytes: usize) -> usize {
        let mut freed = 0usize;
        for (_, h) in self.registry.snapshot() {
            while freed < bytes {
                match h.spill_host_one() {
                    Ok(0) => break,
                    Ok(n) => {
                        freed += n;
                        self.spills.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        log::warn!("host spill failed: {e}");
                        break;
                    }
                }
            }
            if freed >= bytes {
                break;
            }
        }
        freed
    }

    pub fn spill_count(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes.load(Ordering::Relaxed)
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for MemoryExecutor {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Task;
    use crate::memory::batch_holder::MemEnv;
    use crate::types::{Column, RecordBatch};

    fn batch(rows: usize) -> RecordBatch {
        RecordBatch::new(vec![Column::i64("k", vec![7; rows])]).unwrap()
    }

    fn setup(cap: usize) -> (Arc<HolderRegistry>, MemEnv, Arc<TaskQueue>) {
        let env = MemEnv::test(cap);
        (HolderRegistry::new(), env, TaskQueue::new())
    }

    #[test]
    fn spill_for_frees_requested_bytes() {
        let (reg, env, queue) = setup(1 << 20);
        let h = BatchHolder::new("a", env.clone());
        reg.register(0, h.clone());
        for _ in 0..4 {
            h.push_batch(batch(1000)).unwrap();
        }
        let used = env.arena.in_use();
        assert!(used > 0);
        let ex = MemoryExecutor::start(reg, env.arena.clone(), queue, 1.1, 1);
        let freed = ex.spill_for(used / 2);
        assert!(freed >= used / 2, "{freed} < {}", used / 2);
        assert!(env.arena.in_use() <= used - freed);
        assert!(ex.spill_count() > 0);
        ex.stop();
    }

    #[test]
    fn cold_operators_spill_first() {
        let (reg, env, queue) = setup(1 << 20);
        let hot = BatchHolder::new("hot", env.clone());
        let cold = BatchHolder::new("cold", env.clone());
        reg.register(1, hot.clone());
        reg.register(2, cold.clone());
        hot.push_batch(batch(500)).unwrap();
        cold.push_batch(batch(500)).unwrap();
        // op 1 has a high-priority queued task; op 2 has none
        queue.submit(Task::new(1, 1_000, Arc::new(|_| Ok(()))));
        let ex = MemoryExecutor::start(reg, env.arena.clone(), queue, 1.1, 1);
        ex.spill_for(100);
        assert_eq!(cold.stats().device_batches, 0, "cold holder kept on device");
        assert_eq!(hot.stats().device_batches, 1, "hot holder spilled");
        ex.stop();
    }

    #[test]
    fn watermark_monitor_spills_automatically() {
        let env = MemEnv::test(100_000);
        let reg = HolderRegistry::new();
        let queue = TaskQueue::new();
        let h = BatchHolder::new("a", env.clone());
        reg.register(0, h.clone());
        let ex = MemoryExecutor::start(reg, env.arena.clone(), queue, 0.5, 1);
        // fill to ~96%
        for _ in 0..12 {
            h.push_batch(batch(1000)).unwrap();
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(3);
        while env.arena.utilization() > 0.55 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(
            env.arena.utilization() <= 0.55,
            "monitor failed to spill: {}",
            env.arena.utilization()
        );
        // data intact
        let mut rows = 0;
        while let Some(db) = h.pop_device().unwrap() {
            rows += db.rows();
        }
        assert_eq!(rows, 12_000);
        ex.stop();
    }

    #[test]
    fn host_spill_moves_to_disk() {
        let (reg, env, queue) = setup(1 << 20);
        let h = BatchHolder::new("a", env.clone());
        reg.register(0, h.clone());
        h.push_batch_host(batch(2000)).unwrap();
        let ex = MemoryExecutor::start(reg, env.arena.clone(), queue, 1.1, 1);
        let freed = ex.spill_host_for(1);
        assert!(freed > 0);
        assert_eq!(h.stats().disk_batches, 1);
        ex.stop();
    }

    #[test]
    fn pressure_callback_integration() {
        // The governor's pressure handler wired to spill_for unblocks a
        // reservation.
        let (reg, env, queue) = setup(10_000);
        let h = BatchHolder::new("a", env.clone());
        reg.register(0, h.clone());
        h.push_batch(batch(1000)).unwrap(); // 8000 bytes on device
        let ex = MemoryExecutor::start(reg, env.arena.clone(), queue, 1.1, 1);
        let gov = crate::memory::MemoryGovernor::new(env.arena.clone());
        let ex2 = ex.clone();
        gov.set_pressure_handler(move |need| ex2.spill_for(need));
        let r = gov.reserve(6_000, Duration::from_secs(2)).unwrap();
        assert_eq!(r.bytes(), 6_000);
        assert!(ex.spill_count() > 0);
        ex.stop();
    }
}
