//! Pre-loading Executor (§3.3.3): inspects the Compute Executor's
//! queue and materializes data ahead of execution.
//!
//! Two modes (both can be on concurrently, as in the paper):
//! * **Byte-Range Pre-loading** — for queued scan tasks, fetch the
//!   merged byte ranges into the task's staging cell so the compute
//!   task only decompresses and decodes. The compute task never waits
//!   on the pre-loader: if staging isn't `Done` when it runs, it
//!   fetches on its own (Insight B).
//! * **Compute-Task Pre-loading** — for queued tasks whose input holder
//!   has batches below the device tier, promote them toward the device
//!   (disk → host here; the host → device hop happens at pop time over
//!   the fast pinned path).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::exec::task::{Prefetch, StagingState};
use crate::executors::compute::TaskQueue;
use crate::storage::datasource::{CustomObjectStoreDatasource, Datasource};

/// Mode switches (Fig-4 H and I).
#[derive(Clone, Copy, Debug)]
pub struct PreloadModes {
    pub byte_range: bool,
    pub task: bool,
}

/// The executor.
pub struct PreloadExecutor {
    shutdown: Arc<AtomicBool>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    byte_range_loads: Arc<AtomicU64>,
    promotions: Arc<AtomicU64>,
}

impl PreloadExecutor {
    /// `custom` is the coalescing fetch path when the datasource is the
    /// custom one (byte-range preloading "merges sufficiently close
    /// byte ranges"); with a generic datasource byte-range preloading
    /// is unavailable (not a paper configuration either).
    pub fn start(
        queue: Arc<TaskQueue>,
        datasource: Arc<dyn Datasource>,
        custom: Option<Arc<CustomObjectStoreDatasource>>,
        modes: PreloadModes,
        threads: usize,
    ) -> Arc<PreloadExecutor> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let ex = Arc::new(PreloadExecutor {
            shutdown: shutdown.clone(),
            handles: Mutex::new(Vec::new()),
            byte_range_loads: Arc::new(AtomicU64::new(0)),
            promotions: Arc::new(AtomicU64::new(0)),
        });
        if !modes.byte_range && !modes.task {
            return ex; // disabled: no threads (Fig-4 F)
        }
        let mut handles = Vec::new();
        for t in 0..threads.max(1) {
            let queue = queue.clone();
            let ds = datasource.clone();
            let custom = custom.clone();
            let stop = shutdown.clone();
            let brl = ex.byte_range_loads.clone();
            let promos = ex.promotions.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("theseus-preload-{t}"))
                    .spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            let did = Self::pass(&queue, &ds, &custom, modes, &brl, &promos);
                            if !did {
                                std::thread::sleep(Duration::from_millis(3));
                            }
                        }
                    })
                    .expect("spawn preload"),
            );
        }
        *ex.handles.lock().unwrap() = handles;
        ex
    }

    /// One inspection pass. Returns true if any work was done.
    fn pass(
        queue: &TaskQueue,
        ds: &Arc<dyn Datasource>,
        custom: &Option<Arc<CustomObjectStoreDatasource>>,
        modes: PreloadModes,
        brl: &AtomicU64,
        promos: &AtomicU64,
    ) -> bool {
        // Snapshot prefetchable work from the queue (tasks are cloned;
        // staging cells and holders are shared).
        let mut byte_ranges = Vec::new();
        let mut promotes = Vec::new();
        queue.for_each_queued(|t| match &t.prefetch {
            Some(Prefetch::ByteRanges { key, ranges, staging }) if modes.byte_range => {
                byte_ranges.push((key.clone(), ranges.clone(), staging.clone()));
            }
            Some(Prefetch::Promote { holder }) if modes.task => {
                promotes.push(holder.clone());
            }
            _ => {}
        });

        let mut did = false;
        for (key, ranges, staging) in byte_ranges {
            // claim the cell ("temporarily take ownership of the task",
            // §3.2) — skip if another thread or the compute task got it
            {
                let mut s = staging.lock().unwrap();
                match *s {
                    StagingState::Empty => *s = StagingState::InProgress,
                    _ => continue,
                }
            }
            let fetched = match custom {
                Some(c) => c.fetch_ranges(&key, &ranges),
                None => {
                    let _ = ds;
                    Err(crate::Error::ObjectStore(
                        "byte-range preload requires the custom datasource".into(),
                    ))
                }
            };
            let mut s = staging.lock().unwrap();
            match fetched {
                Ok(pages) => {
                    *s = StagingState::Done(pages);
                    brl.fetch_add(1, Ordering::Relaxed);
                    did = true;
                }
                Err(e) => {
                    // release the claim; the compute task will fetch
                    log::debug!("byte-range preload {key}: {e}");
                    *s = StagingState::Empty;
                }
            }
        }

        for holder in promotes {
            match holder.promote_one_to_host() {
                Ok(true) => {
                    promos.fetch_add(1, Ordering::Relaxed);
                    did = true;
                }
                Ok(false) => {}
                Err(e) => log::debug!("promote: {e}"),
            }
        }
        did
    }

    pub fn byte_range_loads(&self) -> u64 {
        self.byte_range_loads.load(Ordering::Relaxed)
    }

    pub fn promotions(&self) -> u64 {
        self.promotions.load(Ordering::Relaxed)
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for PreloadExecutor {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::task::{take_staged, Staging, Task};
    use crate::memory::batch_holder::MemEnv;
    use crate::memory::BatchHolder;
    use crate::sim::SimContext;
    use crate::storage::compression::Codec;
    use crate::storage::datasource::{ByteRange, GenericDatasource};
    use crate::storage::format::FileWriter;
    use crate::storage::object_store::{ObjectStore, SimObjectStore};
    use crate::types::{Column, DType, Field, RecordBatch, Schema};

    fn store_with_file() -> (Arc<SimObjectStore>, Vec<ByteRange>) {
        let s = SimObjectStore::in_memory(&SimContext::test());
        let schema = Schema::new(vec![Field::new("k", DType::Int64)]);
        let batch = RecordBatch::new(vec![Column::i64("k", (0..512).collect())]).unwrap();
        let mut w = FileWriter::new(schema, Codec::None, 256);
        w.write(batch).unwrap();
        let file = w.finish().unwrap();
        s.put("t.ths", &file).unwrap();
        let ds = GenericDatasource::new(s.clone());
        let f = ds.footer("t.ths").unwrap();
        let ranges: Vec<ByteRange> = f.row_groups[0]
            .chunks
            .iter()
            .map(|c| ByteRange { offset: c.offset, len: c.len })
            .collect();
        (s, ranges)
    }

    #[test]
    fn byte_range_preload_fills_staging() {
        let (store, ranges) = store_with_file();
        let queue = TaskQueue::new();
        let custom = Arc::new(CustomObjectStoreDatasource::new(store.clone(), 1 << 20, None));
        let staging: Staging = Arc::new(Mutex::new(StagingState::Empty));
        // a queued scan task advertising its ranges
        queue.submit(
            Task::new(0, 100, Arc::new(|_| Ok(()))).with_prefetch(Prefetch::ByteRanges {
                key: "t.ths".into(),
                ranges,
                staging: staging.clone(),
            }),
        );
        let ex = PreloadExecutor::start(
            queue,
            custom.clone() as Arc<dyn Datasource>,
            Some(custom),
            PreloadModes { byte_range: true, task: true },
            1,
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        loop {
            if let Some(pages) = take_staged(&staging) {
                assert_eq!(pages.len(), 1);
                assert!(!pages[0].is_empty());
                break;
            }
            assert!(std::time::Instant::now() < deadline, "preload never completed");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(ex.byte_range_loads(), 1);
        ex.stop();
    }

    #[test]
    fn disabled_modes_do_nothing() {
        let (store, ranges) = store_with_file();
        let queue = TaskQueue::new();
        let custom = Arc::new(CustomObjectStoreDatasource::new(store.clone(), 0, None));
        let staging: Staging = Arc::new(Mutex::new(StagingState::Empty));
        queue.submit(
            Task::new(0, 100, Arc::new(|_| Ok(()))).with_prefetch(Prefetch::ByteRanges {
                key: "t.ths".into(),
                ranges,
                staging: staging.clone(),
            }),
        );
        let before = store.request_count();
        let ex = PreloadExecutor::start(
            queue,
            custom.clone() as Arc<dyn Datasource>,
            Some(custom),
            PreloadModes { byte_range: false, task: false },
            1,
        );
        std::thread::sleep(Duration::from_millis(80));
        assert!(matches!(*staging.lock().unwrap(), StagingState::Empty));
        assert_eq!(store.request_count(), before);
        ex.stop();
    }

    #[test]
    fn task_preload_promotes_disk_batches() {
        let env = MemEnv::test(1 << 20);
        let holder = BatchHolder::new("in", env.clone());
        let b = RecordBatch::new(vec![Column::i64("k", vec![1; 100])]).unwrap();
        holder.push_batch_host(b).unwrap();
        holder.spill_host_one().unwrap();
        assert_eq!(holder.stats().disk_batches, 1);

        let queue = TaskQueue::new();
        queue.submit(
            Task::new(1, 50, Arc::new(|_| Ok(())))
                .with_prefetch(Prefetch::Promote { holder: holder.clone() }),
        );
        let (store, _) = store_with_file();
        let ds: Arc<dyn Datasource> = Arc::new(GenericDatasource::new(store));
        let ex = PreloadExecutor::start(
            queue,
            ds,
            None,
            PreloadModes { byte_range: false, task: true },
            1,
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while holder.stats().disk_batches > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(holder.stats().disk_batches, 0, "disk batch not promoted");
        assert_eq!(holder.stats().host_batches, 1);
        assert!(ex.promotions() >= 1);
        ex.stop();
    }
}
