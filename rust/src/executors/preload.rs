//! Pre-loading Executor (§3.3.3), Byte-Range half: inspects the
//! Compute Executor's queue and fetches the merged byte ranges of
//! queued scan tasks into their staging cells so the compute task only
//! decompresses and decodes. The compute task never waits on the
//! pre-loader: if staging isn't `Done` when it runs, it fetches on its
//! own (Insight B).
//!
//! The *Compute-Task* half of §3.3.3 (promoting a queued task's
//! below-device batches back toward the device) lives in the
//! Data-Movement Executor ([`crate::executors::movement`]), where
//! promotion shares one victim/beneficiary policy with spilling —
//! demotion and promotion can no longer fight over a holder.
//!
//! Event-driven: submissions of prefetchable tasks mark a
//! [`PressureEvent`] this executor parks on (the seed polled the queue
//! every 3 ms).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::exec::task::{Prefetch, StagingState};
use crate::executors::compute::TaskQueue;
use crate::memory::PressureEvent;
use crate::storage::datasource::CustomObjectStoreDatasource;

/// Fallback sweep for missed edges; the wake path is the queue event.
const SWEEP: Duration = Duration::from_millis(100);

/// The executor.
pub struct PreloadExecutor {
    shutdown: Arc<AtomicBool>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    event: Arc<PressureEvent>,
    byte_range_loads: Arc<AtomicU64>,
    /// qid -> byte-range loads completed for that query's scan tasks.
    per_query: Arc<Mutex<std::collections::HashMap<u64, u64>>>,
}

impl PreloadExecutor {
    /// `custom` is the coalescing fetch path (byte-range preloading
    /// "merges sufficiently close byte ranges"); with a generic
    /// datasource (`custom = None`) byte-range preloading is
    /// unavailable (not a paper configuration either), so staging cells
    /// are left alone and compute tasks fetch for themselves. `enabled
    /// = false` (Fig-4 F/G) spawns no threads.
    pub fn start(
        queue: Arc<TaskQueue>,
        custom: Option<Arc<CustomObjectStoreDatasource>>,
        enabled: bool,
        threads: usize,
    ) -> Arc<PreloadExecutor> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let event = PressureEvent::new();
        let ex = Arc::new(PreloadExecutor {
            shutdown: shutdown.clone(),
            handles: Mutex::new(Vec::new()),
            event: event.clone(),
            byte_range_loads: Arc::new(AtomicU64::new(0)),
            per_query: Arc::new(Mutex::new(std::collections::HashMap::new())),
        });
        if !enabled {
            return ex; // disabled: no threads (Fig-4 F)
        }
        let Some(custom) = custom else {
            return ex; // generic datasource: nothing to coalesce-fetch
        };
        queue.add_listener(event.clone());
        let mut handles = Vec::new();
        for t in 0..threads.max(1) {
            let queue = queue.clone();
            let custom = custom.clone();
            let stop = shutdown.clone();
            let ev = event.clone();
            let brl = ex.byte_range_loads.clone();
            let per_query = ex.per_query.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("theseus-preload-{t}"))
                    .spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            // The snapshot content doesn't matter here:
                            // any wake (queue dirty or sweep) triggers
                            // one inspection pass; memory pressure is
                            // the movement plane's business.
                            ev.wait(SWEEP);
                            if stop.load(Ordering::Relaxed) {
                                return;
                            }
                            Self::pass(&queue, &custom, &brl, &per_query);
                        }
                    })
                    .expect("spawn preload"),
            );
        }
        *ex.handles.lock().unwrap() = handles;
        // catch tasks submitted before the listener attached
        event.mark_queue();
        ex
    }

    /// One inspection pass over the queued byte-range prefetches.
    fn pass(
        queue: &TaskQueue,
        custom: &Arc<CustomObjectStoreDatasource>,
        brl: &AtomicU64,
        per_query: &Mutex<std::collections::HashMap<u64, u64>>,
    ) {
        // Snapshot prefetchable work from the queue (staging cells are
        // shared; tasks stay queued).
        let mut byte_ranges = Vec::new();
        queue.for_each_queued(|t| {
            if let Some(Prefetch::ByteRanges { key, ranges, staging }) = &t.prefetch {
                byte_ranges.push((t.qid, key.clone(), ranges.clone(), staging.clone()));
            }
        });

        for (qid, key, ranges, staging) in byte_ranges {
            // claim the cell ("temporarily take ownership of the task",
            // §3.2) — skip if another thread or the compute task got it
            {
                let mut s = staging.lock().unwrap();
                match *s {
                    StagingState::Empty => *s = StagingState::InProgress,
                    _ => continue,
                }
            }
            let fetched = custom.fetch_ranges(&key, &ranges);
            let mut s = staging.lock().unwrap();
            match fetched {
                Ok(pages) => {
                    *s = StagingState::Done(pages);
                    brl.fetch_add(1, Ordering::Relaxed);
                    *per_query.lock().unwrap().entry(qid).or_insert(0) += 1;
                }
                Err(e) => {
                    // release the claim; the compute task will fetch
                    log::debug!("byte-range preload {key}: {e}");
                    *s = StagingState::Empty;
                }
            }
        }
    }

    pub fn byte_range_loads(&self) -> u64 {
        self.byte_range_loads.load(Ordering::Relaxed)
    }

    /// Byte-range loads completed for one query.
    pub fn loads_for(&self, qid: u64) -> u64 {
        self.per_query.lock().unwrap().get(&qid).copied().unwrap_or(0)
    }

    /// Drop one finished query's load counter.
    pub fn clear_query(&self, qid: u64) {
        self.per_query.lock().unwrap().remove(&qid);
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.event.mark_queue();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for PreloadExecutor {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.event.mark_queue();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::task::{take_staged, Staging, Task};
    use crate::sim::SimContext;
    use crate::storage::compression::Codec;
    use crate::storage::datasource::{ByteRange, Datasource, GenericDatasource};
    use crate::storage::format::FileWriter;
    use crate::storage::object_store::{ObjectStore, SimObjectStore};
    use crate::types::{Column, DType, Field, RecordBatch, Schema};

    fn store_with_file() -> (Arc<SimObjectStore>, Vec<ByteRange>) {
        let s = SimObjectStore::in_memory(&SimContext::test());
        let schema = Schema::new(vec![Field::new("k", DType::Int64)]);
        let batch = RecordBatch::new(vec![Column::i64("k", (0..512).collect())]).unwrap();
        let mut w = FileWriter::new(schema, Codec::None, 256);
        w.write(batch).unwrap();
        let file = w.finish().unwrap();
        s.put("t.ths", &file).unwrap();
        let ds = GenericDatasource::new(s.clone());
        let f = ds.footer("t.ths").unwrap();
        let ranges: Vec<ByteRange> = f.row_groups[0]
            .chunks
            .iter()
            .map(|c| ByteRange { offset: c.offset, len: c.len })
            .collect();
        (s, ranges)
    }

    #[test]
    fn byte_range_preload_fills_staging() {
        let (store, ranges) = store_with_file();
        let queue = TaskQueue::new();
        let custom = Arc::new(CustomObjectStoreDatasource::new(store.clone(), 1 << 20, None));
        let staging: Staging = Arc::new(Mutex::new(StagingState::Empty));
        let ex = PreloadExecutor::start(queue.clone(), Some(custom), true, 1);
        // a queued scan task advertising its ranges — submission marks
        // the event, which is what wakes the pre-loader
        queue.submit(
            Task::new(0, 100, Arc::new(|_| Ok(()))).with_prefetch(Prefetch::ByteRanges {
                key: "t.ths".into(),
                ranges,
                staging: staging.clone(),
            }),
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        loop {
            if let Some(pages) = take_staged(&staging) {
                assert_eq!(pages.len(), 1);
                assert!(!pages[0].is_empty());
                break;
            }
            assert!(std::time::Instant::now() < deadline, "preload never completed");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(ex.byte_range_loads(), 1);
        ex.stop();
    }

    #[test]
    fn disabled_preloader_does_nothing() {
        let (store, ranges) = store_with_file();
        let queue = TaskQueue::new();
        let custom = Arc::new(CustomObjectStoreDatasource::new(store.clone(), 0, None));
        let staging: Staging = Arc::new(Mutex::new(StagingState::Empty));
        queue.submit(
            Task::new(0, 100, Arc::new(|_| Ok(()))).with_prefetch(Prefetch::ByteRanges {
                key: "t.ths".into(),
                ranges,
                staging: staging.clone(),
            }),
        );
        let before = store.request_count();
        let ex = PreloadExecutor::start(queue, Some(custom), false, 1);
        std::thread::sleep(Duration::from_millis(80));
        assert!(matches!(*staging.lock().unwrap(), StagingState::Empty));
        assert_eq!(store.request_count(), before);
        ex.stop();
    }
}
