//! Data-Movement Executor: the unified, event-driven spill + promotion
//! plane (§3.3.2 "Memory Executor" and the promotion half of §3.3.3
//! "Pre-loading Executor", merged).
//!
//! The paper's thesis is that Theseus wins by *balancing data movement*
//! across memory tiers with "specialized asynchronous control
//! mechanisms". The seed split that job between a Memory Executor that
//! busy-polled device utilization every 5 ms and a Pre-load Executor
//! with its own threads and no shared victim policy — demotion and
//! promotion could fight over the same holders. This executor owns one
//! prioritized queue of [`MovementTask`]s and reacts to a shared
//! [`PressureEvent`] instead of polling:
//!
//! * [`crate::memory::DeviceArena`] raises device pressure on watermark
//!   crossings and failed allocations;
//! * [`crate::memory::MemoryGovernor`] raises it on reservations it
//!   cannot grant — and is woken back up by
//!   [`crate::memory::MemoryGovernor::notify_freed`] the moment a
//!   demotion frees bytes, so spills start (and blocked reservations
//!   clear) in microseconds, not on a 5 ms tick;
//! * [`crate::memory::PinnedPool`] raises host pressure when the
//!   fixed-size buffer pool runs dry;
//! * [`crate::executors::compute::TaskQueue`] marks the queue dirty
//!   when pre-loadable work is submitted.
//!
//! On every wake the planner computes victims (demotion) and
//! beneficiaries (promotion) in a *single* pass against one
//! [`TaskQueue::op_priorities`] snapshot: holders feeding imminent
//! compute tasks are spilled last and promoted first, for **both**
//! directions and **both** tier pairs (the seed's `spill_host_for`
//! ignored priorities entirely). A holder never appears as victim and
//! beneficiary in the same round, so the two directions cannot thrash.
//!
//! The loop is closed in both directions (§3.3.1): `op_priorities`
//! steers movement by compute intent, and every *completed* promotion
//! or demotion raises a `ResidencyChanged` notification
//! ([`TaskQueue::notify_residency_changed`]) so the compute queue
//! re-ranks tasks whose input holders just moved tiers.
//!
//! The same installed event doubles as the worker's *memory-pressure
//! epoch* ([`PressureEvent::memory_raise_count`]): buffering producers
//! — the coalescing exchange's per-destination shuffle builders — watch
//! it through [`crate::memory::DeviceArena::pressure_event`] and flush
//! early whenever a raise lands, so buffered shuffle state drains to
//! the wire instead of sitting in host memory while this executor is
//! busy demoting.

use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::sync::{ranks, OrderedCondvar, OrderedMutex};
use std::time::Duration;

use crate::exec::task::Prefetch;
use crate::executors::compute::TaskQueue;
use crate::memory::batch_holder::MemEnv;
use crate::memory::{BatchHolder, MemoryGovernor, PressureEvent, PressureSnapshot, Tier};
use crate::metrics::Metrics;

/// Fallback sweep interval: the planner parks on the pressure event and
/// only uses this to catch missed edges (e.g. pressure raised before
/// startup). It is a safety net, not the trigger — 50x coarser than the
/// seed's polling tick.
const SWEEP: Duration = Duration::from_millis(250);

/// Batches a single promotion task may stage per planning round. Bounds
/// how much disk data one round inflates into host memory; holders with
/// more keep their compute task queued, so the next wake or sweep plans
/// another round.
const PROMOTE_BATCHES_PER_ROUND: usize = 8;

/// Holders under management, tagged by owning (query, operator).
///
/// `device_bytes`/`host_bytes` read each holder's atomic tier counters
/// under the registry lock without cloning anything (the seed cloned
/// the whole holder list per call on the monitor path). The qid tag is
/// what lets a multi-query worker unregister exactly one finished
/// query's holders ([`HolderRegistry::clear_query`]) while concurrent
/// queries' holders stay under management.
pub struct HolderRegistry {
    holders: OrderedMutex<Vec<(u64, usize, BatchHolder)>>,
}

impl Default for HolderRegistry {
    fn default() -> Self {
        HolderRegistry {
            holders: OrderedMutex::new(
                ranks::MOVEMENT_HOLDERS,
                "movement.holders",
                Vec::new(),
            ),
        }
    }
}

impl HolderRegistry {
    pub fn new() -> Arc<HolderRegistry> {
        Arc::new(HolderRegistry::default())
    }

    pub fn register(&self, qid: u64, op: usize, holder: BatchHolder) {
        self.holders.lock().push((qid, op, holder));
    }

    pub fn clear(&self) {
        self.holders.lock().clear();
    }

    /// Unregister every holder belonging to one finished query.
    pub fn clear_query(&self, qid: u64) {
        self.holders.lock().retain(|(q, _, _)| *q != qid);
    }

    pub fn len(&self) -> usize {
        self.holders.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit every registered holder without cloning the list.
    pub fn for_each(&self, mut f: impl FnMut(u64, usize, &BatchHolder)) {
        for (qid, op, h) in self.holders.lock().iter() {
            f(*qid, *op, h);
        }
    }

    /// Total device bytes across registered holders (cheap: atomic
    /// reads under one lock, no clones).
    pub fn device_bytes(&self) -> usize {
        let mut total = 0;
        self.for_each(|_, _, h| total += h.stats().device_bytes);
        total
    }

    /// Total host bytes across registered holders.
    pub fn host_bytes(&self) -> usize {
        let mut total = 0;
        self.for_each(|_, _, h| total += h.stats().host_bytes);
        total
    }

    /// Aggregate residency across every registered holder (atomic reads
    /// under one lock — the worker-level view of where query data
    /// currently lives).
    pub fn residency(&self) -> crate::memory::ResidencySnapshot {
        let mut snap = crate::memory::ResidencySnapshot::default();
        self.for_each(|_, _, h| snap.merge(&h.residency()));
        snap
    }
}

/// Which way a movement task crosses tiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Demote,
    Promote,
}

/// One unit of planned data movement.
pub struct MovementTask {
    pub holder: BatchHolder,
    /// Query whose holder moves — per-qid spill/promotion attribution.
    pub qid: u64,
    pub op: usize,
    pub direction: Direction,
    pub from: Tier,
    pub to: Tier,
    /// Higher executes earlier. Demotions run at
    /// `urgency_reservation`/`urgency_watermark` minus the victim's
    /// coldness rank; promotions at the beneficiary task's priority —
    /// always below demotions, so relieving pressure wins.
    pub urgency: i64,
    /// Demote: stop once this many bytes moved. Promote: stop after
    /// this many batches staged (a per-round cap, not a total).
    pub budget: usize,
}

struct QueuedMove {
    urgency: i64,
    /// FIFO tiebreak: smaller sequence first.
    seq: u64,
    task: MovementTask,
}

impl PartialEq for QueuedMove {
    fn eq(&self, other: &Self) -> bool {
        self.urgency == other.urgency && self.seq == other.seq
    }
}
impl Eq for QueuedMove {}
impl PartialOrd for QueuedMove {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedMove {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.urgency
            .cmp(&other.urgency)
            .then(other.seq.cmp(&self.seq)) // max-heap: older first on tie
    }
}

/// The movement queue, shared between the executor and its threads as
/// its own `Arc` so worker threads never hold a strong reference to
/// the executor while parked (no `Arc` cycle: an executor dropped
/// without `stop()` still signals its threads down via `Drop`).
struct MoveQueue {
    heap: OrderedMutex<BinaryHeap<QueuedMove>>,
    ready: OrderedCondvar,
    seq: AtomicU64,
}

impl MoveQueue {
    fn new() -> Arc<MoveQueue> {
        Arc::new(MoveQueue {
            heap: OrderedMutex::new(
                ranks::MOVEMENT_HEAP,
                "movement.heap",
                BinaryHeap::new(),
            ),
            ready: OrderedCondvar::new(),
            seq: AtomicU64::new(0),
        })
    }

    fn push_all(&self, tasks: Vec<MovementTask>) {
        let mut heap = self.heap.lock();
        for task in tasks {
            heap.push(QueuedMove {
                urgency: task.urgency,
                seq: self.seq.fetch_add(1, Ordering::Relaxed),
                task,
            });
        }
        self.ready.notify_all(&heap);
    }

    /// Pop the most urgent task, waiting up to `timeout`.
    fn pop(&self, timeout: Duration) -> Option<MovementTask> {
        let deadline = std::time::Instant::now() + timeout;
        let mut heap = self.heap.lock();
        loop {
            if let Some(q) = heap.pop() {
                return Some(q.task);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.ready.wait_timeout(heap, deadline - now);
            heap = guard;
        }
    }

    fn clear(&self) -> usize {
        let mut heap = self.heap.lock();
        let n = heap.len();
        heap.clear();
        n
    }

    /// Wake every parked mover (shutdown path) — notify under the heap
    /// lock so a mover between its emptiness check and its park cannot
    /// miss the signal.
    fn wake_all(&self) {
        let heap = self.heap.lock();
        self.ready.notify_all(&heap);
    }
}

/// Knobs (see [`crate::config::WorkerConfig`] for the file-level
/// counterparts).
#[derive(Clone, Copy, Debug)]
pub struct MovementConfig {
    /// Mover threads draining the movement queue.
    pub threads: usize,
    /// Device utilization fraction above which crossings raise
    /// pressure.
    pub spill_watermark: f64,
    /// Promotions pause while device utilization exceeds this (keeps
    /// promotion from fighting demotion).
    pub promote_watermark: f64,
    /// Urgency for demotions answering failed allocations or blocked
    /// reservations.
    pub urgency_reservation: i64,
    /// Urgency for proactive watermark demotions.
    pub urgency_watermark: i64,
    /// Compute-Task Pre-loading on/off (Fig-4 I).
    pub promote_enabled: bool,
}

impl Default for MovementConfig {
    fn default() -> Self {
        MovementConfig {
            threads: 1,
            spill_watermark: 0.85,
            promote_watermark: 0.70,
            urgency_reservation: 1_000_000,
            urgency_watermark: 100_000,
            promote_enabled: true,
        }
    }
}

/// The executor.
pub struct DataMovementExecutor {
    registry: Arc<HolderRegistry>,
    env: MemEnv,
    governor: MemoryGovernor,
    queue: Arc<TaskQueue>,
    event: Arc<PressureEvent>,
    cfg: MovementConfig,
    moves: Arc<MoveQueue>,
    shutdown: Arc<AtomicBool>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    demotions: AtomicU64,
    spilled_bytes: AtomicU64,
    promotions: AtomicU64,
    plans: AtomicU64,
    /// qid -> (device bytes spilled, promotions) — per-query movement
    /// attribution for concurrent sessions.
    per_query: Mutex<HashMap<u64, (u64, u64)>>,
    metrics: Arc<Metrics>,
}

impl DataMovementExecutor {
    /// Bring up the movement plane: installs the pressure event into
    /// the arena, pinned pool, governor, and compute queue, then spawns
    /// one planner thread plus `cfg.threads` movers.
    ///
    /// Threads park on the event / move queue (both their own `Arc`s)
    /// and hold the executor only as a [`Weak`], upgraded per pass —
    /// so dropping the last external handle without calling
    /// [`DataMovementExecutor::stop`] still winds the threads down via
    /// `Drop` instead of leaking them.
    pub fn start(
        registry: Arc<HolderRegistry>,
        env: MemEnv,
        governor: MemoryGovernor,
        queue: Arc<TaskQueue>,
        cfg: MovementConfig,
        metrics: Arc<Metrics>,
    ) -> Arc<DataMovementExecutor> {
        let event = PressureEvent::new();
        env.arena.install_pressure(event.clone(), cfg.spill_watermark);
        if let Some(pool) = &env.pinned {
            pool.install_pressure(event.clone());
        }
        governor.install_pressure(event.clone());
        queue.add_listener(event.clone());

        let ex = Arc::new(DataMovementExecutor {
            registry,
            env,
            governor,
            queue,
            event: event.clone(),
            cfg,
            moves: MoveQueue::new(),
            shutdown: Arc::new(AtomicBool::new(false)),
            handles: Mutex::new(Vec::new()),
            demotions: AtomicU64::new(0),
            spilled_bytes: AtomicU64::new(0),
            promotions: AtomicU64::new(0),
            plans: AtomicU64::new(0),
            per_query: Mutex::new(HashMap::new()),
            metrics,
        });

        let mut handles = Vec::new();
        {
            let weak = Arc::downgrade(&ex);
            let event = event.clone();
            let stop = ex.shutdown.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("theseus-move-plan".into())
                    .spawn(move || loop {
                        let snap = event.wait(SWEEP);
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        let Some(ex) = weak.upgrade() else { return };
                        ex.plan(snap);
                    })
                    .expect("spawn movement planner"),
            );
        }
        for t in 0..cfg.threads.max(1) {
            let weak = Arc::downgrade(&ex);
            let moves = ex.moves.clone();
            let stop = ex.shutdown.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("theseus-move-{t}"))
                    .spawn(move || loop {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        let Some(mv) = moves.pop(Duration::from_millis(100)) else {
                            continue;
                        };
                        let Some(ex) = weak.upgrade() else { return };
                        ex.execute(mv);
                    })
                    .expect("spawn mover"),
            );
        }
        *ex.handles.lock().unwrap() = handles;
        // Catch pressure raised before we attached (e.g. prefetchable
        // tasks already queued).
        event.mark_queue();
        ex
    }

    /// The shared event (tiers hold clones; tests raise it directly).
    pub fn event(&self) -> &Arc<PressureEvent> {
        &self.event
    }

    pub fn spill_count(&self) -> u64 {
        self.demotions.load(Ordering::Relaxed)
    }

    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes.load(Ordering::Relaxed)
    }

    pub fn promotions(&self) -> u64 {
        self.promotions.load(Ordering::Relaxed)
    }

    /// Device bytes spilled for one query's holders.
    pub fn spilled_bytes_for(&self, qid: u64) -> u64 {
        self.per_query.lock().unwrap().get(&qid).map_or(0, |v| v.0)
    }

    /// Promotions staged for one query's holders.
    pub fn promotions_for(&self, qid: u64) -> u64 {
        self.per_query.lock().unwrap().get(&qid).map_or(0, |v| v.1)
    }

    /// Drop one finished query's movement counters (lifetime totals
    /// keep counting).
    pub fn clear_query(&self, qid: u64) {
        self.per_query.lock().unwrap().remove(&qid);
    }

    /// Planner passes executed (event wakes + sweeps that found work).
    pub fn plan_count(&self) -> u64 {
        self.plans.load(Ordering::Relaxed)
    }

    // ------------------------------------------------------- planning

    /// One planning pass: victims and beneficiaries from a single
    /// `op_priorities` snapshot.
    fn plan(&self, snap: PressureSnapshot) {
        // Refresh the §3.4 pool gauges (bounce/waste/exhaustion) on
        // every wake — the movement plane is the natural heartbeat for
        // memory-subsystem metrics.
        if let Some(pool) = &self.env.pinned {
            pool.publish_metrics(&self.metrics);
        }
        crate::sync::publish_metrics(&self.metrics);
        // Idle sweeps (no pressure) are the natural moment to compact
        // mostly-dead spill segments — writers aren't contending for
        // the segments lock, and the reclaimed disk shrinks the next
        // demotion's seek span.
        if snap.is_empty() {
            let _ = self.env.spill.compact();
            self.metrics
                .gauge("spill.compacted_bytes")
                .set(self.env.spill.compacted_bytes() as i64);
            self.metrics
                .gauge("spill.write_failover_total")
                .set(self.env.spill.write_failover_total() as i64);
        }
        let threshold =
            (self.env.arena.capacity() as f64 * self.cfg.spill_watermark) as usize;
        let overage = self.env.arena.in_use().saturating_sub(threshold);
        // The sweep path (empty snapshot) still repairs sustained
        // overage the event may have under-stated.
        let device_need = snap.device_need.max(overage);
        let host_need = snap.host_need;
        let promote = self.cfg.promote_enabled
            && (snap.queue_dirty || snap.is_empty())
            && self.env.arena.utilization() <= self.cfg.promote_watermark;
        if device_need == 0 && host_need == 0 && !promote {
            return;
        }

        // Computed once, used by both directions.
        let prios = self.queue.op_priorities();
        let mut tasks: Vec<MovementTask> = Vec::new();
        let mut victim_ids: HashSet<usize> = HashSet::new();

        if device_need > 0 {
            // Needs beyond the watermark overage come from failed
            // allocations / blocked reservations: maximum urgency.
            let base = if device_need > overage {
                self.cfg.urgency_reservation
            } else {
                self.cfg.urgency_watermark
            };
            self.plan_demotions(
                Tier::Device,
                device_need,
                base,
                &prios,
                &mut victim_ids,
                &mut tasks,
            );
        }
        if host_need > 0 {
            self.plan_demotions(
                Tier::Host,
                host_need,
                self.cfg.urgency_watermark,
                &prios,
                &mut victim_ids,
                &mut tasks,
            );
        }
        if promote {
            self.plan_promotions(&prios, &victim_ids, &mut tasks);
        }
        if tasks.is_empty() {
            return;
        }
        self.plans.fetch_add(1, Ordering::Relaxed);
        self.metrics.counter("movement.plans").inc();
        self.metrics.gauge("movement.queue_depth").add(tasks.len() as i64);
        self.moves.push_all(tasks);
    }

    /// Victim selection for one tier: holders with bytes at `from`,
    /// coldest operator first (lowest queued priority; operators with
    /// no queued tasks are coldest of all), fattest first among equals
    /// — "to avoid spilling data for which compute tasks are close to
    /// being executed" (§3.3.2), now applied to *every* demotion tier
    /// pair.
    fn plan_demotions(
        &self,
        from: Tier,
        need: usize,
        base: i64,
        prios: &HashMap<(u64, usize), i64>,
        victim_ids: &mut HashSet<usize>,
        out: &mut Vec<MovementTask>,
    ) {
        let mut victims: Vec<(i64, usize, u64, usize, BatchHolder)> = Vec::new();
        self.registry.for_each(|qid, op, h| {
            let st = h.stats();
            let bytes = match from {
                Tier::Device => st.device_bytes,
                Tier::Host => st.host_bytes,
                Tier::Disk => 0,
            };
            if bytes > 0 {
                let prio = prios.get(&(qid, op)).copied().unwrap_or(i64::MIN);
                victims.push((prio, bytes, qid, op, h.clone()));
            }
        });
        victims.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        let to = from.spill_target().unwrap_or(Tier::Disk);
        let mut remaining = need;
        for (rank, (_, bytes, qid, op, holder)) in victims.into_iter().enumerate() {
            if remaining == 0 {
                break;
            }
            let budget = bytes.min(remaining);
            remaining -= budget;
            victim_ids.insert(holder.id());
            out.push(MovementTask {
                holder,
                qid,
                op,
                direction: Direction::Demote,
                from,
                to,
                urgency: base.saturating_sub(rank as i64),
                budget,
            });
        }
    }

    /// Beneficiary selection: queued compute tasks advertising
    /// [`Prefetch::Promote`] whose holder has disk-tier batches —
    /// hottest first (by the op's best queued priority, the same
    /// snapshot victim selection reads, scaled by the owning session's
    /// weight so a latency-sensitive query's holders win promotion over
    /// a batch query's at equal plan depth), and never a holder that is
    /// a demotion victim in this same round.
    fn plan_promotions(
        &self,
        prios: &HashMap<(u64, usize), i64>,
        victim_ids: &HashSet<usize>,
        out: &mut Vec<MovementTask>,
    ) {
        let mut seen: HashSet<usize> = HashSet::new();
        let mut found: Vec<(i64, u64, usize, BatchHolder)> = Vec::new();
        self.queue.for_each_queued(|t| {
            if let Some(Prefetch::Promote { holder }) = &t.prefetch {
                let id = holder.id();
                if victim_ids.contains(&id) || !seen.insert(id) {
                    return;
                }
                if holder.stats().disk_batches > 0 {
                    let prio =
                        prios.get(&(t.qid, t.op)).copied().unwrap_or(t.priority);
                    let weighted = prio.saturating_mul(t.weight.max(1));
                    found.push((weighted, t.qid, t.op, holder.clone()));
                }
            }
        });
        for (prio, qid, op, holder) in found {
            out.push(MovementTask {
                holder,
                qid,
                op,
                direction: Direction::Promote,
                from: Tier::Disk,
                to: Tier::Host,
                // always below demotion urgencies: relieving pressure
                // outranks staging ahead of it
                urgency: prio.min(self.cfg.urgency_watermark - 1),
                budget: PROMOTE_BATCHES_PER_ROUND,
            });
        }
    }

    /// Plan promotions against the live queue without enqueueing them —
    /// returns `(qid, urgency)` in the order the mover would execute
    /// (most urgent first). A deterministic observation point for tests
    /// asserting that a weighted session's holders win promotion; it
    /// ignores `promote_enabled` so harnesses can keep the live
    /// promotion plane off while asserting on the plan.
    #[doc(hidden)]
    pub fn planned_promotions(&self) -> Vec<(u64, i64)> {
        let prios = self.queue.op_priorities();
        let mut tasks = Vec::new();
        self.plan_promotions(&prios, &HashSet::new(), &mut tasks);
        let mut order: Vec<(u64, i64)> =
            tasks.into_iter().map(|t| (t.qid, t.urgency)).collect();
        order.sort_by(|a, b| b.1.cmp(&a.1));
        order
    }

    // ------------------------------------------------------- moving

    fn execute(&self, mv: MovementTask) {
        self.metrics.gauge("movement.queue_depth").add(-1);
        match mv.direction {
            Direction::Demote => {
                self.run_demote(&mv);
            }
            Direction::Promote => self.run_promote(&mv),
        }
    }

    /// Execute one demotion task; returns bytes this call freed at
    /// `mv.from`.
    fn run_demote(&self, mv: &MovementTask) -> usize {
        let mut freed = 0usize;
        let mut errored = false;
        while freed < mv.budget {
            match mv.holder.demote_one(mv.from) {
                Ok(0) => break,
                Ok(n) => {
                    freed += n;
                    self.demotions.fetch_add(1, Ordering::Relaxed);
                    if mv.from == Tier::Device {
                        self.spilled_bytes.fetch_add(n as u64, Ordering::Relaxed);
                        self.per_query
                            .lock()
                            .unwrap()
                            .entry(mv.qid)
                            .or_insert((0, 0))
                            .0 += n as u64;
                    }
                }
                Err(e) => {
                    log::warn!("demote {:?}->{:?} failed: {e}", mv.from, mv.to);
                    errored = true;
                    break;
                }
            }
        }
        if freed > 0 {
            self.metrics.counter("movement.demote_bytes").add(freed as u64);
            if mv.from == Tier::Device {
                // Deliver the wakeup blocked reservations are parked on.
                self.governor.notify_freed();
            }
            // ResidencyChanged: queued tasks reading this holder re-rank
            // lazily (their inputs just got colder).
            self.queue.notify_residency_changed(mv.holder.id());
        }
        // A victim drained out from under its budget (a compute task
        // popped its batches between plan and execution): hand the
        // shortfall back to the planner so *other* holders serve it
        // this generation rather than waiting for the governor's re-
        // raise. Skipped on error — re-planning the same failing
        // holder would spin.
        if freed < mv.budget && !errored {
            let shortfall = mv.budget - freed;
            match mv.from {
                Tier::Device => self.event.raise_device(shortfall),
                Tier::Host => self.event.raise_host(shortfall),
                Tier::Disk => {}
            }
        }
        freed
    }

    fn run_promote(&self, mv: &MovementTask) {
        let mut moved = false;
        for _ in 0..mv.budget {
            if self.env.arena.utilization() > self.cfg.promote_watermark {
                break; // device pressure returned: stop staging
            }
            // A dry pinned pool means further promotions land in
            // unbounded pageable memory — stop and let host pressure
            // (already raised by the pool) demote first.
            if let Some(pool) = &self.env.pinned {
                if pool.free_buffers() == 0 {
                    break;
                }
            }
            match mv.holder.promote_one() {
                Ok(true) => {
                    moved = true;
                    self.promotions.fetch_add(1, Ordering::Relaxed);
                    self.per_query
                        .lock()
                        .unwrap()
                        .entry(mv.qid)
                        .or_insert((0, 0))
                        .1 += 1;
                    self.metrics.counter("movement.promotions").inc();
                }
                Ok(false) => break,
                Err(e) => {
                    log::debug!("promote: {e}");
                    break;
                }
            }
        }
        if moved {
            // ResidencyChanged: the beneficiary's queued tasks re-rank
            // upward (their inputs just got hotter).
            self.queue.notify_residency_changed(mv.holder.id());
        }
    }

    /// Synchronous demotion for callers that need bytes freed *now* on
    /// their own thread (tests; emergency paths). Plans with the same
    /// priority policy and executes inline. Returns bytes freed.
    pub fn demote_for(&self, bytes: usize) -> usize {
        let prios = self.queue.op_priorities();
        let mut tasks = Vec::new();
        let mut victims = HashSet::new();
        self.plan_demotions(
            Tier::Device,
            bytes,
            self.cfg.urgency_reservation,
            &prios,
            &mut victims,
            &mut tasks,
        );
        let mut freed = 0;
        for mv in &tasks {
            freed += self.run_demote(mv);
        }
        freed
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // final pool-gauge snapshot so post-run reports see the totals
        if let Some(pool) = &self.env.pinned {
            pool.publish_metrics(&self.metrics);
        }
        crate::sync::publish_metrics(&self.metrics);
        // wake the planner (parked on the event) and the movers
        self.event.mark_queue();
        self.moves.wake_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
        // Tasks still queued were never executed: drop them and settle
        // the depth gauge so post-stop snapshots don't report phantom
        // in-flight movement.
        let dropped = self.moves.clear();
        if dropped > 0 {
            self.metrics.gauge("movement.queue_depth").add(-(dropped as i64));
        }
    }
}

impl Drop for DataMovementExecutor {
    fn drop(&mut self) {
        // Threads hold only Weak<Self>, so this does run when the last
        // external handle goes away without stop(); signal them down
        // (no join: the dropping thread may be one of them).
        self.shutdown.store(true, Ordering::Relaxed);
        self.event.mark_queue();
        self.moves.wake_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Task;
    use crate::types::{Column, RecordBatch};

    fn batch(rows: usize) -> RecordBatch {
        RecordBatch::new(vec![Column::i64("k", vec![7; rows])]).unwrap()
    }

    fn start(
        reg: &Arc<HolderRegistry>,
        env: &MemEnv,
        queue: &Arc<TaskQueue>,
        cfg: MovementConfig,
    ) -> (Arc<DataMovementExecutor>, MemoryGovernor) {
        let governor = MemoryGovernor::new(env.arena.clone());
        let ex = DataMovementExecutor::start(
            reg.clone(),
            env.clone(),
            governor.clone(),
            queue.clone(),
            cfg,
            Arc::new(Metrics::default()),
        );
        (ex, governor)
    }

    /// Acceptance: a reservation blocked on a full arena is unblocked
    /// by the pressure event — with the watermark disabled (1.0) there
    /// is no polling trigger left, so only the event can have done it.
    #[test]
    fn blocked_reservation_unblocked_by_pressure_event() {
        let env = MemEnv::test(10_000);
        let reg = HolderRegistry::new();
        let queue = TaskQueue::new();
        let h = BatchHolder::new("a", env.clone());
        reg.register(0, 0, h.clone());
        h.push_batch(batch(1000)).unwrap(); // ~8 KB resident on device
        let cfg = MovementConfig { spill_watermark: 1.0, ..Default::default() };
        let (ex, governor) = start(&reg, &env, &queue, cfg);
        let raises_before = ex.event().raise_count();

        let r = governor.reserve(6_000, Duration::from_secs(2)).unwrap();
        assert_eq!(r.bytes(), 6_000);
        assert!(ex.spill_count() > 0, "event-driven spill must have run");
        assert!(
            ex.event().raise_count() > raises_before,
            "reservation must signal the event"
        );
        assert_eq!(h.stats().device_batches, 0, "victim demoted off device");
        ex.stop();
    }

    #[test]
    fn watermark_crossing_spills_event_driven() {
        let env = MemEnv::test(100_000);
        let reg = HolderRegistry::new();
        let queue = TaskQueue::new();
        let h = BatchHolder::new("a", env.clone());
        reg.register(0, 0, h.clone());
        let cfg = MovementConfig { spill_watermark: 0.5, ..Default::default() };
        let (ex, _governor) = start(&reg, &env, &queue, cfg);
        for _ in 0..12 {
            h.push_batch(batch(1000)).unwrap();
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(3);
        while env.arena.utilization() > 0.55 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            env.arena.utilization() <= 0.55,
            "crossing failed to trigger spill: {}",
            env.arena.utilization()
        );
        // data intact
        let mut rows = 0;
        while let Some(db) = h.pop_device().unwrap() {
            rows += db.rows();
        }
        assert_eq!(rows, 12_000);
        ex.stop();
    }

    #[test]
    fn cold_operators_spill_first_both_tiers() {
        let env = MemEnv::test(1 << 20);
        let reg = HolderRegistry::new();
        let queue = TaskQueue::new();
        let hot = BatchHolder::new("hot", env.clone());
        let cold = BatchHolder::new("cold", env.clone());
        reg.register(0, 1, hot.clone());
        reg.register(0, 2, cold.clone());
        hot.push_batch(batch(500)).unwrap();
        cold.push_batch(batch(500)).unwrap();
        // op 1 has a high-priority queued task; op 2 has none
        queue.submit(Task::new(1, 1_000, Arc::new(|_| Ok(()))));
        let cfg = MovementConfig { spill_watermark: 1.0, ..Default::default() };
        let (ex, _governor) = start(&reg, &env, &queue, cfg);
        ex.demote_for(100);
        assert_eq!(cold.stats().device_batches, 0, "cold holder spilled");
        assert_eq!(hot.stats().device_batches, 1, "hot holder kept on device");

        // host tier honors the same priorities (the seed's
        // spill_host_for ignored them)
        hot.push_batch_host(batch(400)).unwrap();
        cold.push_batch_host(batch(400)).unwrap();
        ex.event().raise_host(100);
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while cold.stats().disk_batches == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(cold.stats().disk_batches >= 1, "cold host batch demoted to disk");
        assert_eq!(hot.stats().disk_batches, 0, "hot host batch kept");
        ex.stop();
    }

    #[test]
    fn promotion_stages_disk_batches_for_queued_tasks() {
        let env = MemEnv::test(1 << 20);
        let reg = HolderRegistry::new();
        let queue = TaskQueue::new();
        let holder = BatchHolder::new("in", env.clone());
        reg.register(0, 1, holder.clone());
        holder.push_batch_host(batch(100)).unwrap();
        holder.spill_host_one().unwrap();
        assert_eq!(holder.stats().disk_batches, 1);

        let (ex, _governor) = start(&reg, &env, &queue, MovementConfig::default());
        queue.submit(
            Task::new(1, 50, Arc::new(|_| Ok(())))
                .with_prefetch(Prefetch::Promote { holder: holder.clone() }),
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while holder.stats().disk_batches > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(holder.stats().disk_batches, 0, "disk batch not promoted");
        assert_eq!(holder.stats().host_batches, 1);
        assert!(ex.promotions() >= 1);
        ex.stop();
    }

    #[test]
    fn promotion_disabled_leaves_disk_alone() {
        let env = MemEnv::test(1 << 20);
        let reg = HolderRegistry::new();
        let queue = TaskQueue::new();
        let holder = BatchHolder::new("in", env.clone());
        reg.register(0, 1, holder.clone());
        holder.push_batch_host(batch(100)).unwrap();
        holder.spill_host_one().unwrap();
        let cfg = MovementConfig { promote_enabled: false, ..Default::default() };
        let (ex, _governor) = start(&reg, &env, &queue, cfg);
        queue.submit(
            Task::new(1, 50, Arc::new(|_| Ok(())))
                .with_prefetch(Prefetch::Promote { holder: holder.clone() }),
        );
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(holder.stats().disk_batches, 1, "promotion must stay off");
        assert_eq!(ex.promotions(), 0);
        ex.stop();
    }

    #[test]
    fn concurrent_demote_promote_same_holder_via_executor() {
        // Demotion pressure and promotion-worthy queued tasks target
        // the same holder; the plane must neither deadlock nor lose
        // batches.
        let env = MemEnv::test(1 << 22);
        let reg = HolderRegistry::new();
        let queue = TaskQueue::new();
        let h = BatchHolder::new("contended", env.clone());
        reg.register(0, 3, h.clone());
        const BATCHES: usize = 16;
        for _ in 0..BATCHES {
            h.push_batch(batch(200)).unwrap();
        }
        let cfg = MovementConfig {
            threads: 2,
            spill_watermark: 1.0,
            ..Default::default()
        };
        let (ex, _governor) = start(&reg, &env, &queue, cfg);
        queue.submit(
            Task::new(3, 10, Arc::new(|_| Ok(())))
                .with_prefetch(Prefetch::Promote { holder: h.clone() }),
        );
        for round in 0..20 {
            ex.event().raise_device(2_000);
            ex.event().raise_host(1_000);
            if round % 3 == 0 {
                ex.event().mark_queue();
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        ex.stop();
        assert_eq!(h.stats().total_batches(), BATCHES, "{:?}", h.stats());
        let mut rows = 0;
        while let Some(db) = h.pop_device().unwrap() {
            rows += db.rows();
        }
        assert_eq!(rows, BATCHES * 200, "rows lost under contention");
    }

    #[test]
    fn completed_demotion_reranks_queued_tasks() {
        use crate::executors::compute::ResidencyBonus;
        let env = MemEnv::test(1 << 20);
        let reg = HolderRegistry::new();
        let metrics = Arc::new(Metrics::default());
        let bonus =
            ResidencyBonus { device_bonus: 50, spilled_penalty: 200, rerank_batch: 16 };
        let queue = TaskQueue::with_residency(bonus, metrics.clone());
        let cold = BatchHolder::new("cold", env.clone());
        let hot = BatchHolder::new("hot", env.clone());
        reg.register(0, 2, cold.clone()); // only the cold holder is a victim
        cold.push_batch(batch(400)).unwrap();
        hot.push_batch(batch(400)).unwrap();

        // Both device-resident at submit: FIFO would run `cold` first.
        queue.submit(
            Task::new(2, 10, Arc::new(|_| Ok(()))).with_input(cold.clone()),
        );
        queue.submit(Task::new(1, 10, Arc::new(|_| Ok(()))).with_input(hot.clone()));

        let governor = MemoryGovernor::new(env.arena.clone());
        let cfg = MovementConfig { spill_watermark: 1.0, ..Default::default() };
        let ex = DataMovementExecutor::start(
            reg.clone(),
            env.clone(),
            governor,
            queue.clone(),
            cfg,
            Arc::new(Metrics::default()),
        );
        // synchronous demotion completes and raises ResidencyChanged
        assert!(ex.demote_for(100) > 0);
        assert_eq!(cold.stats().device_batches, 0);

        let first = queue.try_pop().unwrap();
        assert_eq!(first.op, 1, "re-rank must run the hot-input task first");
        assert_eq!(queue.try_pop().unwrap().op, 2);
        assert!(metrics.gauge_value("sched.residency_rerank_total") > 0);
        assert!(metrics.gauge_value("sched.spill_stall_avoided") > 0);
        ex.stop();
    }

    #[test]
    fn session_weight_orders_promotions() {
        // Two queries, equal base priority, both with a disk-resident
        // holder advertising Prefetch::Promote: the weight-8 session's
        // holder must be planned at higher urgency than the weight-1
        // session's, and clear_query must drop exactly one query's
        // holders from management.
        let env = MemEnv::test(1 << 20);
        let reg = HolderRegistry::new();
        let queue = TaskQueue::new();
        let mk = |name: &str| {
            let h = BatchHolder::new(name, env.clone());
            h.push_batch_host(batch(100)).unwrap();
            h.spill_host_one().unwrap();
            h
        };
        let batch_h = mk("batch");
        let inter_h = mk("interactive");
        reg.register(1, 4, batch_h.clone());
        reg.register(2, 4, inter_h.clone());
        // keep the live promotion plane off: we assert on the plan
        let cfg = MovementConfig { promote_enabled: false, ..Default::default() };
        let (ex, _governor) = start(&reg, &env, &queue, cfg);
        queue.submit(
            Task::new(4, 50, Arc::new(|_| Ok(())))
                .with_query(1, 1)
                .with_prefetch(Prefetch::Promote { holder: batch_h.clone() }),
        );
        queue.submit(
            Task::new(4, 50, Arc::new(|_| Ok(())))
                .with_query(2, 8)
                .with_prefetch(Prefetch::Promote { holder: inter_h.clone() }),
        );
        let order = ex.planned_promotions();
        assert_eq!(order.len(), 2);
        assert_eq!(order[0].0, 2, "weighted session promoted first: {order:?}");
        assert_eq!(order[1].0, 1);
        assert!(order[0].1 > order[1].1, "urgency strictly higher: {order:?}");

        reg.clear_query(1);
        assert_eq!(reg.len(), 1, "only query 1's holders unregistered");
        let mut left = Vec::new();
        reg.for_each(|qid, _, _| left.push(qid));
        assert_eq!(left, vec![2]);
        ex.stop();
    }

    #[test]
    fn registry_residency_aggregates_holders() {
        let env = MemEnv::test(1 << 20);
        let reg = HolderRegistry::new();
        let a = BatchHolder::new("a", env.clone());
        let b = BatchHolder::new("b", env.clone());
        reg.register(0, 0, a.clone());
        reg.register(0, 1, b.clone());
        a.push_batch(batch(100)).unwrap();
        b.push_batch_host(batch(100)).unwrap();
        b.spill_host_one().unwrap();
        let snap = reg.residency();
        assert_eq!(snap.device_bytes, a.residency().device_bytes);
        assert_eq!(snap.spilled_bytes, b.residency().spilled_bytes);
        assert!(snap.device_bytes > 0 && snap.spilled_bytes > 0);
    }

    #[test]
    fn registry_accounting_is_cheap_and_correct() {
        let env = MemEnv::test(1 << 20);
        let reg = HolderRegistry::new();
        let a = BatchHolder::new("a", env.clone());
        let b = BatchHolder::new("b", env.clone());
        reg.register(0, 0, a.clone());
        reg.register(0, 1, b.clone());
        a.push_batch(batch(100)).unwrap();
        b.push_batch(batch(200)).unwrap();
        b.push_batch_host(batch(50)).unwrap();
        assert_eq!(
            reg.device_bytes(),
            a.stats().device_bytes + b.stats().device_bytes
        );
        assert_eq!(reg.host_bytes(), b.stats().host_bytes);
        assert_eq!(reg.len(), 2);
        reg.clear();
        assert!(reg.is_empty());
        assert_eq!(reg.device_bytes(), 0);
    }
}
