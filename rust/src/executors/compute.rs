//! Compute Executor (§3.3.1): a DAG-aware priority queue drained by a
//! configurable pool of threads, with OOM retry.
//!
//! "The Compute Executor can prioritize tasks in its queue based on
//! different configurable schemes that can take into account a wide
//! variety of factors, including where in the query graph the task came
//! from and the memory tier that the input data resides in. Each
//! Compute Executor thread controls a separate CUDA stream" — here,
//! each thread issues PJRT executions independently (the CPU client
//! runs them on its own pool, our stream analog).
//!
//! Failed tasks with retryable errors (device OOM, reservation timeout,
//! pinned exhaustion) are re-queued with a decayed priority; the
//! operator's memory history is updated by the task itself.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::exec::{Task, WorkerCtx};
use crate::Error;

const MAX_ATTEMPTS: u32 = 6;

struct Queued {
    priority: i64,
    /// FIFO tiebreak: smaller sequence first.
    seq: u64,
    task: Task,
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq)) // max-heap: older first on tie
    }
}

/// The shared queue. The Pre-load and Data-Movement Executors hold
/// references to inspect it (Insight B), and register
/// [`crate::memory::PressureEvent`] listeners so pre-loadable
/// submissions wake them instead of being discovered by polling.
pub struct TaskQueue {
    heap: Mutex<BinaryHeap<Queued>>,
    ready: Condvar,
    seq: AtomicU64,
    /// Tasks currently executing (quiescence detection).
    in_flight: AtomicU64,
    /// Marked dirty when a task with a prefetch hint is submitted.
    listeners: Mutex<Vec<Arc<crate::memory::PressureEvent>>>,
}

impl Default for TaskQueue {
    fn default() -> Self {
        TaskQueue {
            heap: Mutex::new(BinaryHeap::new()),
            ready: Condvar::new(),
            seq: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            listeners: Mutex::new(Vec::new()),
        }
    }
}

impl TaskQueue {
    pub fn new() -> Arc<TaskQueue> {
        Arc::new(TaskQueue::default())
    }

    /// Register an event to be marked dirty whenever a task carrying a
    /// [`crate::exec::task::Prefetch`] is submitted (queue
    /// introspection without a polling loop).
    pub fn add_listener(&self, event: Arc<crate::memory::PressureEvent>) {
        self.listeners.lock().unwrap().push(event);
    }

    pub fn submit(&self, task: Task) {
        let prefetchable = task.prefetch.is_some();
        let q = Queued {
            priority: task.priority,
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            task,
        };
        self.heap.lock().unwrap().push(q);
        self.ready.notify_one();
        if prefetchable {
            for ev in self.listeners.lock().unwrap().iter() {
                ev.mark_queue();
            }
        }
    }

    fn pop(&self, timeout: Duration) -> Option<Task> {
        let deadline = std::time::Instant::now() + timeout;
        let mut heap = self.heap.lock().unwrap();
        loop {
            if let Some(q) = heap.pop() {
                self.in_flight.fetch_add(1, Ordering::AcqRel);
                return Some(q.task);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.ready.wait_timeout(heap, deadline - now).unwrap();
            heap = guard;
        }
    }

    fn task_done(&self) {
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn len(&self) -> usize {
        self.heap.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Queue fully drained and nothing executing.
    pub fn quiescent(&self) -> bool {
        let heap = self.heap.lock().unwrap();
        heap.is_empty() && self.in_flight.load(Ordering::Acquire) == 0
    }

    /// Visit every queued (not in-flight) task — the inspection hook
    /// the Pre-load and Data-Movement Executors use. Unordered.
    pub fn for_each_queued(&self, mut f: impl FnMut(&Task)) {
        let heap = self.heap.lock().unwrap();
        for q in heap.iter() {
            f(&q.task);
        }
    }

    /// Highest queued priority per operator (Data-Movement Executor:
    /// spill holders feeding imminent tasks last, promote them first).
    pub fn op_priorities(&self) -> std::collections::HashMap<usize, i64> {
        let heap = self.heap.lock().unwrap();
        let mut m = std::collections::HashMap::new();
        for q in heap.iter() {
            let e = m.entry(q.task.op).or_insert(i64::MIN);
            *e = (*e).max(q.task.priority);
        }
        m
    }
}

/// The executor: `threads` workers draining the queue.
pub struct ComputeExecutor {
    queue: Arc<TaskQueue>,
    shutdown: Arc<AtomicBool>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    executed: Arc<AtomicU64>,
    retries: Arc<AtomicU64>,
    /// First non-retryable failure (aborts the query).
    failure: Arc<Mutex<Option<Error>>>,
}

impl ComputeExecutor {
    pub fn start(ctx: WorkerCtx, queue: Arc<TaskQueue>, threads: usize) -> Arc<ComputeExecutor> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let ex = Arc::new(ComputeExecutor {
            queue: queue.clone(),
            shutdown: shutdown.clone(),
            handles: Mutex::new(Vec::new()),
            executed: Arc::new(AtomicU64::new(0)),
            retries: Arc::new(AtomicU64::new(0)),
            failure: Arc::new(Mutex::new(None)),
        });
        let mut handles = Vec::new();
        for t in 0..threads.max(1) {
            let queue = queue.clone();
            let stop = shutdown.clone();
            let ctx = ctx.clone();
            let executed = ex.executed.clone();
            let retries = ex.retries.clone();
            let failure = ex.failure.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("theseus-compute-{}-{t}", ctx.worker_id))
                    .spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            let mut task = match queue.pop(Duration::from_millis(20)) {
                                Some(t) => t,
                                None => continue,
                            };
                            let r = (task.run)(&ctx);
                            queue.task_done();
                            match r {
                                Ok(()) => {
                                    executed.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(e) if e.is_retryable() && task.attempts < MAX_ATTEMPTS => {
                                    retries.fetch_add(1, Ordering::Relaxed);
                                    task.attempts += 1;
                                    // decay priority so other work makes
                                    // room (the movement executor gets
                                    // a chance to spill)
                                    task.priority -= 10 * task.attempts as i64;
                                    // brief backoff before re-queue
                                    std::thread::sleep(Duration::from_millis(
                                        2 << task.attempts.min(5),
                                    ));
                                    queue.submit(task);
                                }
                                Err(e) => {
                                    log::error!(
                                        "task op {} failed permanently: {e}",
                                        task.op
                                    );
                                    failure.lock().unwrap().get_or_insert(e);
                                }
                            }
                        }
                    })
                    .expect("spawn compute"),
            );
        }
        *ex.handles.lock().unwrap() = handles;
        ex
    }

    pub fn queue(&self) -> &Arc<TaskQueue> {
        &self.queue
    }

    pub fn executed(&self) -> u64 {
        self.executed.load(Ordering::Relaxed)
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// First permanent failure, if any (take clears it).
    pub fn take_failure(&self) -> Option<Error> {
        self.failure.lock().unwrap().take()
    }

    pub fn has_failure(&self) -> bool {
        self.failure.lock().unwrap().is_some()
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ComputeExecutor {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    fn task(op: usize, prio: i64, f: impl Fn(&WorkerCtx) -> crate::Result<()> + Send + Sync + 'static) -> Task {
        Task::new(op, prio, Arc::new(f))
    }

    #[test]
    fn priority_order_with_fifo_ties() {
        let q = TaskQueue::new();
        let order = Arc::new(Mutex::new(Vec::new()));
        for (op, prio) in [(0usize, 10i64), (1, 30), (2, 10), (3, 20)] {
            let order = order.clone();
            q.submit(task(op, prio, move |_| {
                order.lock().unwrap().push(op);
                Ok(())
            }));
        }
        // drain single-threaded for determinism
        let ctx = WorkerCtx::test();
        while let Some(t) = q.pop(Duration::from_millis(1)) {
            (t.run)(&ctx).unwrap();
            q.task_done();
        }
        assert_eq!(*order.lock().unwrap(), vec![1, 3, 0, 2]);
    }

    #[test]
    fn executor_runs_everything() {
        let q = TaskQueue::new();
        let counter = Arc::new(AtomicU32::new(0));
        let ex = ComputeExecutor::start(WorkerCtx::test(), q.clone(), 4);
        for i in 0..100 {
            let c = counter.clone();
            q.submit(task(i % 5, i as i64, move |_| {
                c.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }));
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !q.quiescent() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(ex.executed(), 100);
        assert!(!ex.has_failure());
        ex.stop();
    }

    #[test]
    fn retryable_errors_retry_then_succeed() {
        let q = TaskQueue::new();
        let ex = ComputeExecutor::start(WorkerCtx::test(), q.clone(), 2);
        let fails = Arc::new(AtomicU32::new(2)); // fail twice, then ok
        let done = Arc::new(AtomicU32::new(0));
        let f2 = fails.clone();
        let d2 = done.clone();
        q.submit(task(0, 0, move |_| {
            if f2.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                .is_ok()
            {
                Err(Error::DeviceOom { requested: 1, capacity: 0, in_use: 0 })
            } else {
                d2.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
        }));
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while done.load(Ordering::Relaxed) == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(done.load(Ordering::Relaxed), 1);
        assert!(ex.retries() >= 2);
        assert!(!ex.has_failure());
        ex.stop();
    }

    #[test]
    fn permanent_failure_is_captured() {
        let q = TaskQueue::new();
        let ex = ComputeExecutor::start(WorkerCtx::test(), q.clone(), 1);
        q.submit(task(0, 0, |_| Err(Error::internal("boom"))));
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while !ex.has_failure() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let e = ex.take_failure().unwrap();
        assert!(e.to_string().contains("boom"));
        ex.stop();
    }

    #[test]
    fn retry_gives_up_after_max_attempts() {
        let q = TaskQueue::new();
        let ex = ComputeExecutor::start(WorkerCtx::test(), q.clone(), 1);
        q.submit(task(0, 0, |_| {
            Err(Error::DeviceOom { requested: 1, capacity: 0, in_use: 0 })
        }));
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !ex.has_failure() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(ex.has_failure(), "should surface OOM after max retries");
        ex.stop();
    }

    #[test]
    fn queue_inspection_sees_pending_tasks() {
        let q = TaskQueue::new();
        q.submit(task(7, 100, |_| Ok(())));
        q.submit(task(7, 50, |_| Ok(())));
        q.submit(task(2, 80, |_| Ok(())));
        let mut seen = 0;
        q.for_each_queued(|t| {
            assert!(t.op == 7 || t.op == 2);
            seen += 1;
        });
        assert_eq!(seen, 3);
        let prios = q.op_priorities();
        assert_eq!(prios[&7], 100);
        assert_eq!(prios[&2], 80);
    }

    #[test]
    fn quiescent_requires_empty_and_idle() {
        let q = TaskQueue::new();
        assert!(q.quiescent());
        q.submit(task(0, 0, |_| Ok(())));
        assert!(!q.quiescent());
        let t = q.pop(Duration::from_millis(10)).unwrap();
        assert!(!q.quiescent(), "in-flight task counts");
        let ctx = WorkerCtx::test();
        (t.run)(&ctx).unwrap();
        q.task_done();
        assert!(q.quiescent());
    }
}
